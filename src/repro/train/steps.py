"""Lowerable step functions: train_step / prefill_step / decode_step.

These are the functions the multi-pod dry-run lowers and the trainers jit.
All are pure: (params, opt_state, batch) -> (params, opt_state, metrics) etc.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import model as M
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.compression import apply_error_feedback

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "init_train_state"]


def init_train_state(cfg: ArchConfig, opt_cfg: AdamWConfig, key):
    params = M.init_params(cfg, key)
    opt_state = adamw_init(params, opt_cfg)
    return params, opt_state


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    grad_compression: bool = False):
    """Returns train_step(params, opt_state, batch[, err_state])."""

    def train_step(params, opt_state, batch, err_state=None):
        (loss, metrics), grads = jax.value_and_grad(
            M.loss_fn, has_aux=True)(params, batch, cfg)
        new_err = None
        if grad_compression:
            grads, new_err = apply_error_feedback(grads, err_state)
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state,
                                                      opt_cfg)
        metrics = dict(metrics, total_loss=loss, **opt_metrics)
        if grad_compression:
            return params, opt_state, new_err, metrics
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, max_len: Optional[int] = None):
    def prefill_step(params, batch):
        L = max_len if max_len is not None else (
            batch["tokens"].shape[1] if "tokens" in batch else batch["embeds"].shape[1])
        return M.prefill(params, batch, cfg, max_len=L)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, token, caches, cur_pos):
        return M.decode_step(params, token, caches, cur_pos, cfg)
    return decode_step
