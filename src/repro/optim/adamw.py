"""Sharded AdamW + cosine schedule with warmup + global-norm clipping.

Optimizer state mirrors the param tree (same sharding), with configurable
state dtype (bf16 m/v for the 314B/1T configs — the int8-Adam class tradeoff;
see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), g


def adamw_init(params, cfg: AdamWConfig):
    dt = dict(float32=jnp.float32, bfloat16=jnp.bfloat16)[cfg.state_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dtype=dt)
    return dict(m=jax.tree.map(zeros, params),
                v=jax.tree.map(zeros, params),
                step=jnp.zeros((), jnp.int32))


def adamw_update(params, grads, state, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict, Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step.astype(jnp.float32))
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = dict(m=jax.tree.unflatten(tdef, [o[1] for o in out]),
                     v=jax.tree.unflatten(tdef, [o[2] for o in out]),
                     step=step)
    return new_params, new_state, dict(lr=lr, grad_norm=gnorm)
