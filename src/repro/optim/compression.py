"""int8 gradient compression with error feedback (distributed-optimization trick).

Per-tensor-row scaling: g ≈ scale * int8.  The residual (g - dequant) is
carried in an error buffer and added to the next step's gradient, so the
compression bias vanishes over time (error-feedback SGD/Adam, 1-bit-Adam
class).  In a multi-pod run this halves/quarters the DP all-reduce bytes —
it is applied to the *data-parallel* gradient reduction only.

compress -> (all-reduce int8 payload) -> decompress.  Under GSPMD the
all-reduce is implicit; we expose the quantize/dequantize pair + the error
state so train_step can wrap its gradients.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress", "decompress", "init_error_state", "apply_error_feedback"]


def compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-wise symmetric int8 quantization. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    flat = g32.reshape(g32.shape[0], -1) if g32.ndim > 1 else g32.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q.reshape(g32.shape), scale.reshape(
        (g32.shape[0],) + (1,) * (g32.ndim - 1) if g32.ndim > 1 else (1,))


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply_error_feedback(grads, err_state):
    """Returns (quantize-then-dequantize grads, new error state).

    The returned grads are what every worker sees after the int8 all-reduce;
    err accumulates the per-worker quantization residual.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = compress(g32)
        deq = decompress(q, s)
        return deq.astype(g.dtype), g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))
