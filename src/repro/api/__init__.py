"""repro.api — the survey API: registry + lazy analysis + fan-out survey.

Three layers, replacing the ad-hoc per-consumer dispatch that used to live in
``benchmarks/table1.py`` / ``examples/topology_report.py`` / ``bounds.TABLE1``:

* :mod:`repro.api.registry` — every topology family in one
  :class:`~repro.api.registry.Family` record (constructor + parameter schema +
  Table-1 closed forms), buildable from spec strings: ``build("slimfly(q=13)")``.
* :mod:`repro.api.analysis` — :class:`~repro.api.analysis.Analysis`, a lazy
  memoizing session over one topology that auto-selects the dense numpy oracle
  vs the JAX Lanczos path by ``n``.
* :mod:`repro.api.survey` — :func:`~repro.api.survey.survey`, the fan-out
  engine behind Table 1 / Fig 5 style comparisons, batching same-shape Lanczos
  solves and emitting rows/CSV/JSON.

``analysis`` and ``survey`` are loaded lazily (PEP 562) so that importing the
registry from ``repro.core.topologies`` (for the ``@register`` decorators)
never pulls the numerics stack into the constructors' import cycle.
"""
from .registry import (Family, REGISTRY, SpecError, TopologyRegistry, build,
                       closed_forms, families, get, parse_spec, register)

__all__ = [
    "Family", "REGISTRY", "SpecError", "TopologyRegistry", "build",
    "closed_forms", "families", "get", "parse_spec", "register",
    "Analysis", "survey", "SurveyResult", "DEFAULT_COLUMNS", "TABLE1_COLUMNS",
    "RAMANUJAN_COLUMNS", "FAULT_COLUMNS", "ROUTING_COLUMNS", "SIM_COLUMNS",
    "WORKLOAD_COLUMNS",
]

_LAZY = {
    "Analysis": ("repro.api.analysis", "Analysis"),
    "survey": ("repro.api.survey", "survey"),
    "SurveyResult": ("repro.api.survey", "SurveyResult"),
    "COLUMNS": ("repro.api.survey", "COLUMNS"),
    "DEFAULT_COLUMNS": ("repro.api.survey", "DEFAULT_COLUMNS"),
    "TABLE1_COLUMNS": ("repro.api.survey", "TABLE1_COLUMNS"),
    "RAMANUJAN_COLUMNS": ("repro.api.survey", "RAMANUJAN_COLUMNS"),
    "FAULT_COLUMNS": ("repro.api.survey", "FAULT_COLUMNS"),
    "ROUTING_COLUMNS": ("repro.api.survey", "ROUTING_COLUMNS"),
    "SIM_COLUMNS": ("repro.api.survey", "SIM_COLUMNS"),
    "WORKLOAD_COLUMNS": ("repro.api.survey", "WORKLOAD_COLUMNS"),
}


def __getattr__(name):
    try:
        modname, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(modname)
    # pin every lazy name this module provides: importing the `survey`
    # SUBMODULE sets a package attribute of the same name, which would
    # otherwise shadow the survey() function on any later lookup
    for lazy_name, (lazy_mod, lazy_attr) in _LAZY.items():
        if lazy_mod == modname:
            globals()[lazy_name] = getattr(mod, lazy_attr)
    return globals()[name]


def __dir__():
    return sorted(set(__all__) | set(globals()))
