"""The survey fan-out: one engine behind Table 1, Fig 5, and the CLI reports.

``survey(specs, columns=...)`` builds every requested topology through the
registry, wraps each in a lazy :class:`~repro.api.analysis.Analysis`, batches
same-shape Lanczos solves into a single vmapped call, and emits rows / CSV /
JSON.  Consumers (``benchmarks/table1.py``, ``benchmarks/lps_bench.py``,
``examples/topology_report.py``) pick a column set and write the result —
no per-topology constructor dispatch anywhere.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import pathlib
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro import obs
from repro.core import spectral as S
from repro.core.graphs import Topology

from .analysis import Analysis
from .registry import REGISTRY

__all__ = ["survey", "SurveyResult", "COLUMNS", "DEFAULT_COLUMNS",
           "TABLE1_COLUMNS", "RAMANUJAN_COLUMNS", "FAULT_COLUMNS",
           "ROUTING_COLUMNS", "SIM_COLUMNS", "WORKLOAD_COLUMNS"]


def _round(x: float, nd: int = 6) -> float:
    return round(float(x), nd)


def csv_field(v) -> str:
    """One CSV cell, quoted/escaped when needed (shared by every CSV writer)."""
    s = "" if v is None else str(v)
    if any(ch in s for ch in ',"\n'):
        s = '"' + s.replace('"', '""') + '"'
    return s


def _forms_value(a: Analysis, key: str) -> Any:
    cf = a.closed_forms
    return _round(cf[key]) if cf and key in cf else None


#: column name -> Analysis -> value.  Scripts may register more.
COLUMNS: Dict[str, Callable[[Analysis], Any]] = {
    "topology": lambda a: a.family or a.name,
    "instance": lambda a: a.name,
    "spec": lambda a: a.spec or a.name,
    "nodes": lambda a: a.n,
    "radix": lambda a: None if a.radix is None else int(a.radix)
        if float(a.radix).is_integer() else a.radix,
    "backend": lambda a: a.backend,
    "bipartite": lambda a: bool(a.topo.meta.get("bipartite")),
    "rho2": lambda a: _round(a.rho2),
    "rho2_ub_paper": lambda a: _forms_value(a, "rho2_ub"),
    "rho2_lb_paper": lambda a: _forms_value(a, "rho2_lb"),
    "rho2_ok": lambda a: _closed_form_ok(a),
    "lambda": lambda a: _round(a.lambda_nontrivial),
    "ramanujan_bound": lambda a: _round(a.ramanujan["lambda_bound"]),
    "is_ramanujan": lambda a: a.ramanujan["is_ramanujan"],
    "diameter": lambda a: a.diameter,
    "alon_milman_diam_ub": lambda a: a.bounds["alon_milman_diameter_ub"],
    "bw_witness": lambda a: a.bisection_witness,
    "bw_fiedler_lb": lambda a: _round(a.bounds["fiedler_bw_lb"], 2),
    "bw_ub_paper": lambda a: _forms_value(a, "bw_ub"),
    "bw_m_half_ub": lambda a: a.bounds["first_moment_bw_ub"],
    "ramanujan_rho2": lambda a: _round(a.ramanujan["rho2_optimum"]),
    "rho2_gap_ratio": lambda a: _round(a.ramanujan["rho2_ratio"], 4),
}

DEFAULT_COLUMNS = [
    "topology", "spec", "nodes", "radix", "backend", "rho2", "rho2_ub_paper",
    "rho2_ok", "bw_fiedler_lb", "bw_witness", "bw_ub_paper",
    "ramanujan_rho2", "rho2_gap_ratio",
]

#: the exact schema of benchmarks/out/table1.csv
TABLE1_COLUMNS = [
    "topology", "instance", "nodes", "radix", "rho2", "rho2_ub_paper",
    "rho2_ok", "bw_fiedler_lb", "bw_witness", "bw_ub_paper",
    "ramanujan_rho2", "rho2_gap_ratio", "seconds",
]

#: the LPS certification schema (benchmarks/lps_bench.py)
RAMANUJAN_COLUMNS = [
    "topology", "spec", "nodes", "radix", "bipartite", "backend", "lambda",
    "ramanujan_bound", "is_ramanujan", "diameter", "alon_milman_diam_ub",
    "seconds",
]

#: resilience columns appended automatically when ``survey(faults=...)``
FAULT_COLUMNS = [
    "fault_model", "fault_rate", "rho2_degraded", "rho2_retention",
    "connectivity_prob", "bw_fiedler_lb_degraded",
]

#: measured path-structure columns appended when ``survey(routing=...)``:
#: exact BFS diameter (hops) + agreement with the registered closed form,
#: the certified diameter lower bound (= diameter when exact; the sampled
#: estimator's guarantee otherwise), average shortest-path length (hops) with
#: its 95% bootstrap CI (degenerate when exact), mean minimal-path count per
#: pair, max directed link load (injection units) and saturation throughput
#: under the configured traffic pattern, and the spectral throughput
#: prediction.  ``routing={"schemes": True}`` additionally fills the
#: routing-scheme comparison: saturation throughput under Valiant load
#: balancing (``thpt_valiant``), UGAL-style adaptive selection
#: (``thpt_ugal``) and k-shortest-path non-minimal ECMP (``thpt_ksp``),
#: the multi-commodity-flow optimal-routing ceiling (``thpt_mcf_ub``, None
#: when scipy is unavailable), and ``thpt_gap_to_opt`` — the best measured
#: scheme as a fraction of that ceiling (1.0 = routing achieves the
#: topology's optimum; the residual gap is the routing loss, separating it
#: from the spectral/topological limit).
ROUTING_COLUMNS = [
    "diameter_bfs", "diameter_lb", "diameter_ok", "avg_hops", "avg_hops_ci",
    "path_diversity", "traffic_pattern", "max_link_load",
    "saturation_throughput", "throughput_spectral", "thpt_valiant",
    "thpt_ugal", "thpt_ksp", "thpt_mcf_ub", "thpt_gap_to_opt",
]

#: executed-schedule columns appended when ``survey(simulate=...)``: the
#: simulated collective/algorithm and round count, measured completion time
#: vs the NetworkModel analytic lower bound (ms; ``sim_model_ratio`` =
#: measured/predicted, ``sim_geq_model`` asserts the bound held), peak link
#: utilization (busy fraction), and the *executed* uniform-workload
#: saturation throughput (injection units — comparable to the static
#: ``saturation_throughput`` of :data:`ROUTING_COLUMNS`).
SIM_COLUMNS = [
    "sim_collective", "sim_algorithm", "sim_rounds", "sim_time_ms",
    "model_time_ms", "sim_model_ratio", "sim_geq_model", "sim_util_max",
    "sim_thpt_uniform",
]

#: executed training-workload columns appended when ``survey(workload=...)``:
#: the canonical workload spec, total simulated step time and its compute
#: term (ms), per-phase-family link time (``comm_dp_ms`` gradient
#: all-reduce, ``comm_tp_ms`` tensor-parallel all-gather/reduce-scatter,
#: ``comm_moe_ms`` expert all-to-all, ``comm_total_ms`` their sum),
#: the exposed-communication fraction of the step ((step - compute)/step,
#: after DP/backward overlap), and the fraction of plan demand dropped
#: between disconnected node pairs.  ``rho2`` rides along so one row pairs
#: the spectral prediction with the executed step time (rank-correlate
#: across rows with :func:`repro.core.workloads.spectral_rank_correlation`).
WORKLOAD_COLUMNS = [
    "workload", "rho2", "step_time_ms", "compute_ms", "comm_dp_ms",
    "comm_tp_ms", "comm_moe_ms", "comm_total_ms", "comm_exposed_frac",
    "workload_dropped_frac",
]


def _closed_form_ok(a: Analysis, tol: float = 1e-6) -> Optional[bool]:
    """Measured rho2 against the registered closed form (None if no form)."""
    cf = a.closed_forms
    if not cf or not ({"rho2_ub", "rho2_lb"} & set(cf)):
        return None
    ok = True
    if "rho2_ub" in cf:
        if cf.get("rho2_exact"):
            ok &= abs(a.rho2 - cf["rho2_ub"]) <= tol * max(1.0, cf["rho2_ub"])
        else:
            ok &= a.rho2 <= cf["rho2_ub"] + tol
    if "rho2_lb" in cf:
        ok &= a.rho2 >= cf["rho2_lb"] - tol
    return bool(ok)


@dataclasses.dataclass
class SurveyResult:
    """Rows + column order, with CSV/JSON emitters.

    ``rows`` hold one dict per surveyed instance (values in the units each
    column documents: eigenvalues dimensionless, diameters/hops in hops,
    loads in injection units, ``seconds`` wall time); ``columns`` fixes the
    emission order.
    """
    rows: List[Dict[str, Any]]
    columns: List[str]

    def __iter__(self):
        return iter(self.rows)

    def __len__(self) -> int:
        return len(self.rows)

    def to_csv(self, path: Optional[str] = None) -> str:
        """Render rows as CSV in column order (quoting comma-bearing cells).

        Args: ``path`` — optional file to write (parents created).
        Returns the CSV text either way.
        """
        text = "\n".join(
            [",".join(self.columns)]
            + [",".join(csv_field(r.get(c)) for c in self.columns)
               for r in self.rows])
        if path is not None:
            p = pathlib.Path(path)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(text)
        return text

    def to_json(self, path: Optional[str] = None) -> str:
        """Render rows as a JSON array (numpy scalars/arrays coerced).

        Args: ``path`` — optional file to write (parents created).
        Returns the JSON text either way.
        """
        text = json.dumps(self.rows, indent=2, default=_json_default)
        if path is not None:
            p = pathlib.Path(path)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(text)
        return text


def _json_default(x):
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, np.ndarray):
        return x.tolist()
    raise TypeError(f"not JSON-serializable: {type(x)}")


def _as_analysis(spec: Union[str, Topology, Analysis], **kwargs) -> Analysis:
    if isinstance(spec, Analysis):
        return spec
    if isinstance(spec, Topology):
        return Analysis(spec, **kwargs)
    return Analysis(REGISTRY.build(spec), **kwargs)


def _batch_lanczos_rho2(analyses: Sequence[Analysis]) -> Dict[int, float]:
    """Solve same-shape Lanczos-backend instances in one vmapped call each.

    Groups by (n, gather-table width, iters, seed); groups of >= 2 regular,
    non-bipartite graphs share a single ``rho2_lanczos_batched`` solve whose
    results pre-populate each Analysis's rho2 cache.  Everything else falls
    back to the per-instance path on first access.  Returns each batched
    analysis's share of its group's solve time (id(a) -> seconds) so row
    timings stay honest.
    """
    groups: Dict[tuple, List[Analysis]] = {}
    for a in analyses:
        if a.backend != "lanczos" or "rho2" in a.__dict__:
            continue
        # the batched solve uses the plain jnp gather matvec; kernel-routed
        # analyses must solve per-instance or the flag never exercises the
        # kernel on grouped (same-shape) surveys
        if a.use_pallas_kernel:
            continue
        if a.topo.meta.get("bipartite") or a.radix is None:
            continue
        deg = np.bincount(a.topo.edges.reshape(-1), minlength=a.n)
        key = (a.n, int(deg.max()), a.lanczos_iters, a.seed)
        groups.setdefault(key, []).append(a)
    shares: Dict[int, float] = {}
    for (n, width, iters, seed), grp in groups.items():
        if len(grp) < 2:
            continue
        obs.count("survey/lanczos_groups")
        obs.count("survey/lanczos_grouped_instances", len(grp))
        t0 = time.time()
        vals = S.rho2_lanczos_batched([a.topo for a in grp], iters=iters,
                                      seed=seed)
        share = (time.time() - t0) / len(grp)
        for a, v in zip(grp, vals):
            a.__dict__["rho2"] = v      # pre-populate the cached_property
            shares[id(a)] = share
    return shares


def _fault_config(faults: Union[float, Dict[str, Any]]) -> Dict[str, Any]:
    cfg = dict(rate=float(faults)) if isinstance(faults, (int, float)) \
        else dict(faults)
    cfg.setdefault("rate", 0.05)
    cfg.setdefault("model", "link")
    cfg.setdefault("samples", 16)
    return cfg


def _fault_values(a: Analysis, cfg: Dict[str, Any]) -> Dict[str, Any]:
    """One-rate fault sweep for a survey row → the FAULT_COLUMNS values."""
    sweep = a.fault_sweep(rates=[cfg["rate"]], model=cfg["model"],
                          samples=cfg["samples"], seed=cfg.get("seed"))
    r = sweep.rows[0]
    return dict(
        fault_model=cfg["model"],
        fault_rate=cfg["rate"],
        rho2_degraded=_round(r["rho2_mean"]),
        rho2_retention=None if r["rho2_retention"] is None
            else _round(r["rho2_retention"], 4),
        connectivity_prob=r["connectivity_prob"],
        bw_fiedler_lb_degraded=_round(r["bw_fiedler_lb_mean"], 2),
    )


def _routing_config(routing: Union[bool, Dict[str, Any]]) -> Dict[str, Any]:
    cfg = {} if routing is True else dict(routing)
    cfg.setdefault("pattern", "uniform")
    cfg.setdefault("sample_fraction", None)   # None = exact all-sources BFS
    cfg.setdefault("seed", None)              # None = the session's seed
    cfg.setdefault("schemes", False)          # fill the thpt_* comparison
    cfg.setdefault("slack", 1)                # ksp detour budget
    cfg.setdefault("groups", None)            # MCF commodity grouping
    return cfg


def _sim_config(simulate: Union[bool, Dict[str, Any]]) -> Dict[str, Any]:
    cfg = {} if simulate is True else dict(simulate)
    cfg.setdefault("collective", "all_reduce")
    cfg.setdefault("algorithm", None)
    cfg.setdefault("payload", float(1 << 26))
    cfg.setdefault("pattern", "uniform")   # None skips the workload column
    if cfg["collective"] == "traffic":
        # the measured-vs-model columns need a collective the analytic model
        # predicts; the executed workload already has its own column
        raise ValueError(
            "survey(simulate=...): collective='traffic' has no analytic "
            "prediction to validate against — pick a collective (e.g. "
            "'all_reduce') and choose the workload via pattern=")
    return cfg


def _sim_values(a: Analysis, cfg: Dict[str, Any]) -> Dict[str, Any]:
    """Executed-schedule quantities for one survey row (SIM_COLUMNS)."""
    sim = a.simulate(cfg["collective"], cfg["algorithm"],
                     payload=cfg["payload"])
    val = a.network_model().validate(sim)
    thpt = None
    if cfg["pattern"]:
        thpt = a.simulate("traffic", pattern=cfg["pattern"],
                          payload=cfg["payload"]).saturation_throughput
    # the largest payload: the same one sim_util_max is accounted at
    row = val["rows"][int(np.argmax(sim.payload_bytes))]
    return dict(
        sim_collective=cfg["collective"],
        sim_algorithm=sim.algorithm,
        sim_rounds=sim.rounds,
        sim_time_ms=_round(row["measured_s"] * 1e3),
        model_time_ms=_round(row["predicted_s"] * 1e3),
        sim_model_ratio=_round(row["ratio"], 4),
        sim_geq_model=val["all_measured_geq_predicted"],
        sim_util_max=_round(sim.utilization_max, 4),
        sim_thpt_uniform=None if thpt is None else _round(thpt, 4),
    )


def _routing_values(a: Analysis, cfg: Dict[str, Any]) -> Dict[str, Any]:
    """Measured routing/traffic quantities for one survey row (ROUTING_COLUMNS)."""
    from repro.core.traffic import spectral_throughput_estimate

    r = a.routing(sample_fraction=cfg["sample_fraction"], seed=cfg["seed"])
    t = a.traffic(cfg["pattern"], sample_fraction=cfg["sample_fraction"],
                  seed=cfg["seed"])
    cf = a.closed_forms
    # exact runs assert equality with the closed form; a sampled run can only
    # certify that its lower bound does not exceed it
    diameter_ok = None if not cf or "diameter" not in cf \
        else bool(r.diameter == int(cf["diameter"])) if r.exact \
        else bool(r.diameter_lb <= int(cf["diameter"]))
    schemes: Dict[str, Optional[float]] = dict(
        thpt_valiant=None, thpt_ugal=None, thpt_ksp=None, thpt_mcf_ub=None,
        thpt_gap_to_opt=None)
    if cfg["schemes"]:
        measured = {"minimal": t.saturation_throughput}
        for scheme in ("valiant", "ugal", "ksp"):
            measured[scheme] = a.traffic(
                cfg["pattern"], scheme=scheme, slack=cfg["slack"],
                sample_fraction=cfg["sample_fraction"],
                seed=cfg["seed"]).saturation_throughput
        schemes.update(thpt_valiant=_round(measured["valiant"], 4),
                       thpt_ugal=_round(measured["ugal"], 4),
                       thpt_ksp=_round(measured["ksp"], 4))
        try:
            ub = a.mcf_throughput_ub(cfg["pattern"], groups=cfg["groups"])
        except RuntimeError:     # scipy not installed: no ceiling, no gap
            ub = None
        if ub is not None and np.isfinite(ub) and ub > 0:
            best = max(v for v in measured.values() if np.isfinite(v))
            schemes.update(thpt_mcf_ub=_round(ub, 4),
                           thpt_gap_to_opt=_round(best / ub, 4))
    return dict(
        diameter_bfs=r.diameter,
        diameter_lb=r.diameter_lb,
        diameter_ok=diameter_ok,
        avg_hops=_round(r.avg_path_length, 4),
        avg_hops_ci=[_round(c, 4) for c in r.avg_hops_ci],
        path_diversity=_round(r.path_diversity_mean, 4),
        traffic_pattern=t.pattern,
        max_link_load=_round(t.max_link_load, 4),
        saturation_throughput=_round(t.saturation_throughput, 4),
        throughput_spectral=_round(
            spectral_throughput_estimate(a.n, a.rho2), 4),
        **schemes,
    )


def _workload_config(workload: Any) -> Dict[str, Any]:
    cfg = dict(workload) if isinstance(workload, dict) else \
        dict(spec=workload)
    if "spec" not in cfg:
        raise KeyError("survey(workload=...) config dict needs a 'spec' key")
    cfg.setdefault("placement", "linear")
    cfg.setdefault("seed", 0)
    return cfg


def _workload_values(a: Analysis, cfg: Dict[str, Any]) -> Dict[str, Any]:
    """Executed training-step quantities for one survey row
    (WORKLOAD_COLUMNS; ``rho2`` is filled by the generic column)."""
    res = a.simulate(workload=cfg["spec"], placement=cfg["placement"])
    return dict(
        workload=res.plan.spec.spec,
        step_time_ms=_round(res.step_seconds * 1e3),
        compute_ms=_round(res.compute_seconds * 1e3),
        comm_dp_ms=_round(res.dp_seconds * 1e3),
        comm_tp_ms=_round(res.tp_seconds * 1e3),
        comm_moe_ms=_round(res.moe_seconds * 1e3),
        comm_total_ms=_round(res.comm_seconds * 1e3),
        comm_exposed_frac=_round(res.exposed_comm_fraction, 4),
        workload_dropped_frac=_round(res.dropped_frac, 4),
    )


def survey(specs: Sequence[Union[str, Topology, Analysis]],
           columns: Optional[Sequence[str]] = None, *,
           dense_threshold: int = S.DENSE_THRESHOLD,
           lanczos_iters: int = 200, seed: int = 0,
           batch_lanczos: bool = True,
           use_pallas_kernel: bool = False,
           faults: Optional[Union[float, Dict[str, Any]]] = None,
           routing: Optional[Union[bool, Dict[str, Any]]] = None,
           simulate: Optional[Union[bool, Dict[str, Any]]] = None,
           workload: Optional[Any] = None,
           trace: Union[bool, str, pathlib.Path, None] = None
           ) -> SurveyResult:
    """Uniform spectral survey over many topologies (the paper's Table 1).

    ``specs``: spec strings (``"slimfly(q=13)"``), Topology instances, or
    pre-built Analysis sessions.  ``columns``: names from :data:`COLUMNS`
    (plus ``"seconds"``, filled with per-row wall time); defaults to
    :data:`DEFAULT_COLUMNS`.  Instances with ``n > dense_threshold`` route
    through the JAX Lanczos path automatically; same-shape groups share one
    batched solve.

    ``faults``: a fault rate (``faults=0.05``) or config dict
    (``faults=dict(rate=0.1, model="attack_spectral", samples=32)``) runs a
    per-instance fault sweep at that rate and appends the resilience columns
    of :data:`FAULT_COLUMNS` to every row.

    ``routing``: ``True`` or a config dict (``routing=dict(pattern=
    "adversarial")``) runs the measured path-level analysis — batched
    all-sources BFS + minimal-path ECMP link loads under one synthetic
    traffic pattern — appending :data:`ROUTING_COLUMNS` to every row
    (diameters/hops in hops, loads in injection units).  Config keys
    ``sample_fraction`` / ``seed`` switch to the sampled-source estimator
    (``routing=dict(sample_fraction=0.01, seed=0)``): ``diameter_bfs`` is
    then the certified lower bound ``diameter_lb``, ``avg_hops_ci`` its
    bootstrap CI, and traffic loads carry the n/S correction — the
    datacenter-scale path (``sample_fraction=1.0`` reproduces exact).
    ``routing=dict(schemes=True)`` additionally evaluates the non-minimal /
    adaptive routing schemes and the MCF optimal-routing ceiling, filling
    ``thpt_valiant`` / ``thpt_ugal`` / ``thpt_ksp`` / ``thpt_mcf_ub`` /
    ``thpt_gap_to_opt`` (config keys ``slack`` and ``groups`` tune the ksp
    detour budget and MCF commodity grouping).

    ``simulate``: ``True`` or a config dict (``simulate=dict(collective=
    "all_reduce", algorithm="ring", payload=1 << 26, pattern="uniform")``)
    *executes* the collective schedule and the uniform workload on every
    instance's links, appending :data:`SIM_COLUMNS` — measured completion
    time next to the NetworkModel lower bound, peak link utilization, and
    the executed saturation throughput.

    ``workload``: a training-job spec string
    (``workload="kimi_k2_1t@dp=64,tp=8,ep=16"``, see
    :func:`repro.core.workloads.parse_workload`) or a config dict
    (``workload=dict(spec="qwen2_7b@dp=32,tp=2", placement="random")``)
    compiles the full per-step communication plan onto every instance and
    *executes* it, appending :data:`WORKLOAD_COLUMNS` — simulated step time
    and its compute / per-phase-family communication breakdown (ms) next to
    the rho2 the paper says should predict it.

    ``trace``: ``True`` records :mod:`repro.obs` spans for the whole survey
    (build / batched-solve / per-row), readable afterwards via
    ``obs.trace_events()`` / ``obs.metrics_report()``; a path writes the
    Chrome-trace-event ``trace.json`` there on exit (perfetto-loadable).
    """
    cols = list(columns if columns is not None else DEFAULT_COLUMNS)
    fault_cfg = routing_cfg = sim_cfg = workload_cfg = None
    extra = {"seconds"}
    if faults is not None:
        fault_cfg = _fault_config(faults)
        cols += [c for c in FAULT_COLUMNS if c not in cols]
        extra |= set(FAULT_COLUMNS)    # only meaningful with faults=...
    if routing not in (None, False):   # {} is a valid all-defaults config
        routing_cfg = _routing_config(routing)
        cols += [c for c in ROUTING_COLUMNS if c not in cols]
        extra |= set(ROUTING_COLUMNS)  # only meaningful with routing=...
    if simulate not in (None, False):  # {} is a valid all-defaults config
        sim_cfg = _sim_config(simulate)
        cols += [c for c in SIM_COLUMNS if c not in cols]
        extra |= set(SIM_COLUMNS)      # only meaningful with simulate=...
    if workload is not None:
        workload_cfg = _workload_config(workload)
        cols += [c for c in WORKLOAD_COLUMNS if c not in cols]
        extra |= set(WORKLOAD_COLUMNS) - set(COLUMNS)  # rho2 stays generic
    unknown = [c for c in cols if c not in extra and c not in COLUMNS]
    if unknown:
        raise KeyError(f"unknown survey column(s) {unknown}; available: "
                       f"{sorted(COLUMNS)} + {sorted(extra)}")
    with contextlib.ExitStack() as stack:
        if trace not in (None, False):
            path = None if trace is True else trace
            stack.enter_context(obs.tracing(path))
        analyses, build_secs = [], []
        with obs.span("survey/build", phase="build", specs=len(specs)):
            for s in specs:
                t0 = time.time()
                analyses.append(_as_analysis(
                    s, dense_threshold=dense_threshold,
                    lanczos_iters=lanczos_iters, seed=seed,
                    use_pallas_kernel=use_pallas_kernel))
                build_secs.append(time.time() - t0)
        solve_shares: Dict[int, float] = {}
        if batch_lanczos:
            with obs.span("survey/batched_lanczos", phase="execute"):
                solve_shares = _batch_lanczos_rho2(analyses)
        rows = []
        for a, built in zip(analyses, build_secs):
            t0 = time.time()
            with obs.span("survey/row", phase="execute", instance=a.name,
                          family=a.family or a.name):
                row = {c: COLUMNS[c](a) for c in cols
                       if c != "seconds" and c in COLUMNS}
                if fault_cfg is not None:
                    row.update(_fault_values(a, fault_cfg))
                if routing_cfg is not None:
                    row.update(_routing_values(a, routing_cfg))
                if sim_cfg is not None:
                    row.update(_sim_values(a, sim_cfg))
                if workload_cfg is not None:
                    row.update(_workload_values(a, workload_cfg))
            if "seconds" in cols:
                # construction + (amortized) batched solve + lazy evaluation,
                # so the column means what the pre-registry bench reported
                row["seconds"] = round(
                    built + solve_shares.get(id(a), 0.0) + time.time() - t0, 2)
            rows.append(row)
    return SurveyResult(rows=rows, columns=cols)
