"""Unified topology registry: one record per family, replacing ad-hoc dispatch.

Every topology family of the survey (paper §4 + the LPS Ramanujan reference of
§3) registers itself here via the :func:`register` decorator applied to its
constructor in :mod:`repro.core.topologies` / :mod:`repro.core.ramanujan`.
A :class:`Family` record carries, in one place, what used to be scattered
across three call sites:

* the constructor (formerly the ``CASES`` lambdas of ``benchmarks/table1.py``),
* the parameter schema (formerly the if/elif ``build()`` chain of
  ``examples/topology_report.py``),
* the analytic Table-1 closed forms (formerly only reachable through
  ``bounds.TABLE1`` keyed by free-floating name strings).

Spec strings
------------
``build("slimfly(q=13)")``, ``build("torus(16,2)")`` and bare names with
defaultable parameters (``build("petersen")``) work from CLIs and config
files.  Positional arguments bind in schema order; values are Python literals
(ints, floats, bools, strings).

This module deliberately imports nothing from ``repro.core`` at module scope
(only under ``TYPE_CHECKING``) so constructors can import the decorator
without a cycle; registration happens as a side effect of importing the
constructor modules, which :func:`_ensure_populated` triggers lazily.
"""
from __future__ import annotations

import ast
import dataclasses
import difflib
import warnings
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, TYPE_CHECKING)

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.graphs import Topology

__all__ = [
    "Family", "TopologyRegistry", "REGISTRY", "register", "get", "families",
    "build", "parse_spec", "closed_forms", "SpecError",
]


class SpecError(ValueError):
    """A topology spec string or parameter set that cannot be resolved."""


@dataclasses.dataclass(frozen=True)
class Family:
    """Everything the survey needs to know about one topology family."""
    name: str
    ctor: Callable[..., "Topology"]
    params: Tuple[Tuple[str, type], ...]      # ordered (name, type) schema
    defaults: Mapping[str, Any]
    closed_forms: Optional[Callable[..., Dict[str, float]]] = None
    aliases: Tuple[str, ...] = ()
    deprecated_aliases: Tuple[str, ...] = ()
    tags: frozenset = frozenset()
    variadic: bool = False                    # single param absorbs *args
    default_instance: Optional[str] = None    # canonical small spec string
    doc: str = ""

    # -- construction -----------------------------------------------------
    def bind(self, args: Sequence[Any] = (), kwargs: Optional[Mapping[str, Any]] = None
             ) -> Dict[str, Any]:
        """Resolve positional/keyword values against the schema → full kwargs."""
        kwargs = dict(kwargs or {})
        names = [p for p, _ in self.params]
        if self.variadic:
            if kwargs:
                raise SpecError(f"{self.name} takes positional values only "
                                f"(variadic '{names[0]}')")
            return {names[0]: tuple(args)}
        if len(args) > len(names):
            raise SpecError(f"{self.name} takes at most {len(names)} "
                            f"parameters {names}, got {len(args)} positional")
        bound = dict(zip(names, args))
        for k, v in kwargs.items():
            if k not in names:
                raise SpecError(f"{self.name} has no parameter '{k}' "
                                f"(schema: {names})")
            if k in bound:
                raise SpecError(f"{self.name}: parameter '{k}' given twice")
            bound[k] = v
        for k, v in self.defaults.items():
            bound.setdefault(k, v)
        missing = [n for n in names if n not in bound]
        if missing:
            raise SpecError(f"{self.name} missing required parameter(s) "
                            f"{missing} (schema: {names})")
        for (pname, ptype) in self.params:
            val = bound[pname]
            if ptype is int and isinstance(val, bool):
                raise SpecError(f"{self.name}.{pname}: expected int, got bool")
            if ptype in (int, float, str) and not isinstance(val, ptype):
                if ptype is float and isinstance(val, int):
                    bound[pname] = float(val)
                else:
                    raise SpecError(f"{self.name}.{pname}: expected "
                                    f"{ptype.__name__}, got {val!r}")
        return bound

    def build(self, *args: Any, **kwargs: Any) -> "Topology":
        """Construct an instance (schema-checked), stamping ``family``/
        ``spec``/tag metadata onto the returned Topology."""
        bound = self.bind(args, kwargs)
        if self.variadic:
            topo = self.ctor(*bound[self.params[0][0]])
        else:
            topo = self.ctor(**bound)
        topo.meta.setdefault("family", self.name)
        topo.meta.setdefault("spec", self.spec_string(bound))
        for tag in self.tags:
            topo.meta.setdefault(tag, True)
        return topo

    def forms(self, *args: Any, **kwargs: Any) -> Optional[Dict[str, float]]:
        """Analytic closed forms (nodes/radix/rho2/bw) at these parameters."""
        if self.closed_forms is None:
            return None
        bound = self.bind(args, kwargs)
        if self.variadic:
            return self.closed_forms(*bound[self.params[0][0]])
        return self.closed_forms(**bound)

    def spec_string(self, bound: Mapping[str, Any]) -> str:
        if self.variadic:
            vals = ",".join(repr(v) for v in bound[self.params[0][0]])
            return f"{self.name}({vals})"
        if not self.params:
            return self.name
        vals = ",".join(repr(bound[p]) for p, _ in self.params)
        return f"{self.name}({vals})"


class TopologyRegistry:
    """Name → :class:`Family` map with alias resolution and spec parsing."""

    def __init__(self) -> None:
        self._families: Dict[str, Family] = {}
        self._alias: Dict[str, str] = {}
        self._deprecated: Dict[str, str] = {}

    # -- registration -----------------------------------------------------
    def register(self, name: str, *, params: Optional[Mapping[str, type]] = None,
                 defaults: Optional[Mapping[str, Any]] = None,
                 closed_forms: Optional[Callable[..., Dict[str, float]]] = None,
                 aliases: Sequence[str] = (),
                 deprecated_aliases: Sequence[str] = (),
                 tags: Sequence[str] = (),
                 variadic: bool = False,
                 default_instance: Optional[str] = None) -> Callable:
        """Decorator registering a constructor as a topology family."""
        def deco(ctor: Callable[..., "Topology"]) -> Callable[..., "Topology"]:
            if name in self._families or name in self._alias:
                raise ValueError(f"duplicate topology family {name!r}")
            fam = Family(
                name=name, ctor=ctor,
                params=tuple((params or {}).items()),
                defaults=dict(defaults or {}),
                closed_forms=closed_forms,
                aliases=tuple(aliases),
                deprecated_aliases=tuple(deprecated_aliases),
                tags=frozenset(tags),
                variadic=variadic,
                default_instance=default_instance,
                doc=(ctor.__doc__ or "").strip().splitlines()[0] if ctor.__doc__ else "",
            )
            self._families[name] = fam
            for a in fam.aliases:
                self._alias[a] = name
            for a in fam.deprecated_aliases:
                self._deprecated[a] = name
            return ctor
        return deco

    # -- lookup -----------------------------------------------------------
    def get(self, name: str) -> Family:
        _ensure_populated()
        if name in self._families:
            return self._families[name]
        if name in self._alias:
            return self._families[self._alias[name]]
        if name in self._deprecated:
            target = self._deprecated[name]
            warnings.warn(f"topology family {name!r} is deprecated; use "
                          f"{target!r}", DeprecationWarning, stacklevel=3)
            return self._families[target]
        known = sorted(set(self._families) | set(self._alias) | set(self._deprecated))
        hint = difflib.get_close_matches(name, known, n=1)
        suffix = f" — did you mean {hint[0]!r}?" if hint else ""
        raise SpecError(f"unknown topology family {name!r}{suffix} "
                        f"(known: {', '.join(known)})")

    def families(self) -> List[str]:
        _ensure_populated()
        return sorted(self._families)

    def __contains__(self, name: str) -> bool:
        _ensure_populated()
        return (name in self._families or name in self._alias
                or name in self._deprecated)

    def __iter__(self):
        _ensure_populated()
        return iter(sorted(self._families.values(), key=lambda f: f.name))

    # -- spec strings -----------------------------------------------------
    def parse(self, spec: str) -> Tuple[Family, Dict[str, Any]]:
        """``"slimfly(q=13)"`` → (Family, {"q": 13}).  Bare names allowed."""
        spec = spec.strip()
        if not spec:
            raise SpecError("empty topology spec")
        if "(" not in spec:
            fam = self.get(spec)
            return fam, fam.bind()
        try:
            node = ast.parse(spec, mode="eval").body
        except SyntaxError as e:
            raise SpecError(f"unparseable topology spec {spec!r}: {e}") from e
        if not isinstance(node, ast.Call) or not isinstance(node.func, ast.Name):
            raise SpecError(f"topology spec must look like name(arg, key=val); "
                            f"got {spec!r}")
        fam = self.get(node.func.id)
        try:
            args = [ast.literal_eval(a) for a in node.args]
            kwargs = {kw.arg: ast.literal_eval(kw.value) for kw in node.keywords}
        except (ValueError, SyntaxError) as e:
            raise SpecError(f"spec arguments must be literals: {spec!r}") from e
        if None in kwargs:
            raise SpecError(f"**kwargs not allowed in spec {spec!r}")
        return fam, fam.bind(args, kwargs)

    def build(self, spec: str) -> "Topology":
        """Parse a spec string and construct the instance it names."""
        from repro import obs
        fam, bound = self.parse(spec)
        with obs.span("registry/build", phase="build", spec=spec):
            if fam.variadic:
                return fam.build(*bound[fam.params[0][0]])
            return fam.build(**bound)


#: process-wide singleton — the registration target of ``@register``.
REGISTRY = TopologyRegistry()

_populated = False


def _ensure_populated() -> None:
    """Import the constructor modules so their ``@register`` decorators run."""
    global _populated
    if _populated:
        return
    _populated = True
    import repro.core.topologies   # noqa: F401  (registration side effects)
    import repro.core.ramanujan    # noqa: F401
    import repro.core.synthesis    # noqa: F401  (designed families)


def register(name: str, **kwargs: Any) -> Callable:
    """Module-level shorthand for ``REGISTRY.register`` (the decorator)."""
    return REGISTRY.register(name, **kwargs)


def get(name: str) -> Family:
    """Look up a :class:`Family` by name or (deprecated) alias.

    Args: ``name`` — family name (``"slimfly"``), alias, or deprecated alias
    (which warns).  Returns the :class:`Family` record; raises
    :class:`SpecError` with a did-you-mean hint for unknown names.
    """
    return REGISTRY.get(name)


def families() -> List[str]:
    """Sorted canonical family names currently registered (no aliases)."""
    return REGISTRY.families()


def build(spec: str) -> "Topology":
    """Construct a topology from a spec string (or bare family name).

    Args: ``spec`` — e.g. ``"slimfly(q=13)"``, ``"torus(16,2)"`` or
    ``"petersen"``; values are Python literals, positional args bind in
    schema order.  Returns the built :class:`~repro.core.graphs.Topology`
    (with ``family``/``spec`` recorded in ``meta``); raises
    :class:`SpecError` on unknown families or malformed parameters.
    """
    return REGISTRY.build(spec)


def parse_spec(spec: str) -> Tuple[Family, Dict[str, Any]]:
    """Parse without building: ``"slimfly(q=13)"`` → (Family, bound params).

    Returns the family record plus the fully-defaulted parameter dict —
    what :func:`build` would construct with; raises :class:`SpecError` on
    malformed specs.
    """
    return REGISTRY.parse(spec)


def closed_forms(name: str, *args: Any, **kwargs: Any) -> Dict[str, float]:
    """Analytic Table-1 record for a family at given parameters.

    Raises :class:`SpecError` if the family has no registered closed forms.
    """
    fam = REGISTRY.get(name)
    forms = fam.forms(*args, **kwargs)
    if forms is None:
        raise SpecError(f"family {fam.name!r} has no registered closed forms")
    return forms
