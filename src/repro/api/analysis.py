"""Lazy, memoizing analysis session over one topology.

``Analysis(topo)`` computes-on-demand and caches every quantity the paper's
survey reports: spectrum, rho2, diameter, witnessed bisection, the analytic
bounds of :mod:`repro.core.bounds`, and the equal-radix Ramanujan/LPS
comparison.  The backend auto-selects by ``n``:

* ``n <= dense_threshold`` — dense float64 numpy oracle (full spectrum,
  exact Fiedler vector);
* larger — the matrix-free JAX Lanczos path (``rho2_lanczos``, top-Ritz
  Fiedler approximation) through the :mod:`repro.kernels.spmv` dispatcher
  (the Pallas kernel wherever it compiles, the jnp reference elsewhere;
  ``use_pallas_kernel=True`` forces the kernel path), so device-scale
  instances never pay a dense eigendecomposition.

Nothing is computed in ``__init__``; every property memoizes on first access,
so ``survey()`` can pre-populate (e.g. batched rho2 solves) without waste.
"""
from __future__ import annotations

from functools import cached_property
from typing import Any, Dict, Optional, Sequence, Union

import numpy as np

from repro.core import bounds as B
from repro.core import collectives as C
from repro.core import faults as F
from repro.core import properties as P
from repro.core import routing as R
from repro.core import simulate as SM
from repro.core import spectral as S
from repro.core import traffic as TR
from repro.core.graphs import Topology
from repro.core.ramanujan import ramanujan_bound
from repro.kernels import spmv as KS

from .registry import REGISTRY, SpecError

__all__ = ["Analysis"]


class Analysis:
    """One topology, every survey quantity, computed lazily and cached."""

    def __init__(self, topo: Union[Topology, str], *,
                 dense_threshold: int = S.DENSE_THRESHOLD,
                 lanczos_iters: int = 200, seed: int = 0,
                 use_pallas_kernel: bool = False) -> None:
        if isinstance(topo, str):
            topo = REGISTRY.build(topo)
        self.topo = topo
        self.dense_threshold = int(dense_threshold)
        self.lanczos_iters = int(lanczos_iters)
        self.seed = int(seed)
        self.use_pallas_kernel = bool(use_pallas_kernel)

    # -- identity ----------------------------------------------------------
    @property
    def n(self) -> int:
        """Number of vertices (routers/chips)."""
        return self.topo.n

    @property
    def name(self) -> str:
        """Instance name, e.g. ``slimfly(13)``."""
        return self.topo.name

    @property
    def family(self) -> Optional[str]:
        """Registry family name, or None for hand-built topologies."""
        return self.topo.meta.get("family")

    @property
    def spec(self) -> Optional[str]:
        """Canonical spec string, or None for hand-built topologies."""
        return self.topo.meta.get("spec")

    @property
    def backend(self) -> str:
        """'dense' or 'lanczos' — chosen once by ``n`` vs the threshold."""
        return "dense" if self.n <= self.dense_threshold else "lanczos"

    @cached_property
    def radix(self) -> Optional[float]:
        """Degree if regular, else None (bounds fall back to max degree)."""
        try:
            return float(self.topo.radix)
        except ValueError:
            return None

    @cached_property
    def max_degree(self) -> float:
        return float(self.topo.degrees().max())

    # -- spectral quantities ----------------------------------------------
    def _matvec(self):
        tab, w = self.topo.gather_operands()
        backend = KS.kernel_backend() if self.use_pallas_kernel else None
        return KS.spmv_matvec(tab, w, backend=backend)

    @cached_property
    def spectrum(self) -> np.ndarray:
        """Full adjacency spectrum (ascending) — dense backend only."""
        if self.backend != "dense":
            raise RuntimeError(
                f"{self.name}: full spectrum needs the dense oracle "
                f"(n={self.n} > dense_threshold={self.dense_threshold}); "
                "raise dense_threshold or use rho2/lambda_nontrivial, which "
                "route through Lanczos")
        return S.adjacency_spectrum(self.topo)

    @cached_property
    def rho2(self) -> float:
        """Algebraic connectivity rho_2 (second-smallest Laplacian eigenvalue)."""
        if self.backend == "dense":
            return float(S.laplacian_spectrum(self.topo)[1])
        return S.rho2_lanczos(self.topo, iters=self.lanczos_iters,
                              seed=self.seed, matvec=self._matvec())

    @cached_property
    def lambda2(self) -> Optional[float]:
        """Second-largest adjacency eigenvalue (k - rho2 for regular G)."""
        if self.radix is not None:
            return self.radix - self.rho2
        if self.backend == "dense":
            return float(self.spectrum[-2])
        return None

    @cached_property
    def lambda_nontrivial(self) -> float:
        """lambda(G): largest |eigenvalue| excluding the trivial ±k pair."""
        if self.backend == "dense":
            return S.lambda_nontrivial(self.topo)
        lmax, lmin = S.lanczos_extremes(
            self._matvec(), self.n, m=self.lanczos_iters, seed=self.seed,
            deflate_vectors=S.trivial_deflation(self.topo))
        return float(max(abs(lmax), abs(lmin)))

    @cached_property
    def spectral_gap(self) -> float:
        """k - lambda_2 (= rho2 for regular G); dense general fallback."""
        if self.radix is not None:
            return self.rho2
        return S.spectral_gap(self.topo)

    # -- combinatorial quantities -----------------------------------------
    @cached_property
    def diameter(self) -> int:
        return P.diameter(
            self.topo,
            vertex_transitive=bool(self.topo.meta.get("vertex_transitive")))

    @cached_property
    def fiedler(self) -> np.ndarray:
        """Canonical Fiedler vector: deterministic across eigensolver paths.

        Routed through :func:`repro.core.spectral.canonical_fiedler`, so on
        degenerate Fiedler eigenspaces (butterfly, torus, ...) every backend
        — dense eigh, Lanczos, any BLAS build — yields the *same* vector at
        dense-tractable sizes, keeping tie-sensitive consumers (the
        adversarial traffic pattern) backend-invariant.
        """
        if self.backend == "dense":
            return S.canonical_fiedler(self.topo)
        vec = S.fiedler_lanczos(self.topo, iters=self.lanczos_iters,
                                seed=self.seed)
        return S.canonical_fiedler(self.topo, vec)

    @cached_property
    def bisection_mask(self) -> np.ndarray:
        order = np.argsort(self.fiedler, kind="stable")
        mask = np.zeros(self.n, dtype=bool)
        mask[order[: self.n // 2]] = True
        return mask

    @cached_property
    def bisection_witness(self) -> float:
        """Edges crossing a balanced Fiedler sweep cut — a true bisection,
        hence a certified upper bound on BW(G) on both backends."""
        return P.bisection_witness(self.topo, self.bisection_mask)

    # -- analytic bounds ---------------------------------------------------
    @cached_property
    def bounds(self) -> Dict[str, float]:
        """Every closed-form bound of bounds.py evaluated at (n, deg, rho2)."""
        n, rho2 = self.n, self.rho2
        kmax = self.max_degree
        out = dict(
            fiedler_bw_lb=B.fiedler_bw_lb(n, rho2),
            cheeger_bw_ub=B.cheeger_bw_ub(n, kmax, rho2),
            first_moment_bw_ub=B.first_moment_bw_ub(self.topo.m),
            alon_milman_diameter_ub=B.alon_milman_diameter_ub(n, kmax, rho2),
            mohar_diameter_lb=B.mohar_diameter_lb(n, rho2),
            fiedler_vertex_connectivity_lb=B.fiedler_vertex_connectivity_lb(rho2),
        )
        if self.radix is not None and self.lambda2 is not None:
            out["tanner_isoperimetric_lb"] = B.tanner_isoperimetric_lb(
                self.radix, self.lambda2)
        return out

    @cached_property
    def closed_forms(self) -> Optional[Dict[str, float]]:
        """The registered analytic Table-1 record for this instance, if any."""
        if not self.spec:
            return None
        try:
            fam, bound = REGISTRY.parse(self.spec)
        except SpecError:
            return None
        if fam.variadic:
            return fam.forms(*bound[fam.params[0][0]])
        return fam.forms(**bound)

    # -- Ramanujan comparison (equal radix, §3) ----------------------------
    @cached_property
    def ramanujan(self) -> Dict[str, Any]:
        """Equal-radix comparison against the Ramanujan optimum (LPS class)."""
        if self.radix is None:
            raise RuntimeError(f"{self.name} is irregular — the equal-radix "
                               "Ramanujan comparison needs a regular graph")
        k = self.radix
        opt = B.ramanujan_rho2(k)
        lam = self.lambda_nontrivial
        bound = ramanujan_bound(int(k))
        return dict(
            radix=k,
            rho2_optimum=opt,
            rho2_ratio=self.rho2 / opt,
            bw_lb_at_optimum=B.ramanujan_bw_lb(self.n, k),
            lambda_bound=bound,
            lam=lam,
            is_ramanujan=bool(lam <= bound + 1e-6),
        )

    # -- measured path structure (routing & traffic) -----------------------
    def _routing_key(self, sample_fraction: Optional[float],
                     seed: Optional[int]):
        """Cache key of one routing configuration.  Exact analysis keys on
        nothing (it is deterministic); sampled analyses key on BOTH the
        fraction and the resolved seed so different samples never alias."""
        if sample_fraction is None:
            return ("exact",)
        return ("sampled", float(sample_fraction),
                self.seed if seed is None else int(seed))

    def routing(self, sources: Optional[Sequence[int]] = None, *,
                sample_fraction: Optional[float] = None,
                seed: Optional[int] = None) -> "R.RoutingResult":
        """Measured path structure via batched BFS (lazy, cached per config).

        Args:
            sources: explicit BFS source vertices (not cached).  ``None``
                with no ``sample_fraction`` runs all n sources → exact
                diameter, hop-count distribution, average shortest-path
                length, and per-pair minimal-path counts.
            sample_fraction: run BFS from a ``round(fraction * n)``-subset of
                sources drawn by :func:`repro.core.routing.sample_sources` —
                the datacenter-scale estimator (``diameter`` becomes a
                certified lower bound, ``avg_hops_ci`` a bootstrap CI).
                ``1.0`` reproduces the exact analysis bit-for-bit.  Cached
                per ``(sample_fraction, seed)``.
            seed: source-sampling seed; defaults to this session's seed.

        Returns:
            :class:`repro.core.routing.RoutingResult` (units: hops).
        """
        if sources is not None:
            return R.analyze_routing(self.topo, sources=sources)
        cache = self.__dict__.setdefault("_routing_cache", {})
        key = self._routing_key(sample_fraction, seed)
        if key not in cache:
            cache[key] = R.analyze_routing(
                self.topo, sample_fraction=sample_fraction,
                seed=self.seed if seed is None else int(seed))
        return cache[key]

    def traffic(self, pattern: str = "uniform", *,
                scheme: str = "minimal",
                slack: int = 1,
                sample_fraction: Optional[float] = None,
                seed: Optional[int] = None) -> "TR.TrafficResult":
        """Link-load accounting of one synthetic pattern (lazy, cached).

        Routes the named demand pattern (see
        :data:`repro.core.traffic.TRAFFIC_PATTERNS`) under the chosen
        ``scheme`` (:data:`repro.core.traffic.ROUTING_SCHEMES`: minimal
        ECMP, Valiant, UGAL, or k-shortest-path with ``slack`` extra hops),
        reusing this session's cached :meth:`routing` matrices and (for
        ``adversarial``) canonical Fiedler vector.  With
        ``sample_fraction``, only the sampled source rows are routed and the
        loads carry the n/S unbiasedness correction (see
        :func:`repro.core.traffic.evaluate_traffic`); cache entries key on
        ``(pattern, scheme, slack, sample_fraction, seed)``.

        Returns:
            :class:`repro.core.traffic.TrafficResult` — per-directed-link
            loads in injection units, max load, saturation throughput.
        """
        cache = self.__dict__.setdefault("_traffic", {})
        key = (pattern, scheme, int(slack)) + \
            self._routing_key(sample_fraction, seed)
        if key not in cache:
            fiedler = self.fiedler if pattern == "adversarial" else None
            cache[key] = TR.evaluate_traffic(
                self.topo, pattern, scheme=scheme, slack=slack,
                routing=self.routing(sample_fraction=sample_fraction,
                                     seed=seed),
                fiedler=fiedler)
        return cache[key]

    def mcf_throughput_ub(self, pattern: str = "uniform", *,
                          groups: Optional[int] = None) -> float:
        """Multi-commodity-flow LP throughput ceiling (lazy, cached).

        The grouped-commodity LP upper bound of
        :func:`repro.core.traffic.mcf_throughput_ub` for this topology and
        pattern — the optimality ceiling every measured scheme's
        ``saturation_throughput`` is compared against (``thpt_gap_to_opt``
        in the survey).  Raises ``RuntimeError`` when scipy is unavailable.
        """
        cache = self.__dict__.setdefault("_mcf", {})
        key = (pattern, groups)
        if key not in cache:
            fiedler = self.fiedler if pattern == "adversarial" else None
            cache[key] = TR.mcf_throughput_ub(
                self.topo, pattern, fiedler=fiedler, groups=groups)
        return cache[key]

    # -- executed schedules (link-level simulation) ------------------------
    def network_model(self) -> "C.NetworkModel":
        """The analytic (alpha, beta) collective model of this topology
        (lazy, cached), built from this session's measured rho2 and routing
        analysis — so its ``validate`` hook ratios the *same* spectral
        figures :meth:`simulate` executes against.

        Returns:
            :class:`repro.core.collectives.NetworkModel` with the guaranteed
            Fiedler bisection, measured diameter, and measured avg hops.
        """
        if "_network" not in self.__dict__:
            self.__dict__["_network"] = C.network_from_topology(
                self.topo, rho2=self.rho2, routing=self.routing())
        return self.__dict__["_network"]

    def simulate(self, collective: str = "all_reduce",
                 algorithm: Optional[str] = None, *,
                 payload: Union[float, Sequence[float]] = float(1 << 26),
                 pattern: Optional[str] = None,
                 workload: Optional[Any] = None,
                 placement: str = "linear",
                 link_bw: float = C.LINK_BW,
                 hop_latency: float = C.PER_HOP_LATENCY,
                 root: int = 0,
                 scheme: str = "minimal",
                 slack: int = 1,
                 telemetry: bool = False) -> Any:
        """Execute a collective algorithm or traffic workload on the links
        (lazy, cached per configuration).

        Lowers the named schedule (:data:`repro.core.simulate.SIM_ALGORITHMS`)
        onto this topology's gather-table slots — reusing this session's
        cached :meth:`routing` matrices for the ECMP lowering — and runs the
        jitted round engine, vmapped over all requested payload sizes.

        Args:
            collective: ``all_reduce`` / ``reduce_scatter`` / ``all_gather``
                / ``broadcast``, or ``"traffic"`` to execute a demand-matrix
                workload instead.
            algorithm: schedule algorithm (default: the collective's first
                :data:`~repro.core.simulate.SIM_ALGORITHMS` entry).
            payload: bytes per node — scalar or sequence (one vmapped engine
                call sweeps all sizes).
            pattern: traffic pattern for ``collective="traffic"`` (default
                ``uniform``; ``adversarial`` reuses the cached Fiedler
                vector).
            workload: training-job spec string
                (``"kimi_k2_1t@dp=64,tp=8,ep=16"``), parsed
                :class:`~repro.core.workloads.WorkloadSpec`, or prebuilt
                :class:`~repro.core.workloads.CommPlan`.  When given, the
                full per-step communication plan is compiled onto this
                topology (``collective``/``algorithm``/``payload``/``root``
                do not apply) and a
                :class:`~repro.core.workloads.WorkloadResult` is returned.
            placement: logical-rank → physical-node strategy for
                ``workload=`` (see
                :func:`repro.core.placement.place_ranks`).
            link_bw / hop_latency: engine constants (defaults match
                :class:`~repro.core.collectives.NetworkModel`, so
                ``network_model().validate(...)`` is apples-to-apples).
            root: broadcast root vertex.
            scheme: routing scheme for the link lowering — ``minimal``
                (ECMP, default), ``valiant``, ``ugal`` or ``ksp`` (see
                :data:`repro.core.traffic.ROUTING_SCHEMES`).  Applies to
                traffic workloads and demand-lowered collectives;
                ``workload=`` runs always use minimal ECMP.
            slack: extra hops beyond shortest for ``scheme="ksp"``.
            telemetry: attach per-round engine telemetry
                (:class:`repro.core.simulate.RoundTelemetry` — round times,
                per-round max/mean link loads and utilizations, argmax
                contended link) as ``result.telemetry``.  Does not apply to
                ``workload=`` runs.

        Returns:
            :class:`repro.core.simulate.SimulationResult` — measured times
            (seconds), per-link utilization, congestion accounting — or a
            :class:`repro.core.workloads.WorkloadResult` when ``workload=``
            is given.
        """
        cache = self.__dict__.setdefault("_simulate", {})
        if workload is not None:
            from repro.core import workloads as W

            plan = workload if isinstance(workload, W.CommPlan) else \
                W.plan_workload(workload)
            key = ("workload", plan.spec.spec, placement, link_bw,
                   hop_latency)
            if key not in cache:
                cache[key] = W.simulate_workload(
                    self.topo, plan, placement=placement,
                    routing=self.routing(), link_bw=link_bw,
                    hop_latency=hop_latency)
            return cache[key]
        pay = tuple(np.atleast_1d(np.asarray(payload, dtype=np.float64)))
        # resolve defaults BEFORE keying so simulate("all_reduce") and
        # simulate("all_reduce", "ring") share one cache entry
        if collective == "traffic":
            if algorithm not in (None, "ecmp"):
                raise ValueError("traffic workloads always route via ECMP; "
                                 f"algorithm={algorithm!r} does not apply")
            pattern = pattern or "uniform"
            algorithm = "ecmp"
        else:
            if pattern is not None:
                raise ValueError("pattern= only applies to "
                                 "collective='traffic'")
            if collective not in SM.SIM_ALGORITHMS:
                raise ValueError(f"unknown collective {collective!r} (known: "
                                 f"{sorted(SM.SIM_ALGORITHMS)} + 'traffic')")
            algorithm = algorithm or SM.SIM_ALGORITHMS[collective][0]
        key = (collective, algorithm, pay, pattern, link_bw, hop_latency,
               root, scheme, int(slack), bool(telemetry))
        if key not in cache:
            if collective == "traffic":
                fiedler = self.fiedler if pattern == "adversarial" else None
                cache[key] = SM.simulate_traffic(
                    self.topo, pattern, payloads=pay, link_bw=link_bw,
                    hop_latency=hop_latency, routing=self.routing(),
                    fiedler=fiedler, scheme=scheme, slack=slack,
                    telemetry=telemetry)
            else:
                cache[key] = SM.simulate_collective(
                    self.topo, collective, algorithm, payloads=pay,
                    link_bw=link_bw, hop_latency=hop_latency,
                    routing=self.routing(), root=root, scheme=scheme,
                    slack=slack, telemetry=telemetry)
        return cache[key]

    # -- degraded operation (fault tolerance, §3) --------------------------
    def fault_sweep(self, rates: Sequence[float] = (0.02, 0.05, 0.1, 0.2),
                    model: str = "link", samples: int = 32,
                    seed: Optional[int] = None,
                    iters: Optional[int] = None,
                    routing: bool = False,
                    simulate: bool = False,
                    sim_payload: float = float(1 << 26),
                    workload: Optional[Any] = None,
                    workload_samples: int = 2) -> "F.FaultSweepResult":
        """Survival curves under fault injection (rho2, bisection floor,
        connectivity vs fault rate).  Monte-Carlo models batch all ``samples``
        degraded instances per rate into ONE vmapped Laplacian Lanczos solve;
        the adversarial models (``attack_degree``, ``attack_spectral``) are
        deterministic.  Reuses this session's cached healthy rho2 and (for the
        spectral attack) Fiedler vector.  ``routing=True`` additionally runs
        batched BFS over each rate's stacked degraded tables, appending
        measured degraded diameter / path-length / reachability per rate.
        ``simulate=True`` executes a ring all-reduce of ``sim_payload`` bytes
        on every degraded sample (one vmapped engine call per rate),
        appending measured degraded collective times
        (``sim_allreduce_mean/max``, ``sim_dropped_frac_mean``).
        ``workload=`` (spec string / :class:`~repro.core.workloads.CommPlan`)
        executes the full training-step plan on the first
        ``workload_samples`` degraded samples per rate, appending
        ``workload_step_mean/max`` and ``workload_dropped_frac_mean``."""
        fiedler = self.fiedler if model == "attack_spectral" else None
        return F.fault_sweep(
            self.topo, rates=rates, model=model, samples=samples,
            seed=self.seed if seed is None else int(seed),
            iters=min(iters or self.lanczos_iters, max(self.n - 1, 8)),
            rho2_healthy=self.rho2, fiedler=fiedler, routing=routing,
            simulate=simulate, sim_payload=sim_payload,
            workload=workload, workload_samples=workload_samples)

    # -- presentation ------------------------------------------------------
    def report(self) -> str:
        """Paper-style text report (the old examples/topology_report.py body)."""
        g, bd = self.topo, self.bounds
        lines = [
            f"topology        : {g.name}",
            f"spec            : {self.spec or '(hand-built)'}",
            f"backend         : {self.backend} (n={self.n}, "
            f"dense_threshold={self.dense_threshold})",
            f"nodes / radix   : {self.n} / "
            f"{int(self.radix) if self.radix is not None else 'irregular'}",
            f"rho2 (measured) : {self.rho2:.5f}",
        ]
        cf = self.closed_forms
        if cf and "rho2_ub" in cf:
            rel = "=" if cf.get("rho2_exact") else "<="
            lines.append(f"rho2 (paper)    : {rel} {cf['rho2_ub']:.5f}")
        lines += [
            f"diameter        : {self.diameter}  "
            f"(Alon-Milman UB: {bd['alon_milman_diameter_ub']:.0f})",
            f"bisection       : witnessed {self.bisection_witness:.0f}; "
            f"Fiedler floor {bd['fiedler_bw_lb']:.0f}; "
            f"m/2 cap {bd['first_moment_bw_ub']:.0f}",
            f"fault tolerance : kappa >= rho2 = {self.rho2:.3f}",
        ]
        if self.radix is not None:
            r = self.ramanujan
            lines += [
                "--- Ramanujan comparison (equal radix) ---",
                f"rho2 optimum    : {r['rho2_optimum']:.5f} "
                f"(this graph: {100 * r['rho2_ratio']:.1f}% of optimal)",
                f"BW floor at opt : {r['bw_lb_at_optimum']:.0f} edges",
                f"Ramanujan?      : {r['is_ramanujan']} "
                f"(lambda={r['lam']:.4f}, bound={r['lambda_bound']:.4f})",
            ]
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Analysis({self.name}, n={self.n}, backend={self.backend})"
