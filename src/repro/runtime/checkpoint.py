"""Sharded, atomic checkpointing (no external deps: npz shards + json manifest).

Layout:
    <dir>/step_000123/
        manifest.json          # step, tree structure, shard map, config hash
        shard_00000.npz        # flat-index -> array chunks owned by this host
    <dir>/LATEST               # atomic pointer (rename), written LAST

Writes are crash-safe: the step directory is written under a tmp name and
renamed, then LATEST is updated by atomic rename.  Multi-host: each host
writes only the leaves it owns (here: single host writes all; the shard map
records ownership so a restart with a different host count can re-shard —
see runtime.elastic).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "list_checkpoints"]


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, state: Any,
                    keep: int = 3, host_id: int = 0, n_hosts: int = 1) -> str:
    """Write ``state`` (any pytree of arrays) atomically; returns final path."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(state)
    owned = [i for i in range(len(leaves)) if i % n_hosts == host_id]
    final = d / f"step_{step:09d}"
    tmp = d / f".tmp_step_{step:09d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    def _storable(x):
        a = np.asarray(x)
        if a.dtype.kind == "V" or not a.dtype.isnative or \
                str(a.dtype) in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            return a.astype(np.float32)   # bf16 -> f32 is lossless
        return a

    arrays = {f"leaf_{i}": _storable(leaves[i]) for i in owned}
    np.savez(tmp / f"shard_{host_id:05d}.npz", **arrays)
    manifest = dict(
        step=step,
        n_leaves=len(leaves),
        n_hosts=n_hosts,
        treedef=str(treedef),
        dtypes=[str(np.asarray(l).dtype) for l in leaves],
        shapes=[list(np.asarray(l).shape) for l in leaves],
        owner={str(i): i % n_hosts for i in range(len(leaves))},
    )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic publish of the step
    tmp_latest = d / f".LATEST_{os.getpid()}"
    tmp_latest.write_text(final.name)
    os.rename(tmp_latest, d / "LATEST")         # atomic pointer flip
    _gc(d, keep)
    return str(final)


def _gc(d: Path, keep: int):
    steps = sorted(p for p in d.iterdir() if p.name.startswith("step_"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    d = Path(directory)
    ptr = d / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (d / name / "manifest.json").exists():
        # torn write of the step dir: fall back to newest complete step
        steps = sorted(p for p in d.iterdir() if p.name.startswith("step_")
                       and (p / "manifest.json").exists())
        if not steps:
            return None
        name = steps[-1].name
    return int(name.split("_")[1])


def list_checkpoints(directory: str):
    d = Path(directory)
    if not d.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in d.iterdir()
                  if p.name.startswith("step_") and (p / "manifest.json").exists())


def restore_checkpoint(directory: str, like: Any, step: Optional[int] = None
                       ) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (arrays re-cast to like's dtypes).

    Re-sharding on restore: arrays are loaded host-side and can be re-placed
    under any mesh by the caller (device_put with new shardings) — see
    runtime.elastic.reshard.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = Path(directory) / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(f"checkpoint has {manifest['n_leaves']} leaves, "
                         f"target tree has {len(leaves)}")
    loaded: Dict[int, np.ndarray] = {}
    for shard in sorted(d.glob("shard_*.npz")):
        with np.load(shard) as z:
            for key in z.files:
                loaded[int(key.split("_")[1])] = z[key]
    new_leaves = []
    for i, ref in enumerate(leaves):
        arr = loaded[i]
        want_shape = tuple(np.asarray(ref).shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"leaf {i}: checkpoint shape {arr.shape} != "
                             f"target {want_shape}")
        new_leaves.append(arr.astype(np.asarray(ref).dtype))
    return jax.tree.unflatten(treedef, new_leaves), step
