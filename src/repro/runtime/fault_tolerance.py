"""Fault-tolerance primitives: straggler monitor, elastic re-mesh/reshard,
and the discrepancy-based degraded-operation certificate (paper §3).

Large-scale story (DESIGN.md §2): on a torus, losing nodes forces re-packing
into a contiguous sub-torus; on a Ramanujan interconnect the discrepancy
property certifies a bandwidth floor for *whatever* nodes survive, so the
scheduler can keep the job running with only a re-shard.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.placement import ramanujan_placement_guarantee

__all__ = ["StragglerMonitor", "reshard", "degraded_operation_certificate",
           "ElasticPlan"]


# --------------------------------------------------------------------------
# straggler mitigation
# --------------------------------------------------------------------------

class StragglerMonitor:
    """Tracks per-step wall time; flags stragglers by robust z-score.

    Policy hooks: ``on_straggler`` is called with (step, duration, median);
    in a multi-host deployment this triggers (a) marking the slow host for
    the next elastic re-mesh, or (b) skipping its gradient contribution for
    the step (bounded staleness).  Here it records decisions for tests.
    """

    def __init__(self, window: int = 32, threshold: float = 3.0,
                 min_samples: int = 8):
        self.window: Deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.min_samples = min_samples
        self.flagged: List[Tuple[int, float, float]] = []
        self._t0: Optional[float] = None

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int, duration: Optional[float] = None) -> bool:
        if duration is None:
            duration = time.monotonic() - (self._t0 or time.monotonic())
        is_straggler = False
        if len(self.window) >= self.min_samples:
            med = float(np.median(self.window))
            mad = float(np.median(np.abs(np.asarray(self.window) - med))) + 1e-9
            if duration > med + self.threshold * 1.4826 * mad and duration > 1.2 * med:
                is_straggler = True
                self.flagged.append((step, duration, med))
        self.window.append(duration)
        return is_straggler


# --------------------------------------------------------------------------
# elastic re-mesh
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_devices: int
    new_devices: int
    new_mesh_shape: Tuple[int, ...]
    note: str


def reshard(state: Any, new_shardings: Any) -> Any:
    """Re-place a (host-materialized or differently-sharded) pytree under new
    shardings — the restore path after an elastic re-mesh."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), state, new_shardings)


def plan_elastic_remesh(n_devices: int, lost: int, model_axis: int
                        ) -> ElasticPlan:
    """Largest (data, model) mesh on surviving devices, preserving the model
    axis (TP degree is a property of the compiled program; only DP shrinks)."""
    survive = n_devices - lost
    data = survive // model_axis
    if data < 1:
        raise ValueError("not enough devices to keep the model axis")
    return ElasticPlan(n_devices, data * model_axis, (data, model_axis),
                       note=f"dp {n_devices // model_axis}->{data}, tp kept")


def degraded_operation_certificate(n: int, radix: int, alpha: float):
    """The paper's §3 guarantee applied to the surviving alpha-fraction."""
    return ramanujan_placement_guarantee(n, radix, alpha)
