"""Training loop with checkpoint/restart, straggler monitoring, and optional
int8 gradient compression.  Single-host execution of the same step functions
the multi-pod dry-run lowers."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..configs.base import ArchConfig
from ..data.pipeline import DataConfig, synthetic_batch
from ..optim.adamw import AdamWConfig
from ..optim.compression import init_error_state
from ..train.steps import init_train_state, make_train_step
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .fault_tolerance import StragglerMonitor

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    seed: int = 0
    grad_compression: bool = False
    keep_ckpts: int = 3


class Trainer:
    def __init__(self, cfg: ArchConfig, opt_cfg: AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig,
                 mesh=None, shardings=None):
        self.cfg, self.opt_cfg, self.data_cfg, self.tcfg = cfg, opt_cfg, data_cfg, tcfg
        self.mesh = mesh
        self.monitor = StragglerMonitor()
        self.history: List[Dict[str, float]] = []
        step_fn = make_train_step(cfg, opt_cfg,
                                  grad_compression=tcfg.grad_compression)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        self.params = None
        self.opt_state = None
        self.err_state = None
        self.step = 0

    # -- state ---------------------------------------------------------------
    def init_or_restore(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        self.params, self.opt_state = init_train_state(self.cfg, self.opt_cfg, key)
        if self.tcfg.grad_compression:
            self.err_state = init_error_state(self.params)
        if self.tcfg.ckpt_dir and latest_step(self.tcfg.ckpt_dir) is not None:
            state = dict(params=self.params, opt=self.opt_state)
            state, step = restore_checkpoint(self.tcfg.ckpt_dir, state)
            self.params, self.opt_state = state["params"], state["opt"]
            self.step = step
            return step
        return 0

    # -- loop ----------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> List[Dict[str, float]]:
        assert self.params is not None, "call init_or_restore() first"
        target = self.step + (steps if steps is not None else
                              self.tcfg.total_steps - self.step)
        while self.step < target:
            batch = synthetic_batch(self.data_cfg, self.step,
                                    frontend=self.cfg.frontend,
                                    d_model=self.cfg.d_model)
            self.monitor.step_start()
            if self.tcfg.grad_compression:
                self.params, self.opt_state, self.err_state, metrics = \
                    self.step_fn(self.params, self.opt_state, batch,
                                 self.err_state)
            else:
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            straggle = self.monitor.step_end(self.step)
            metrics["straggler"] = float(straggle)
            self.step += 1
            self.history.append(dict(step=self.step, **metrics))
            if self.tcfg.ckpt_dir and self.step % self.tcfg.ckpt_every == 0:
                self.save()
        return self.history

    def save(self):
        state = dict(params=self.params, opt=self.opt_state)
        return save_checkpoint(self.tcfg.ckpt_dir, self.step, state,
                               keep=self.tcfg.keep_ckpts)
