"""The Reduction Lemma (Lemma 1): orbit quotients whose spectrum embeds in G's.

Given a partition of V(G) into orbits of a subgroup of Aut(G), the weighted,
directed, looped quotient H — H[sigma, tau] = total edge weight from any vertex
of sigma into tau — has spec(H) ⊆ spec(G).  We *verify* the orbit property
numerically (all rows of a block must have equal sums into every block) instead
of trusting the caller, so misuse fails loudly.
"""
from __future__ import annotations

from typing import Sequence, Tuple, Union

import numpy as np

from .graphs import Topology

__all__ = ["quotient", "spectrum_subset", "orbit_quotient_spectrum"]


def quotient(topo: Union[Topology, np.ndarray], orbits: Sequence[int],
             check: bool = True, atol: float = 1e-9) -> np.ndarray:
    """Quotient adjacency matrix H (generally non-symmetric).

    orbits: length-n array of orbit ids (0..r-1).
    """
    A = topo.adjacency() if isinstance(topo, Topology) else np.asarray(topo, dtype=np.float64)
    orbits = np.asarray(orbits)
    n = A.shape[0]
    ids = np.unique(orbits)
    r = len(ids)
    remap = {int(o): i for i, o in enumerate(ids)}
    lab = np.array([remap[int(o)] for o in orbits])
    # row sums of A into each orbit, per vertex: (n, r)
    M = np.zeros((n, r))
    for t in range(r):
        M[:, t] = A[:, lab == t].sum(axis=1)
    H = np.zeros((r, r))
    for s in range(r):
        rows = M[lab == s]
        if check and not np.allclose(rows, rows[0], atol=atol):
            raise ValueError(f"partition is not an automorphism-orbit partition "
                             f"(block {s} has unequal row sums)")
        H[s] = rows[0]
    return H


def spectrum_subset(spec_h: np.ndarray, spec_g: np.ndarray,
                    atol: float = 1e-6) -> bool:
    """Every eigenvalue of H appears in spec(G) (as sets, per the lemma)."""
    sg = np.sort(np.real(spec_g))
    for lam in np.real(spec_h):
        i = np.searchsorted(sg, lam)
        near = []
        if i < len(sg):
            near.append(abs(sg[i] - lam))
        if i > 0:
            near.append(abs(sg[i - 1] - lam))
        if min(near) > atol:
            return False
    return True


def orbit_quotient_spectrum(topo: Topology, orbits: Sequence[int]) -> np.ndarray:
    """Eigenvalues of the quotient (may be complex for non-normal H; the lemma
    guarantees they are real since they live in spec(G))."""
    H = quotient(topo, orbits)
    return np.linalg.eigvals(H)
