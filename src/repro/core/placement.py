"""Job placement & degraded-operation guarantees from the discrepancy property.

The paper's §3 observation: on a Ramanujan topology, *any* alpha-fraction of
nodes retains bisection bandwidth >= (alpha k n/2)(alpha/2 - 2 sqrt(k-1)/k (1 -
alpha/2)) — independent of WHICH nodes.  This is the formal basis for
fault-tolerant/elastic scheduling without re-packing: after failures the
surviving node set keeps a certified bandwidth floor.

A torus offers no such guarantee: a scattered alpha-subset can have near-zero
internal bandwidth.  ``empirical_subset_bw`` measures that gap.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .bounds import active_subset_bw_lb
from .ramanujan import ramanujan_bound
from .graphs import Topology

__all__ = ["PlacementGuarantee", "ramanujan_placement_guarantee",
           "empirical_subset_bw", "min_alpha_for_positive_guarantee",
           "place_ranks"]


def place_ranks(n: int, world: int, strategy: str = "linear",
                seed: int = 0) -> np.ndarray:
    """Map ``world`` logical job ranks onto ``n`` physical nodes.

    The workload compiler (:mod:`repro.core.workloads`) uses this to pin a
    training job's rank grid to a topology; traffic between ranks that land
    on the same node is free.  Ranks are spread as evenly as possible: node
    loads differ by at most one for every strategy.

    Strategies:
      * ``"linear"`` — rank ``r`` -> node ``r * n // world``: consecutive
        ranks stay adjacent in node id, so axis-local groups (TP blocks)
        co-locate when the job oversubscribes the machine.
      * ``"round_robin"`` — rank ``r`` -> node ``r % n``: consecutive ranks
        land on distinct nodes (stripes every group across the machine).
      * ``"random"`` — the linear assignment pushed through a seeded node
        permutation: balanced but uniformly scattered, the
        placement-agnostic setting of the paper's discrepancy argument.

    Args:
        n: physical node count (>= 1).
        world: logical rank count (>= 1); may exceed ``n`` (oversubscribed)
            or be below ``n`` (idle nodes).
        strategy: one of the three names above.
        seed: RNG seed for ``"random"``.

    Returns:
        int array of shape ``(world,)``; entry ``r`` is the node of rank ``r``.
    """
    if n < 1 or world < 1:
        raise ValueError(f"need n >= 1 and world >= 1, got n={n}, "
                         f"world={world}")
    ranks = np.arange(world)
    if strategy == "linear":
        return (ranks * n) // world
    if strategy == "round_robin":
        return ranks % n
    if strategy == "random":
        perm = np.random.default_rng(seed).permutation(n)
        return perm[(ranks * n) // world]
    raise ValueError(f"unknown placement strategy {strategy!r} "
                     "(known: linear, round_robin, random)")


@dataclasses.dataclass(frozen=True)
class PlacementGuarantee:
    topology: str
    alpha: float
    nodes_active: int
    guaranteed_bisection_edges: float   # certified floor (>= 0 means usable)
    note: str = ""


def ramanujan_placement_guarantee(n: int, k: int, alpha: float) -> PlacementGuarantee:
    g = active_subset_bw_lb(alpha, n, k)
    return PlacementGuarantee(
        topology=f"ramanujan(n={n},k={k})", alpha=alpha,
        nodes_active=int(alpha * n), guaranteed_bisection_edges=max(g, 0.0),
        note="discrepancy property — holds for ANY active subset")


def min_alpha_for_positive_guarantee(k: int) -> float:
    """Smallest alpha with a positive discrepancy floor:
    alpha/2 > (2 sqrt(k-1)/k)(1 - alpha/2)  =>  alpha > 2c/(1+c), c = 2 sqrt(k-1)/k."""
    c = ramanujan_bound(k) / k
    return 2.0 * c / (1.0 + c)


def empirical_subset_bw(topo: Topology, alpha: float, trials: int = 32,
                        seed: int = 0) -> float:
    """Worst observed bisection bandwidth across random alpha-subsets,
    bisected by a random balanced split of the subset (upper bound on the
    subset's bisection; lower is worse)."""
    rng = np.random.default_rng(seed)
    worst = np.inf
    na = max(2, int(alpha * topo.n))
    u, v = topo.edges[:, 0], topo.edges[:, 1]
    for _ in range(trials):
        sub = rng.choice(topo.n, size=na, replace=False)
        half = rng.permutation(na)
        side = np.zeros(topo.n, dtype=np.int8)  # 0 = inactive
        side[sub[half[: na // 2]]] = 1
        side[sub[half[na // 2:]]] = 2
        cross = float(np.sum((side[u] == 1) & (side[v] == 2))
                      + np.sum((side[u] == 2) & (side[v] == 1)))
        worst = min(worst, cross)
    return worst
