"""Spectral solvers: dense oracles (numpy, float64) + device-scale Lanczos (JAX).

The dense path is the test oracle and handles n <= ~4096.  The Lanczos path is
the production solver: it never materializes the n x n matrix — the adjacency
operator of a regular (multi)graph is applied through the (n, k) neighbor
table, ``(A x)[i] = sum_j x[table[i, j]] + loops[i] * x[i]``, routed through
the universal spmv dispatcher (:mod:`repro.kernels.spmv`): the Pallas kernel
where it compiles, the pure-jnp reference elsewhere.

The batched solvers stream their (B, n, k) operand stacks through Lanczos in
memory-bounded batch tiles (:data:`DEFAULT_BATCH_TILE_BYTES`), so a fault
sweep or synthesis scoring pass at n ~ 10^5 never materializes B Lanczos
bases at once; tiles are placed with
:func:`repro.launch.mesh.shard_batch` so multi-device hosts split the batch.

Relations used throughout (k-regular G):  rho_2 = k * mu_2 = k - lambda_2.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import spmv as KS
from repro.launch import mesh as _mesh

from .graphs import Topology

__all__ = [
    "adjacency_spectrum", "laplacian_spectrum", "normalized_laplacian_spectrum",
    "algebraic_connectivity", "spectral_gap", "lambda_nontrivial",
    "fiedler_vector", "canonical_fiedler", "table_matvec", "lanczos_tridiag",
    "lanczos_extremes", "lanczos_top_ritz", "rho2_lanczos",
    "rho2_lanczos_batched", "rho2_laplacian_batched", "signed_extremes_batched",
    "fiedler_lanczos", "DENSE_THRESHOLD", "DEFAULT_BATCH_TILE_BYTES",
]

#: graphs at or below this order use the dense float64 oracle; larger ones go
#: through the matrix-free JAX Lanczos path.  The Analysis/survey API reads
#: this as its default auto-selection cutover.
DENSE_THRESHOLD = 4096

#: memory budget per batched-Lanczos tile: the batch axis of a (B, n, k)
#: operand stack is chunked so one tile's working set (per-sample Lanczos
#: basis (m+1, n) f32 + gather operands) stays under this many bytes.
#: Tier-1 sizes (n <= 2184, B <= 48) always fit one tile, so chunking is
#: invisible there; at n = 65536 a 24-candidate signing batch streams in
#: a few tiles instead of 7 GB at once.
DEFAULT_BATCH_TILE_BYTES = 256 << 20


def _batch_tile(B: int, n: int, k: int, m: int,
                batch_chunk: Optional[int]) -> int:
    """Samples per batched-Lanczos tile (explicit override or byte budget)."""
    if batch_chunk is not None:
        return max(1, min(int(batch_chunk), B))
    per_sample = 4 * n * (m + 2 * k + 16)   # V basis + operands + workspace
    return max(1, min(B, DEFAULT_BATCH_TILE_BYTES // max(per_sample, 1)))


# --------------------------------------------------------------------------
# dense oracles (host, float64)
# --------------------------------------------------------------------------

def adjacency_spectrum(topo: Topology) -> np.ndarray:
    return np.linalg.eigvalsh(topo.adjacency())


def laplacian_spectrum(topo: Topology) -> np.ndarray:
    return np.linalg.eigvalsh(topo.laplacian())


def normalized_laplacian_spectrum(topo: Topology) -> np.ndarray:
    return np.linalg.eigvalsh(topo.normalized_laplacian())


def algebraic_connectivity(topo: Topology, method: str = "auto",
                           iters: int = 200, seed: int = 0) -> float:
    """rho_2: second-smallest Laplacian eigenvalue."""
    if method == "dense" or (method == "auto" and topo.n <= DENSE_THRESHOLD):
        return float(laplacian_spectrum(topo)[1])
    return rho2_lanczos(topo, iters=iters, seed=seed)


def spectral_gap(topo: Topology) -> float:
    """lambda_1 - lambda_2 of the adjacency matrix."""
    s = adjacency_spectrum(topo)
    return float(s[-1] - s[-2])


def lambda_nontrivial(topo: Topology) -> float:
    """lambda(G): largest |eigenvalue| != ±k (Definition 1)."""
    k = topo.radix
    s = adjacency_spectrum(topo)
    nontriv = s[np.abs(np.abs(s) - k) > 1e-6]
    return float(np.max(np.abs(nontriv)))


def fiedler_vector(topo: Topology) -> np.ndarray:
    """Eigenvector of L for rho_2 (dense path) — the bisection sweep witness."""
    w, v = np.linalg.eigh(topo.laplacian())
    return v[:, 1]


def _sign_canonical(vec: np.ndarray, tol: float = 1e-12) -> np.ndarray:
    """Flip ``vec`` so its first entry with |value| > tol is positive."""
    nz = np.flatnonzero(np.abs(vec) > tol)
    if nz.size and vec[nz[0]] < 0:
        return -vec
    return vec


def canonical_fiedler(topo: Topology, vector: Optional[np.ndarray] = None,
                      *, tol: float = 1e-6) -> np.ndarray:
    """A *deterministic* representative of the rho_2 Laplacian eigenspace.

    Symmetric families (butterfly, torus, hypercube, ...) have degenerate
    Fiedler eigenspaces, so ``eigh``'s second column is an arbitrary rotation
    within that eigenspace — it differs across BLAS builds and across
    dense-vs-Lanczos solver paths, which made the tie-sensitive adversarial
    traffic pattern drift between backends (butterfly ``thpt_adversarial``
    moved 0.3143 -> 0.3004 purely from an eigensolver path change).

    Dense path (``n <= DENSE_THRESHOLD``): recompute the full eigensystem,
    select every eigenvector with ``|w - rho_2| <= tol * max(1, |rho_2|)``
    (excluding the constant mode), and return the normalized projection of a
    fixed deterministic probe onto that eigenspace.  The projection is
    basis-invariant, so any eigensolver producing the same eigenspace yields
    the same vector — the input ``vector`` is ignored here by design.

    Above the dense threshold an exact eigenspace is unavailable; the provided
    Lanczos ``vector`` is returned sign-canonicalized (approximate invariance:
    deterministic up to the Lanczos solver's own reproducibility).
    """
    n = topo.n
    if n > DENSE_THRESHOLD:
        if vector is None:
            raise ValueError("canonical_fiedler above DENSE_THRESHOLD needs "
                             "an explicit (Lanczos) vector")
        vec = np.asarray(vector, dtype=np.float64)
        nrm = np.linalg.norm(vec)
        if nrm > 0:
            vec = vec / nrm
        return _sign_canonical(vec)
    w, v = np.linalg.eigh(topo.laplacian())
    rho2 = w[1]
    member = np.abs(w - rho2) <= tol * max(1.0, abs(rho2))
    member[0] = False                      # never the constant mode
    basis = v[:, member]                   # (n, m) orthonormal eigenspace
    idx = np.arange(n, dtype=np.float64)
    probes = [idx / n, np.cos(idx), idx * idx / (n * n)]
    for probe in probes:
        rep = basis @ (basis.T @ probe)
        nrm = np.linalg.norm(rep)
        if nrm > tol:
            return _sign_canonical(rep / nrm)
    return _sign_canonical(v[:, 1])        # probes all orthogonal: fall back


# --------------------------------------------------------------------------
# device-scale Lanczos (JAX)
# --------------------------------------------------------------------------

def table_matvec(table: np.ndarray, loops: Optional[np.ndarray] = None,
                 backend: Optional[str] = None
                 ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Adjacency operator from an (n, k) neighbor table.

    Routed through the universal spmv dispatcher: the Pallas kernel where it
    compiles, the pure-jnp gather-sum reference elsewhere.  ``backend``
    (``"ref"`` / ``"pallas"`` / ``"pallas_interpret"``) is resolved once at
    closure creation; ``None`` follows :func:`repro.kernels.spmv.resolve_backend`.
    """
    return KS.spmv_matvec(table, loops, backend=backend)


def _lanczos_scan(op: Callable, v0: jnp.ndarray, m: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """m-step Lanczos recurrence with full (two-pass) reorthogonalization.

    Traceable building block shared by the single-graph, batched (vmap), and
    Ritz-vector entry points.  Returns (alpha[m], beta[m], V[(m+1), n]).
    """
    # trace-time: one increment per XLA (re)trace of any Lanczos entry point
    # — the observable behind the survey's no-retrace regression gate
    obs.count("jit_trace/lanczos_scan")
    n = v0.shape[0]
    v = v0.astype(jnp.float32)
    v = v / jnp.linalg.norm(v)
    V0 = jnp.zeros((m + 1, n), dtype=jnp.float32).at[0].set(v)

    def body(carry, j):
        V, v, v_prev, beta_prev = carry
        w = op(v) - beta_prev * v_prev
        alpha = jnp.dot(w, v)
        w = w - alpha * v
        mask = (jnp.arange(m + 1) <= j).astype(jnp.float32)
        for _ in range(2):  # two-pass full reorthogonalization
            coeff = (V @ w) * mask
            w = w - V.T @ coeff
        beta = jnp.linalg.norm(w)
        ok = beta > 1e-7
        v_next = jnp.where(ok, w / jnp.where(ok, beta, 1.0), jnp.zeros_like(w))
        beta = jnp.where(ok, beta, 0.0)
        V = V.at[j + 1].set(v_next)
        return (V, v_next, v, beta), (alpha, beta)

    (V, _, _, _), (alphas, betas) = jax.lax.scan(
        body, (V0, v, jnp.zeros_like(v), jnp.float32(0.0)), jnp.arange(m))
    return alphas, betas, V


@functools.partial(jax.jit, static_argnames=("matvec", "m"))
def lanczos_tridiag(matvec: Callable, v0: jnp.ndarray, m: int,
                    deflate: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """m-step Lanczos with full (two-pass) reorthogonalization.

    ``deflate``: optional (d, n) orthonormal rows projected out of the operator
    (P A P with P = I - D^T D), used to remove the trivial ±k eigenpairs.
    Returns (alpha[m], beta[m-1]) of the symmetric tridiagonal T.
    """
    alphas, betas, _ = _lanczos_with_basis(matvec, v0, m, deflate)
    return alphas, betas[:-1]


@functools.partial(jax.jit, static_argnames=("matvec", "m"))
def _lanczos_with_basis(matvec: Callable, v0: jnp.ndarray, m: int,
                        deflate: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    def project(x):
        if deflate is not None:
            x = x - deflate.T @ (deflate @ x)
        return x

    def op(x):
        return project(matvec(project(x)))

    v = project(v0.astype(jnp.float32))
    return _lanczos_scan(op, v, m)


def _tridiag_eigvals(alphas: np.ndarray, betas: np.ndarray) -> np.ndarray:
    m = len(alphas)
    T = np.zeros((m, m))
    T[np.arange(m), np.arange(m)] = np.asarray(alphas, dtype=np.float64)
    T[np.arange(m - 1), np.arange(1, m)] = np.asarray(betas, dtype=np.float64)
    T[np.arange(1, m), np.arange(m - 1)] = np.asarray(betas, dtype=np.float64)
    return np.linalg.eigvalsh(T)


def lanczos_extremes(matvec: Callable, n: int, m: int = 200, seed: int = 0,
                     deflate_vectors: Optional[Sequence[np.ndarray]] = None
                     ) -> Tuple[float, float]:
    """(lambda_max, lambda_min) of the (deflated) operator."""
    obs.count("lanczos/solves")
    obs.count("lanczos/iters", m)
    key = jax.random.PRNGKey(seed)
    v0 = jax.random.normal(key, (n,), dtype=jnp.float32)
    deflate = None
    if deflate_vectors:
        D = np.stack([d / np.linalg.norm(d) for d in deflate_vectors])
        # orthonormalize (tiny d x d Gram-Schmidt)
        Q, _ = np.linalg.qr(D.T)
        deflate = jnp.asarray(Q.T, dtype=jnp.float32)
    alphas, betas = lanczos_tridiag(matvec, v0, m, deflate)
    ev = _tridiag_eigvals(np.asarray(alphas), np.asarray(betas))
    return float(ev[-1]), float(ev[0])


def lanczos_top_ritz(matvec: Callable, n: int, m: int = 200, seed: int = 0,
                     deflate_vectors: Optional[Sequence[np.ndarray]] = None
                     ) -> Tuple[float, np.ndarray]:
    """Top eigenpair (lambda_max, Ritz vector) of the (deflated) operator.

    The Ritz vector is V^T y for the top eigenvector y of the tridiagonal T —
    the matrix-free analogue of the dense ``fiedler_vector`` when the operator
    is the ones-deflated adjacency of a regular graph.
    """
    obs.count("lanczos/solves")
    obs.count("lanczos/iters", m)
    key = jax.random.PRNGKey(seed)
    v0 = jax.random.normal(key, (n,), dtype=jnp.float32)
    deflate = None
    if deflate_vectors:
        D = np.stack([d / np.linalg.norm(d) for d in deflate_vectors])
        Q, _ = np.linalg.qr(D.T)
        deflate = jnp.asarray(Q.T, dtype=jnp.float32)
    alphas, betas, V = _lanczos_with_basis(matvec, v0, m, deflate)
    alphas = np.asarray(alphas, dtype=np.float64)
    betas = np.asarray(betas, dtype=np.float64)[:-1]
    T = np.diag(alphas) + np.diag(betas, 1) + np.diag(betas, -1)
    w, y = np.linalg.eigh(T)
    ritz = np.asarray(V)[:m].T @ y[:, -1]
    nrm = np.linalg.norm(ritz)
    if nrm > 0:
        ritz = ritz / nrm
    return float(w[-1]), ritz


@obs.traced("spectral/rho2_lanczos", phase="execute")
def rho2_lanczos(topo: Topology, iters: int = 200, seed: int = 0,
                 matvec: Optional[Callable] = None) -> float:
    """rho_2 = k - lambda_2 for regular graphs, via ones-deflated Lanczos.

    For bipartite graphs the -k eigenpair is also deflated (sign vector from
    the 2-coloring) so the reported lambda_2 is the top *nontrivial* one.
    Note: assumes lambda_2 >= 0 (true for all surveyed topologies; dense path
    covers near-complete graphs where lambda_2 < 0).

    ``matvec``: optional replacement adjacency operator obeying the same
    padded gather-table contract (e.g. the ``cayley_spmv`` Pallas kernel via
    ``kernel_matvec``); defaults to the pure-jnp :func:`table_matvec`.
    """
    k = topo.radix
    if matvec is None:
        tab, w = topo.gather_operands()  # valid for any multigraph (loops folded)
        mv = table_matvec(tab, w)
    else:
        mv = matvec
    defl = [np.ones(topo.n)]
    if topo.meta.get("bipartite"):
        defl.append(_bipartite_sign(topo))
    lmax, _ = lanczos_extremes(mv, topo.n, m=iters, seed=seed,
                               deflate_vectors=defl)
    return float(k - lmax)


def _bipartite_sign(topo: Topology) -> np.ndarray:
    import networkx as nx

    color = nx.bipartite.color(topo.to_networkx())
    return np.array([1.0 if color[i] == 0 else -1.0 for i in range(topo.n)])


def trivial_deflation(topo: Topology) -> list:
    """Deflation basis removing the trivial adjacency eigenpairs: the all-ones
    (+k) vector, plus the 2-coloring sign vector (-k) for bipartite graphs.

    Bipartiteness is detected (O(m) 2-coloring) rather than read from meta —
    even-k tori, hypercubes, etc. are bipartite without declaring it.
    """
    defl = [np.ones(topo.n)]
    if topo.meta.get("bipartite") or _is_bipartite(topo):
        defl.append(_bipartite_sign(topo))
    return defl


def _is_bipartite(topo: Topology) -> bool:
    import networkx as nx

    return bool(nx.is_bipartite(topo.to_networkx()))


@obs.traced("spectral/fiedler_lanczos", phase="execute")
def fiedler_lanczos(topo: Topology, iters: int = 200, seed: int = 0) -> np.ndarray:
    """Approximate Fiedler vector, matrix-free (device-scale graphs).

    For k-regular G the Laplacian eigenvector of rho_2 equals the adjacency
    eigenvector of lambda_2, which is the top Ritz vector of the ones-deflated
    adjacency operator.  Used by the Analysis/survey layer to witness
    bisections when n is too large for the dense eigendecomposition.
    """
    tab, w = topo.gather_operands()
    mv = table_matvec(tab, w)
    _, ritz = lanczos_top_ritz(mv, topo.n, m=iters, seed=seed,
                               deflate_vectors=[np.ones(topo.n)])
    return ritz


@functools.partial(jax.jit, static_argnames=("m", "backend"))
def _lanczos_tridiag_batched(tables: jnp.ndarray, weights: jnp.ndarray,
                             v0s: jnp.ndarray, m: int,
                             backend: Optional[str] = None
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """vmapped ones-deflated Lanczos over B same-shape neighbor tables.

    ``tables``: (B, n, k) int32, ``weights``: (B, n) float32 per-vertex loop
    weights, ``v0s``: (B, n) float32 start vectors.  Returns stacked
    (alphas (B, m), betas (B, m)).  ``backend`` is static — the resolved
    spmv route is baked into the trace.
    """
    bk = KS.resolve_backend(backend)

    def run(tab, lw, v0):
        def op(x):
            x = x - jnp.mean(x)                      # project out ones
            y = KS.spmv(x, tab, lw, backend=bk)
            return y - jnp.mean(y)

        alphas, betas, _ = _lanczos_scan(op, v0 - jnp.mean(v0), m)
        return alphas, betas

    return jax.vmap(run)(tables, weights, v0s)


def _truncate_at_breakdown(alphas: np.ndarray, betas: np.ndarray
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Cut (alpha, beta) at the first Lanczos breakdown (beta zeroed by the
    scan).  Steps past a breakdown contribute spurious zero rows to T, which
    are harmless when reading the *largest* Ritz value but poison the
    *smallest* one (the quantity the Laplacian path reports)."""
    zero = np.nonzero(betas == 0.0)[0]
    if zero.size:
        obs.count("lanczos/breakdown_truncations")
        keep = int(zero[0]) + 1
        return alphas[:keep], betas[:max(keep - 1, 0)]
    return alphas, betas[:-1]


def _batched_ritz_extremes(alphas: jnp.ndarray, betas: jnp.ndarray
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """(lambda_min, lambda_max) Ritz values per batch row, each row
    breakdown-truncated (:func:`_truncate_at_breakdown`) before the tridiag
    solve.  Shared readout for every batched-Lanczos path so breakdown
    handling cannot drift between them."""
    alphas = np.asarray(alphas, dtype=np.float64)
    betas = np.asarray(betas, dtype=np.float64)
    B = alphas.shape[0]
    lmin = np.empty(B, dtype=np.float64)
    lmax = np.empty(B, dtype=np.float64)
    for i in range(B):
        a_i, b_i = _truncate_at_breakdown(alphas[i], betas[i])
        ev = _tridiag_eigvals(a_i, b_i)
        lmin[i], lmax[i] = float(ev[0]), float(ev[-1])
    return lmin, lmax


@functools.partial(jax.jit, static_argnames=("m", "backend"))
def _lap_lanczos_batched(tables: jnp.ndarray, weights: jnp.ndarray,
                         degs: jnp.ndarray, v0s: jnp.ndarray, m: int,
                         backend: Optional[str] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """vmapped ones-deflated *Laplacian* Lanczos over B same-shape tables.

    The adjacency batch (:func:`_lanczos_tridiag_batched`) needs regular
    graphs; this one applies L = D - A through the padded gather form, so it
    is valid for the irregular graphs produced by fault injection.  ``degs``
    holds per-vertex degrees *including* signed self-loop weights, which makes
    ``deg * x - (gather + w * x)`` exactly L x (loops cancel).

    Deflation of the trivial 0 eigenpair (ones) is done by a rank-one SHIFT,
    not a projection: ``L + c * ones ones^T / n`` moves the ones eigenvalue to
    ``c = max_deg + 2 > rho2`` (Fiedler: rho2 <= vertex connectivity <=
    min degree, and rho2 = n = max_deg + 1 for K_n) and leaves every
    ones-orthogonal eigenpair untouched.  A projection would let float32
    roundoff reintroduce the ones component, whose ghost 0 Ritz value poisons
    the *smallest* eigenvalue — exactly the one this path reports.
    """
    bk = KS.resolve_backend(backend)

    def run(tab, lw, deg, v0):
        c = jnp.max(deg) + 2.0

        def op(x):
            lx = deg * x - KS.spmv(x, tab, lw, backend=bk)
            return lx + c * jnp.mean(x)

        alphas, betas, _ = _lanczos_scan(op, v0, m)
        return alphas, betas

    return jax.vmap(run)(tables, weights, degs, v0s)


def _tile_indices(lo: int, hi: int, tile: int) -> Tuple[np.ndarray, int]:
    """Index vector for one batch tile, padded to ``tile`` samples by
    repeating sample ``lo`` so every tile replays one compiled solve (the
    padded rows are recomputed garbage, sliced off by the caller)."""
    idx = np.arange(lo, hi, dtype=np.int64)
    if idx.size < tile:
        idx = np.concatenate([idx, np.full(tile - idx.size, lo, np.int64)])
    return idx, hi - lo


@obs.traced("spectral/rho2_laplacian_batched", phase="execute")
def rho2_laplacian_batched(tables: np.ndarray, weights: np.ndarray,
                           degs: np.ndarray, iters: int = 160,
                           seed: int = 0, *,
                           batch_chunk: Optional[int] = None,
                           backend: Optional[str] = None) -> np.ndarray:
    """rho_2 for B (possibly irregular) graphs in one *streamed* Lanczos solve.

    Operands are stacked padded gather forms — ``tables`` (B, n, k) int32,
    ``weights`` (B, n) per-vertex self weights (loop + padding compensation),
    ``degs`` (B, n) degrees including loop weights — exactly what
    :func:`repro.core.faults.stacked_operands` builds for a batch of fault
    samples.  Returns the second-smallest Laplacian eigenvalue per graph
    (~0 for disconnected samples: the extra kernel vector survives the ones
    deflation).  This is the fault-sweep engine: B degraded instances never
    cost B Python-level solves.

    The batch axis streams through the vmapped solve in memory-bounded tiles
    (``batch_chunk`` samples each; default from
    :data:`DEFAULT_BATCH_TILE_BYTES` — tier-1 sizes always fit one tile, so
    results are identical to the unchunked solve).  Tiles are placed with
    :func:`repro.launch.mesh.shard_batch`.  ``backend`` picks the spmv route
    (default: kernel where it compiles, reference on CPU).
    """
    tables = np.asarray(tables)
    weights, degs = np.asarray(weights), np.asarray(degs)
    B, n, k = tables.shape
    obs.count("lanczos/solves", B)
    obs.count("lanczos/iters", B * iters)
    key = jax.random.PRNGKey(seed)
    v0s = np.asarray(jax.random.normal(key, (B, n), dtype=jnp.float32))
    tile = _batch_tile(B, n, k, iters, batch_chunk)
    bk = KS.resolve_backend(backend)
    alphas = np.empty((B, iters), dtype=np.float64)
    betas = np.empty((B, iters), dtype=np.float64)
    for lo in range(0, B, tile):
        idx, keep = _tile_indices(lo, min(lo + tile, B), tile)
        ops = _mesh.shard_batch(
            jnp.asarray(tables[idx], dtype=jnp.int32),
            jnp.asarray(weights[idx], dtype=jnp.float32),
            jnp.asarray(degs[idx], dtype=jnp.float32),
            jnp.asarray(v0s[idx]))
        a, b = _lap_lanczos_batched(*ops, iters, backend=bk)
        alphas[lo:lo + keep] = np.asarray(a, dtype=np.float64)[:keep]
        betas[lo:lo + keep] = np.asarray(b, dtype=np.float64)[:keep]
    lmin, _ = _batched_ritz_extremes(alphas, betas)
    return np.maximum(lmin, 0.0)


@functools.partial(jax.jit, static_argnames=("m", "backend"))
def _signed_lanczos_batched(table: jnp.ndarray, slot_signs: jnp.ndarray,
                            v0s: jnp.ndarray, m: int,
                            backend: Optional[str] = None
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """vmapped Lanczos on B *signed* adjacency operators sharing one table.

    ``table``: (n, k) int32 neighbor table of the base graph, shared across
    the batch; ``slot_signs``: (B, n, k) float32 per-slot ±1 signs (the
    signing of edge e written into both of e's table slots); ``v0s``: (B, n)
    start vectors.  The operator is ``(A_s x)[i] = sum_j s[i,j] x[table[i,j]]``
    — the Bilu–Linial signed adjacency in the padded gather-table contract,
    applied through the spmv dispatcher's ``signs=`` form.
    No deflation: a signing destroys the trivial ±k eigenpairs.
    """
    bk = KS.resolve_backend(backend)

    def run(sg, v0):
        def op(x):
            return KS.spmv(x, table, signs=sg, backend=bk)

        alphas, betas, _ = _lanczos_scan(op, v0, m)
        return alphas, betas

    return jax.vmap(run)(slot_signs, v0s)


@obs.traced("spectral/signed_extremes_batched", phase="execute")
def signed_extremes_batched(table: np.ndarray, slot_signs: np.ndarray,
                            iters: int = 90, seed: int = 0, *,
                            batch_chunk: Optional[int] = None,
                            backend: Optional[str] = None
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """(lambda_max, lambda_min) of B signed adjacencies in one streamed solve.

    This is the synthesis subsystem's objective oracle: by Bilu–Linial the
    eigenvalues of the signed adjacency A_s are exactly the NEW eigenvalues a
    2-lift introduces, so ``lambda_max`` bounds the lift's lambda_2 and
    ``max(|lambda_min|, lambda_max)`` is the signed spectral radius (the
    Ramanujan criterion).  Operands follow :func:`_signed_lanczos_batched`;
    returns float64 arrays (lmax (B,), lmin (B,)), breakdown-truncated so
    spurious zero Ritz rows never contaminate either end.

    Like :func:`rho2_laplacian_batched`, the batch axis streams through the
    vmapped solve in memory-bounded tiles (``batch_chunk`` /
    :data:`DEFAULT_BATCH_TILE_BYTES`); tier-1 sizes fit one tile and are
    bit-identical to the unchunked solve.
    """
    slot_signs = np.asarray(slot_signs)
    B, n, k = slot_signs.shape
    obs.count("lanczos/solves", B)
    obs.count("lanczos/iters", B * iters)
    v0s = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (B, n),
                                       dtype=jnp.float32))
    tab = jnp.asarray(table, dtype=jnp.int32)
    tile = _batch_tile(B, n, k, iters, batch_chunk)
    bk = KS.resolve_backend(backend)
    alphas = np.empty((B, iters), dtype=np.float64)
    betas = np.empty((B, iters), dtype=np.float64)
    for lo in range(0, B, tile):
        idx, keep = _tile_indices(lo, min(lo + tile, B), tile)
        sg, v0 = _mesh.shard_batch(
            jnp.asarray(slot_signs[idx], dtype=jnp.float32),
            jnp.asarray(v0s[idx]))
        a, b = _signed_lanczos_batched(tab, sg, v0, iters, backend=bk)
        alphas[lo:lo + keep] = np.asarray(a, dtype=np.float64)[:keep]
        betas[lo:lo + keep] = np.asarray(b, dtype=np.float64)[:keep]
    lmin, lmax = _batched_ritz_extremes(alphas, betas)
    return lmax, lmin


def rho2_lanczos_batched(topos: Sequence[Topology], iters: int = 200,
                         seed: int = 0) -> list:
    """rho_2 for a batch of same-shape regular graphs in ONE vmapped solve.

    All topologies must share (n, table-width) so their neighbor tables stack;
    bipartite graphs are rejected (their -k pair needs per-graph deflation) —
    the survey layer routes those through :func:`rho2_lanczos` one by one.
    """
    if not topos:
        return []
    shapes = set()
    tabs, lws = [], []
    for t in topos:
        if t.meta.get("bipartite"):
            raise ValueError(f"{t.name}: bipartite graphs cannot be batched")
        tab, w = t.gather_operands()
        shapes.add(tab.shape)
        tabs.append(tab)
        lws.append(w)
    if len(shapes) != 1:
        raise ValueError(f"neighbor tables must share one shape, got {shapes}")
    obs.count("lanczos/solves", len(topos))
    obs.count("lanczos/iters", len(topos) * iters)
    key = jax.random.PRNGKey(seed)
    n = topos[0].n
    v0s = jax.random.normal(key, (len(topos), n), dtype=jnp.float32)
    alphas, betas = _lanczos_tridiag_batched(
        jnp.asarray(np.stack(tabs), dtype=jnp.int32),
        jnp.asarray(np.stack(lws), dtype=jnp.float32), v0s, iters)
    alphas = np.asarray(alphas, dtype=np.float64)
    betas = np.asarray(betas, dtype=np.float64)
    out = []
    for i, t in enumerate(topos):
        ev = _tridiag_eigvals(alphas[i], betas[i][:-1])
        out.append(float(t.radix - ev[-1]))
    return out
