"""Graph lifts (Bilu–Linial) — the machinery behind Xpander (paper §3.2).

A 2-lift of G doubles the vertices; each edge is either "parallel" (straight)
or "crossing" per a ±1 signing.  Bilu–Linial: the lift's new eigenvalues are
exactly the eigenvalues of the *signed* adjacency A_s, so a signing with small
spectral radius yields a near-Ramanujan double cover — repeated lifting grows
expanders of any size from a small seed (the Xpander construction).

``best_random_signing`` searches random signings for small lambda(A_s);
``k_lift`` generalizes to permutation lifts.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .graphs import Topology

__all__ = ["two_lift", "signed_spectral_radius", "best_random_signing",
           "xpander_like", "k_lift"]


def two_lift(topo: Topology, signing: np.ndarray) -> Topology:
    """2-lift: vertex v -> (v, 0), (v, 1).  Edge e={u,v} with signing +1 stays
    parallel ((u,i)~(v,i)); with -1 it crosses ((u,i)~(v,1-i))."""
    signing = np.asarray(signing)
    assert signing.shape == (topo.m,)
    n = topo.n
    e = topo.edges
    par = signing > 0
    edges = []
    # parallel copies
    edges.append(np.stack([e[par, 0], e[par, 1]], axis=1))                # layer 0
    edges.append(np.stack([e[par, 0] + n, e[par, 1] + n], axis=1))        # layer 1
    # crossing copies
    edges.append(np.stack([e[~par, 0], e[~par, 1] + n], axis=1))
    edges.append(np.stack([e[~par, 0] + n, e[~par, 1]], axis=1))
    return Topology(f"2lift({topo.name})", 2 * n, np.concatenate(edges, axis=0),
                    meta=dict(base=topo.name))


def signed_spectral_radius(topo: Topology, signing: np.ndarray) -> float:
    """lambda(A_s): the largest |eigenvalue| of the signed adjacency — exactly
    the set of NEW eigenvalues introduced by the 2-lift (Bilu–Linial)."""
    A = np.zeros((topo.n, topo.n))
    for (u, v), s in zip(topo.edges, signing):
        A[u, v] += s
        A[v, u] += s
    return float(np.max(np.abs(np.linalg.eigvalsh(A))))


def best_random_signing(topo: Topology, trials: int = 64, seed: int = 0
                        ) -> Tuple[np.ndarray, float]:
    """Random search for a signing with small lambda(A_s).  Bilu–Linial prove
    a signing with lambda <= O(sqrt(k log^3 k)) always exists; random signings
    concentrate near 2 sqrt(k-1) already for modest sizes."""
    rng = np.random.default_rng(seed)
    best, best_lam = None, np.inf
    for _ in range(trials):
        s = rng.choice([-1.0, 1.0], size=topo.m)
        lam = signed_spectral_radius(topo, s)
        if lam < best_lam:
            best, best_lam = s, lam
    return best, best_lam


def xpander_like(seed_topo: Topology, doublings: int, trials: int = 64,
                 seed: int = 0) -> Topology:
    """Xpander-style growth: repeatedly 2-lift with the best random signing.

    Keeps the radix of the seed while doubling nodes each step; the spectral
    gap degrades only by the worst signed radius encountered (tracked in
    meta['lift_lams']).
    """
    g = seed_topo
    lams = []
    for i in range(doublings):
        s, lam = best_random_signing(g, trials=trials, seed=seed + i)
        lams.append(lam)
        g = two_lift(g, s)
    g.meta["lift_lams"] = lams
    g.meta["seed"] = seed_topo.name
    return g


def k_lift(topo: Topology, k: int, seed: int = 0) -> Topology:
    """Random k-lift: vertex v -> (v, 0..k-1); edge {u,v} becomes the matching
    (u,i)~(v, pi(i)) for a uniform permutation pi per edge."""
    rng = np.random.default_rng(seed)
    n = topo.n
    edges = []
    for (u, v) in topo.edges:
        pi = rng.permutation(k)
        for i in range(k):
            edges.append((u * k + i, v * k + pi[i]))
    return Topology(f"{k}lift({topo.name})", n * k,
                    np.array(edges, dtype=np.int64), meta=dict(base=topo.name))
