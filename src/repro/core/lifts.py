"""Graph lifts (Bilu–Linial) — the machinery behind Xpander (paper §3.2).

A 2-lift of G doubles the vertices; each edge is either "parallel" (straight)
or "crossing" per a ±1 signing.  Bilu–Linial: the lift's new eigenvalues are
exactly the eigenvalues of the *signed* adjacency A_s, so a signing with small
spectral radius yields a near-Ramanujan double cover — repeated lifting grows
expanders of any size from a small seed (the Xpander construction).

``best_random_signing`` searches random signings for small lambda(A_s);
``k_lift`` generalizes to permutation lifts.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .graphs import Topology

__all__ = ["two_lift", "signed_spectral_radius", "best_random_signing",
           "xpander_like", "k_lift"]


def two_lift(topo: Topology, signing: np.ndarray) -> Topology:
    """2-lift: vertex v -> (v, 0), (v, 1).  Edge e={u,v} with signing +1 stays
    parallel ((u,i)~(v,i)); with -1 it crosses ((u,i)~(v,1-i))."""
    signing = np.asarray(signing)
    assert signing.shape == (topo.m,)
    n = topo.n
    e = topo.edges
    par = signing > 0
    edges = []
    # parallel copies
    edges.append(np.stack([e[par, 0], e[par, 1]], axis=1))                # layer 0
    edges.append(np.stack([e[par, 0] + n, e[par, 1] + n], axis=1))        # layer 1
    # crossing copies
    edges.append(np.stack([e[~par, 0], e[~par, 1] + n], axis=1))
    edges.append(np.stack([e[~par, 0] + n, e[~par, 1]], axis=1))
    return Topology(f"2lift({topo.name})", 2 * n, np.concatenate(edges, axis=0),
                    meta=dict(base=topo.name))


def _signed_adjacency(topo: Topology, signing: np.ndarray) -> np.ndarray:
    A = np.zeros((topo.n, topo.n))
    np.add.at(A, (topo.edges[:, 0], topo.edges[:, 1]), signing)
    np.add.at(A, (topo.edges[:, 1], topo.edges[:, 0]), signing)
    return A


def _signed_eigvals(topo: Topology, signing: np.ndarray) -> np.ndarray:
    return np.linalg.eigvalsh(_signed_adjacency(topo, signing))


def signed_spectral_radius(topo: Topology, signing: np.ndarray) -> float:
    """lambda(A_s): the largest |eigenvalue| of the signed adjacency — exactly
    the set of NEW eigenvalues introduced by the 2-lift (Bilu–Linial)."""
    return float(np.max(np.abs(_signed_eigvals(topo, signing))))


def _signing_objective(ev: np.ndarray, objective: str) -> float:
    # "radius": Ramanujan criterion, max |eigenvalue|.  "gap": only the top
    # positive eigenvalue binds rho2 = k - lambda_2 of the lift, so minimizing
    # it maximizes the grown graph's algebraic connectivity.
    if objective == "gap":
        return float(ev[-1])
    return float(max(abs(ev[0]), ev[-1]))


def best_random_signing(topo: Topology, trials: int = 64, seed: int = 0,
                        objective: str = "radius", refine: bool = False
                        ) -> Tuple[np.ndarray, float]:
    """Search for a signing with small lambda(A_s).  Bilu–Linial prove
    a signing with lambda <= O(sqrt(k log^3 k)) always exists; random signings
    concentrate near 2 sqrt(k-1) already for modest sizes.

    ``objective``: "radius" minimizes max|eig(A_s)| (the Ramanujan criterion);
    "gap" minimizes the top positive eigenvalue (the one binding the lift's
    rho2).  ``refine=True`` follows the random search with greedy single-edge
    sign flips until a local optimum (dense eigensolves; small graphs only).
    Returns (signing, signed spectral radius) — the radius is reported even
    under the "gap" objective, for Ramanujan-style accounting.
    """
    rng = np.random.default_rng(seed)
    best, best_obj = None, np.inf
    for _ in range(trials):
        s = rng.choice([-1.0, 1.0], size=topo.m)
        obj = _signing_objective(_signed_eigvals(topo, s), objective)
        if obj < best_obj:
            best, best_obj = s, obj
    if refine and topo.n <= 512:
        # incremental flips: a sign flip of edge e={u,v} is a two-entry
        # -/+2s update of the signed adjacency, so keep A current and
        # revert rejected flips instead of rebuilding from the edge list
        A = _signed_adjacency(topo, best)
        improved = True
        while improved:
            improved = False
            for e, (u, v) in enumerate(topo.edges):
                s = best[e]
                A[u, v] -= 2 * s
                A[v, u] -= 2 * s
                obj = _signing_objective(np.linalg.eigvalsh(A), objective)
                if obj < best_obj - 1e-12:
                    best[e] = -s
                    best_obj = obj
                    improved = True
                else:
                    A[u, v] += 2 * s
                    A[v, u] += 2 * s
    return best, signed_spectral_radius(topo, best)


#: above this order, ``xpander_like`` switches from the dense per-signing
#: eigensolve to the batched gather-table search of ``repro.core.synthesis``
DENSE_LIFT_CUTOFF = 256


def xpander_like(seed_topo: Topology, doublings: int, trials: int = 64,
                 seed: int = 0) -> Topology:
    """Xpander-style growth: repeatedly 2-lift with the best random signing.

    Keeps the radix of the seed while doubling nodes each step; the spectral
    gap degrades only by the worst signed radius encountered (tracked in
    meta['lift_lams']).  Signings are selected on the "gap" objective with
    refinement — the grown graph's rho2 is what Xpander cares about.  Levels
    at or below ``DENSE_LIFT_CUTOFF`` vertices use the dense float64
    eigensolve; larger levels run the batched vmapped-Lanczos search of
    :func:`repro.core.synthesis.best_signing_batched` (same objective, one
    solve for all candidates), so growth to device-scale n never pays a
    per-signing dense eigendecomposition.
    """
    g = seed_topo
    lams = []
    for i in range(doublings):
        if g.n <= DENSE_LIFT_CUTOFF:
            s, lam = best_random_signing(g, trials=trials, seed=seed + i,
                                         objective="gap", refine=True)
        else:
            from .synthesis import best_signing_batched

            # mirrors the dense branch: winner picked on "gap", radius reported
            s, _top, lam = best_signing_batched(
                g, batch=min(trials, 32), steps=8 * trials,
                seed=seed + i, objective="gap")
        lams.append(lam)
        g = two_lift(g, s)
    g.meta["lift_lams"] = lams
    g.meta["seed"] = seed_topo.name
    return g


def k_lift(topo: Topology, k: int, seed: int = 0) -> Topology:
    """Random k-lift: vertex v -> (v, 0..k-1); edge {u,v} becomes the matching
    (u,i)~(v, pi(i)) for a uniform permutation pi per edge."""
    rng = np.random.default_rng(seed)
    n = topo.n
    edges = []
    for (u, v) in topo.edges:
        pi = rng.permutation(k)
        for i in range(k):
            edges.append((u * k + i, v * k + pi[i]))
    return Topology(f"{k}lift({topo.name})", n * k,
                    np.array(edges, dtype=np.int64), meta=dict(base=topo.name))
