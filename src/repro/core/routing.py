"""Path-level routing: batched all-sources BFS over the padded gather tables.

The spectral layer bounds diameter and bisection from rho_2; this module
*measures* the path structure those bounds predict, by actually traversing the
graph.  Everything runs on the same (n, k) padded gather-table adjacency that
``spectral.py`` and ``faults.py`` use (rows short of ``k`` edge-neighbors are
padded with the vertex's own index — harmless for reachability, masked out of
path counting), so the one operand layout feeds Lanczos, fault sweeps, and
routing alike, healthy or degraded.

Three levels of entry:

* :func:`bfs_distances` / :func:`shortest_path_counts` — the JAX-vectorized
  primitives: S sources advance one frontier per step in one gather each
  (``reached[:, table]``), batched over sources and jit-compiled; path counts
  run the same layered pass over the BFS DAG.
* :func:`analyze_routing` — all-sources (or sampled-sources) analysis of one
  :class:`~repro.core.graphs.Topology` → :class:`RoutingResult` with the exact
  diameter, hop-count distribution, average shortest-path length, and per-pair
  minimal-path counts (path diversity).
* :func:`routing_stats_stacked` — the degraded-operation path: per-graph BFS
  statistics for a ``(B, n, k)`` stack of padded tables (the
  :func:`repro.core.faults.stacked_operands` block), one vmapped BFS for all B
  fault samples.

Units: distances and diameters are in **hops**; ``seconds`` fields are wall
time; histograms count ordered (source, target) pairs.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro import obs
from repro.kernels import spmv as KS

from .graphs import Topology

__all__ = [
    "RoutingResult", "bfs_distances", "shortest_path_counts",
    "analyze_routing", "routing_stats_stacked", "sample_sources",
    "reverse_slot_index", "DEFAULT_SOURCE_CHUNK",
]

#: sources per jitted BFS/path-count call — bounds the (chunk, n, k) gather
#: intermediate to a few MB at the survey's largest instances.
DEFAULT_SOURCE_CHUNK = 512


# --------------------------------------------------------------------------
# JAX primitives: frontier BFS + layered path counting, batched over sources
# --------------------------------------------------------------------------

@jax.jit
def _bfs_dist_chunk(table: jnp.ndarray, dist0: jnp.ndarray) -> jnp.ndarray:
    """Frontier BFS for a (S, n) block of sources over one (n, k) table.

    ``dist0`` holds 0 at each row's source and -1 elsewhere; each iteration
    reaches every vertex with a reached neighbor (one gather over the whole
    block) until no row changes.  Runs diameter(G)-many iterations, not n.
    Self-padded table entries only ever re-reach the vertex itself.
    """
    obs.count("jit_trace/bfs")                   # trace-time increment

    def cond(state):
        _, _, active = state
        return active

    def body(state):
        dist, d, _ = state
        reached = dist >= 0
        nbr = reached[:, table].any(axis=2)
        newly = nbr & ~reached
        dist = jnp.where(newly, d, dist)
        return dist, d + 1, newly.any()

    dist, _, _ = jax.lax.while_loop(
        cond, body, (dist0, jnp.int32(1), jnp.bool_(True)))
    return dist


@functools.partial(jax.jit, static_argnames=("backend",))
def _sigma_chunk(table: jnp.ndarray, dist: jnp.ndarray,
                 backend: Optional[str] = None) -> jnp.ndarray:
    """Minimal-path counts sigma(s, v) for a (S, n) block of BFS distances.

    Layered DP over the BFS DAG: sigma at layer d is the sum of sigma over
    neighbors at layer d-1 — one spmv per layer, routed through the
    :mod:`repro.kernels.spmv` dispatcher.  Self-padded entries contribute
    nothing because a vertex is never in the layer preceding its own.

    Accumulates in float64 when x64 is enabled at trace time (the
    :func:`shortest_path_counts` entry point wraps its calls in
    ``enable_x64``): float32 counts go inexact past 2^24 and high-diversity
    expanders blow through that well before n=10^5 — e.g. torus(32, 2)'s
    antipodal pairs have C(32, 16) ≈ 6.0e8 minimal paths.
    """
    obs.count("jit_trace/sigma_dp")              # trace-time increment
    bk = KS.resolve_backend(backend)
    dmax = jnp.maximum(dist.max(), 0)
    acc_dt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    sigma0 = (dist == 0).astype(acc_dt)

    def body(d, sigma):
        prev = jnp.where(dist == d - 1, sigma, 0.0)
        contrib = jax.vmap(lambda p: KS.spmv(p, table, backend=bk))(prev)
        return jnp.where(dist == d, contrib, sigma)

    return jax.lax.fori_loop(1, dmax + 1, body, sigma0)


def _gather_table(topo: Topology) -> np.ndarray:
    tab, _ = topo.gather_operands()
    return tab


def _chunks(S: int, chunk: int):
    for lo in range(0, S, chunk):
        yield lo, min(lo + chunk, S)


def bfs_distances(table: np.ndarray, sources: Optional[Sequence[int]] = None,
                  chunk: int = DEFAULT_SOURCE_CHUNK) -> np.ndarray:
    """Shortest-path hop distances from each source over a padded table.

    Args:
        table: (n, k) int neighbor table (``Topology.gather_operands()[0]`` —
            self-padded rows are fine).
        sources: vertex ids to run BFS from; default all n (all-pairs).
        chunk: sources per jitted call (memory knob, result-invariant).

    Returns:
        (S, n) int32 matrix of hop distances; -1 marks unreachable targets.
    """
    table = np.asarray(table)
    n = table.shape[0]
    srcs = np.arange(n, dtype=np.int64) if sources is None \
        else np.asarray(list(sources), dtype=np.int64)
    tab = jnp.asarray(table, dtype=jnp.int32)
    out = np.empty((srcs.size, n), dtype=np.int32)
    for lo, hi in _chunks(srcs.size, chunk):
        dist0 = jnp.full((hi - lo, n), -1, dtype=jnp.int32)
        dist0 = dist0.at[jnp.arange(hi - lo), jnp.asarray(srcs[lo:hi])].set(0)
        out[lo:hi] = np.asarray(_bfs_dist_chunk(tab, dist0))
    return out


def shortest_path_counts(table: np.ndarray, dist: np.ndarray,
                         chunk: int = DEFAULT_SOURCE_CHUNK,
                         backend: Optional[str] = None) -> np.ndarray:
    """Minimal-path counts sigma(s, t) for precomputed BFS distances.

    Args:
        table: (n, k) padded neighbor table (same one ``dist`` came from).
        dist: (S, n) int32 output of :func:`bfs_distances`.
        chunk: sources per jitted call.
        backend: spmv backend for the layered DP (default: dispatcher's).

    Returns:
        (S, n) float64 counts of distinct shortest s→t paths (parallel edges
        count as distinct paths); 0 for unreachable targets, 1 on the diagonal.
        The DP runs in float64 (``enable_x64`` scope), so counts are exact
        integers up to 2^53 — past the 2^24 ceiling the old float32
        accumulator hit on high-diversity families like torus(32, 2).
    """
    table = np.asarray(table)
    tab = jnp.asarray(table, dtype=jnp.int32)
    out = np.empty(dist.shape, dtype=np.float64)
    with enable_x64():
        for lo, hi in _chunks(dist.shape[0], chunk):
            out[lo:hi] = np.asarray(
                _sigma_chunk(tab, jnp.asarray(dist[lo:hi]), backend=backend),
                dtype=np.float64)
    return out


def reverse_slot_index(table: np.ndarray) -> np.ndarray:
    """Slot index of each directed edge's reverse: ``rev[v, j]`` is the slot
    ``j'`` in row ``u = table[v, j]`` with ``table[u, j'] == v``.

    The padded gather table stores each undirected edge as two directed slots;
    adaptive routing (UGAL's channel-load lookup) needs the load of the
    *incoming* link ``u → v`` while iterating slots of ``v``, i.e.
    ``loads[table[v, j], rev[v, j]]``.  Parallel edges are paired copy-by-copy
    (the i-th slot of one endpoint with the i-th of the other), self-padded
    slots map to themselves.  Pure host-side numpy, O(nk log nk).
    """
    table = np.asarray(table)
    n, k = table.shape
    u = np.repeat(np.arange(n, dtype=np.int64), k)
    v = table.astype(np.int64).ravel()
    slots = np.tile(np.arange(k, dtype=np.int64), n)
    rev = np.empty(n * k, dtype=np.int64)
    pad = u == v
    rev[pad] = slots[pad]
    live = np.flatnonzero(~pad)
    ul, vl, sl = u[live], v[live], slots[live]
    lo, hi = np.minimum(ul, vl), np.maximum(ul, vl)
    # sort into runs per undirected edge {lo, hi}: the low-endpoint copies
    # first (slot-sorted), then the high-endpoint copies — pairing is then a
    # half-rotation within each run
    order = np.lexsort((sl, ul, hi, lo))
    key = lo[order] * n + hi[order]
    m = order.size
    if m:
        starts = np.flatnonzero(np.r_[True, key[1:] != key[:-1]])
        sizes = np.diff(np.r_[starts, m])
        gid = np.cumsum(np.r_[0, key[1:] != key[:-1]])
        start_of, size_of = starts[gid], sizes[gid]
        if np.any(size_of % 2):
            raise ValueError("table is not symmetric: some directed edge "
                             "has no reverse slot")
        rank = np.arange(m) - start_of
        partner = start_of + (rank + size_of // 2) % size_of
        rev[live[order]] = sl[order[partner]]
    return rev.reshape(n, k)


def sample_sources(n: int, s: int, seed: int = 0) -> np.ndarray:
    """``s`` distinct BFS source vertices, uniform without replacement.

    Deterministic in ``(n, s, seed)``; returned sorted so downstream masking
    is cache-friendly.  ``s >= n`` degenerates to *all* sources (``arange``),
    which is what makes ``sample_fraction=1.0`` reproduce the exact
    all-sources analysis bit-for-bit.
    """
    if s >= n:
        return np.arange(n, dtype=np.int64)
    if s < 1:
        raise ValueError(f"need at least one source (got s={s})")
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(n, size=s, replace=False)).astype(np.int64)


# --------------------------------------------------------------------------
# one-topology analysis
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RoutingResult:
    """Measured path structure of one topology (all units in hops).

    ``dist``/``sigma`` keep the full (S, n) matrices so the traffic layer can
    route demands without re-running BFS.  When ``sources`` is a proper subset
    of the vertices, ``diameter`` is the max eccentricity over that sample —
    a certified *lower* bound on the true diameter (``exact`` is False).
    """
    name: str
    n: int
    sources: np.ndarray            # (S,) vertex ids BFS ran from
    exact: bool                    # True iff sources cover all n vertices
    dist: np.ndarray               # (S, n) int32 hops, -1 = unreachable
    sigma: np.ndarray              # (S, n) float64 minimal-path counts
    diameter: int                  # max finite hops over sampled pairs
    avg_path_length: float         # mean hops over reachable ordered pairs
    hop_histogram: np.ndarray      # (diameter+1,) ordered-pair counts by hops
    unreachable_pairs: int         # ordered pairs with no path (s != t)
    path_diversity_mean: float     # mean sigma over reachable pairs (s != t)
    path_diversity_min: float      # min sigma over reachable pairs (s != t)
    eccentricity: np.ndarray       # (S,) max finite hops per source
    seconds: float                 # wall time of the analysis
    diameter_lb: int = 0           # certified lower bound (== diameter)
    avg_hops_ci: Tuple[float, float] = (0.0, 0.0)  # 95% bootstrap CI
    seed: Optional[int] = None     # source-sampling seed (None = explicit/all)

    def to_dict(self) -> Dict:
        """JSON-ready summary (drops the (S, n) matrices)."""
        return dict(
            name=self.name, n=self.n, sources=int(self.sources.size),
            exact=self.exact, diameter=int(self.diameter),
            diameter_lb=int(self.diameter_lb),
            avg_path_length=round(float(self.avg_path_length), 6),
            avg_hops_ci=[round(float(c), 6) for c in self.avg_hops_ci],
            hop_histogram=self.hop_histogram.tolist(),
            unreachable_pairs=int(self.unreachable_pairs),
            path_diversity_mean=round(float(self.path_diversity_mean), 4),
            path_diversity_min=float(self.path_diversity_min),
            seconds=round(self.seconds, 3))

    def report(self) -> str:
        """Compact text block for CLI reports."""
        kind = "exact (all sources)" if self.exact else \
            f"sampled ({self.sources.size}/{self.n} sources, diameter is a LB)"
        lines = [
            f"routing         : {kind}",
            f"diameter (BFS)  : {self.diameter} hops",
            f"avg path length : {self.avg_path_length:.4f} hops",
            f"path diversity  : mean {self.path_diversity_mean:.2f} / "
            f"min {self.path_diversity_min:.0f} minimal paths per pair",
        ]
        if not self.exact:
            lo, hi = self.avg_hops_ci
            lines.append(f"avg hops 95% CI : [{lo:.4f}, {hi:.4f}] (bootstrap)")
        if self.unreachable_pairs:
            lines.append(f"unreachable     : {self.unreachable_pairs} ordered pairs")
        return "\n".join(lines)


def _bootstrap_avg_hops_ci(dist: np.ndarray, srcs: np.ndarray,
                           seed: Optional[int], bootstrap: int,
                           confidence: float) -> Tuple[float, float]:
    """Percentile bootstrap CI for avg hops, resampling *source rows*.

    Sources are the sampling unit (targets within a row are a census), so the
    bootstrap resamples whole rows with replacement and recomputes the ratio
    estimator sum(hops)/count(reachable) per replicate.  Deterministic in the
    routing seed.  Slightly conservative: it ignores the variance reduction
    from drawing sources *without* replacement, so observed coverage runs at
    or above the nominal rate.
    """
    S = dist.shape[0]
    finite = dist >= 0
    offdiag = finite.copy()
    offdiag[np.arange(S), srcs] = False
    row_sum = np.where(offdiag, dist, 0).sum(axis=1).astype(np.float64)
    row_cnt = offdiag.sum(axis=1).astype(np.float64)
    rng = np.random.default_rng((0 if seed is None else seed) + 0x5EED)
    idx = rng.integers(0, S, size=(bootstrap, S))
    sums = row_sum[idx].sum(axis=1)
    cnts = row_cnt[idx].sum(axis=1)
    est = sums / np.maximum(cnts, 1.0)
    alpha = (1.0 - confidence) / 2.0
    return float(np.quantile(est, alpha)), float(np.quantile(est, 1.0 - alpha))


@obs.traced("routing/analyze", phase="execute")
def analyze_routing(topo: Union[Topology, Tuple[np.ndarray, int]],
                    sources: Optional[Sequence[int]] = None,
                    chunk: int = DEFAULT_SOURCE_CHUNK, *,
                    sample_fraction: Optional[float] = None,
                    seed: int = 0,
                    bootstrap: int = 256,
                    confidence: float = 0.95,
                    backend: Optional[str] = None) -> RoutingResult:
    """Path-level analysis of one topology via batched BFS, exact or sampled.

    Args:
        topo: a :class:`Topology`, or a ``(table, n)`` pair of an already-built
            padded gather table (the degraded-operation entry point).
        sources: explicit BFS source vertices; default all n → exact diameter /
            distribution.  Mutually exclusive with ``sample_fraction``.
        chunk: sources per jitted call (memory knob).
        sample_fraction: if set, BFS runs from ``round(fraction * n)`` sources
            drawn by :func:`sample_sources` with ``seed``.  ``1.0`` selects
            every vertex and reproduces the exact analysis bit-for-bit;
            anything less returns estimates: ``diameter`` becomes the
            certified lower bound ``diameter_lb`` and ``avg_path_length``
            carries the bootstrap ``avg_hops_ci``.
        seed: source-sampling seed (also seeds the bootstrap resampler).
        bootstrap: bootstrap replicates for the CI.
        confidence: CI coverage level (default 95%).
        backend: spmv backend for the sigma DP (default: dispatcher's).

    Returns:
        :class:`RoutingResult` with distances, path counts, and summary stats.
    """
    t0 = time.time()
    if isinstance(topo, Topology):
        name, n, table = topo.name, topo.n, _gather_table(topo)
    else:
        table, n = np.asarray(topo[0]), int(topo[1])
        name = f"table(n={n})"
    used_seed: Optional[int] = None
    if sample_fraction is not None:
        if sources is not None:
            raise ValueError("pass either sources= or sample_fraction=, not both")
        if not 0.0 < sample_fraction <= 1.0:
            raise ValueError(f"sample_fraction must be in (0, 1] "
                             f"(got {sample_fraction})")
        srcs = sample_sources(n, max(1, int(round(sample_fraction * n))), seed)
        used_seed = seed
    elif sources is None:
        srcs = np.arange(n, dtype=np.int64)
    else:
        srcs = np.asarray(list(sources), dtype=np.int64)
    obs.count("routing/bfs_sources", int(srcs.size))
    dist = bfs_distances(table, srcs, chunk=chunk)
    sigma = shortest_path_counts(table, dist, chunk=chunk, backend=backend)
    finite = dist >= 0
    offdiag = finite.copy()
    offdiag[np.arange(srcs.size), srcs] = False   # drop s == t pairs
    hops = dist[offdiag]
    diameter = int(hops.max()) if hops.size else 0
    hist = np.bincount(hops, minlength=diameter + 1) if hops.size else \
        np.zeros(1, dtype=np.int64)
    div = sigma[offdiag]
    ecc = np.where(finite, dist, -1).max(axis=1)
    exact = bool(srcs.size == n)
    avg = float(hops.mean()) if hops.size else 0.0
    if exact:
        ci = (avg, avg)
    else:
        obs.count("routing/bootstrap_reps", int(bootstrap))
        ci = _bootstrap_avg_hops_ci(dist, srcs, used_seed, bootstrap,
                                    confidence)
    return RoutingResult(
        name=name, n=n, sources=srcs, exact=exact,
        dist=dist, sigma=sigma, diameter=diameter,
        avg_path_length=avg,
        hop_histogram=hist.astype(np.int64),
        unreachable_pairs=int((~finite).sum()),
        path_diversity_mean=float(div.mean()) if div.size else 0.0,
        path_diversity_min=float(div.min()) if div.size else 0.0,
        eccentricity=ecc.astype(np.int64),
        seconds=time.time() - t0,
        diameter_lb=diameter, avg_hops_ci=ci, seed=used_seed)


# --------------------------------------------------------------------------
# degraded-operation path: stats over a (B, n, k) stack of padded tables
# --------------------------------------------------------------------------

@jax.jit
def _bfs_dist_stacked(tables: jnp.ndarray, dist0: jnp.ndarray) -> jnp.ndarray:
    """vmapped frontier BFS: (B, n, k) tables x (S, n) start block → (B, S, n)."""
    return jax.vmap(lambda tab: _bfs_dist_chunk(tab, dist0))(tables)


def routing_stats_stacked(tables: np.ndarray,
                          sources: Optional[Sequence[int]] = None
                          ) -> List[Dict]:
    """Per-graph BFS statistics for B stacked padded tables in one vmapped call.

    This is the fault-subsystem hook: ``tables`` is the (B, n, k) block that
    :func:`repro.core.faults.stacked_operands` already builds for a batch of
    degraded samples, so a fault sweep measures degraded diameters the same
    way it measures degraded rho_2 — one device call for all B samples.

    Args:
        tables: (B, n, k) int padded neighbor tables (self-padded rows OK).
        sources: BFS sources shared by every graph; default all n vertices.

    Returns:
        One dict per graph: ``diameter`` (hops; max over sampled pairs — exact
        when sources cover all vertices and the graph is connected),
        ``avg_path_length`` (hops over reachable ordered pairs),
        ``reachable_frac`` (reachable fraction of sampled ordered s != t
        pairs), ``unreachable_pairs``.
    """
    tables = np.asarray(tables)
    B, n, _ = tables.shape
    srcs = np.arange(n, dtype=np.int64) if sources is None \
        else np.asarray(list(sources), dtype=np.int64)
    dist0 = jnp.full((srcs.size, n), -1, dtype=jnp.int32)
    dist0 = dist0.at[jnp.arange(srcs.size), jnp.asarray(srcs)].set(0)
    dist = np.asarray(_bfs_dist_stacked(
        jnp.asarray(tables, dtype=jnp.int32), dist0))
    out = []
    for b in range(B):
        d = dist[b]
        finite = d >= 0
        offdiag = finite.copy()
        offdiag[np.arange(srcs.size), srcs] = False
        hops = d[offdiag]
        pairs = srcs.size * (n - 1)
        out.append(dict(
            diameter=int(hops.max()) if hops.size else 0,
            avg_path_length=float(hops.mean()) if hops.size else 0.0,
            reachable_frac=float(hops.size / pairs) if pairs else 1.0,
            unreachable_pairs=int(pairs - hops.size),
        ))
    return out
