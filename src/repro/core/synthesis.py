"""Topology synthesis: batched search for maximum-spectral-gap graphs.

The paper's conclusion — every surveyed topology sits well below the
Ramanujan spectral-gap optimum — "suggests the potential utility of adopting
Ramanujan graphs as interconnection networks."  This module *designs* such
networks at a target (n, k) instead of only analyzing given ones, along the
two constructive paths of the literature:

* **Bilu–Linial lifts** (the Xpander line): repeatedly 2-lift a small seed,
  choosing each edge signing to minimize the top eigenvalue of the signed
  adjacency A_s — by the Bilu–Linial identity, spec(2-lift) = spec(A) ∪
  spec(A_s), so the signing alone controls the new eigenvalues.  The signed
  objective runs in the padded gather-table operand contract (one shared
  (n, k) neighbor table + per-candidate (n, k) slot signs) so B candidate
  signings cost ONE vmapped Lanczos solve
  (:func:`repro.core.spectral.signed_extremes_batched`), and a simulated-
  annealing single-flip refinement loop runs fully jitted under
  ``jax.lax.fori_loop`` with a warm-started small-Lanczos objective estimate.

* **Degree-preserving rewiring** (Markov-chain double-edge swaps): for sizes
  a lift tower cannot reach, hill-climb over the connected double-edge-swap
  chain from a random regular graph, scoring every candidate batch with the
  PR-2 batched Laplacian Lanczos (one vmapped solve per round via
  :func:`repro.core.spectral.rho2_laplacian_batched` over
  :func:`repro.core.faults.stacked_operands`).

:func:`synthesize` wraps both behind one call and returns a
:class:`SynthesisResult` (best topology, rho2 trajectory, fraction of the
Ramanujan-bound gap achieved).  The products register as first-class
families — ``build("xpander(512,6)")``, ``build("rewired(360,5)")`` — so
``Analysis``, ``survey()``, ``fault_sweep()`` and ``routing()/traffic()``
consume designed topologies exactly like surveyed ones.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from ..api.registry import register
from ..kernels import spmv as KS
from . import bounds as B
from . import spectral as S
from .graphs import Topology
from .lifts import two_lift

__all__ = [
    "SynthesisResult", "synthesize", "lift_search", "rewire_search",
    "best_signing_batched", "signed_slot_operands", "double_edge_swaps",
    "xpander", "rewired",
]

#: candidate signings / graphs evaluated per batched solve
DEFAULT_BATCH = 24
#: default refinement budgets (see ``synthesize``'s ``budget`` docs)
DEFAULT_LIFT_BUDGET = 2400
DEFAULT_REWIRE_BUDGET = 288


# --------------------------------------------------------------------------
# signed-adjacency operands: the lifts.py objective in gather-table form
# --------------------------------------------------------------------------

def signed_slot_operands(topo: Topology) -> Tuple[np.ndarray, np.ndarray]:
    """(table (n, k) int32, edge_slot (n, k) int32) for an edge-regular graph.

    ``table`` is the standard neighbor table; ``edge_slot[i, j]`` is the row
    index into ``topo.edges`` that produced slot (i, j), so a batch of
    signings (B, m) expands to per-slot signs with ONE gather —
    ``signings[:, edge_slot]`` — placing each edge's sign into both of its
    table slots.  This is the port of ``lifts._signed_adjacency`` to the
    operand contract shared with the ``cayley_spmv`` kernel.
    """
    if topo.loops is not None and np.any(topo.loops):
        raise ValueError(f"{topo.name}: signed lifts need a loop-free graph")
    src = np.concatenate([topo.edges[:, 0], topo.edges[:, 1]])
    dst = np.concatenate([topo.edges[:, 1], topo.edges[:, 0]])
    eid = np.tile(np.arange(topo.m, dtype=np.int32), 2)
    order = np.argsort(src, kind="stable")
    src, dst, eid = src[order], dst[order], eid[order]
    deg = np.bincount(src, minlength=topo.n)
    k = int(deg.max())
    if not np.all(deg == k):
        raise ValueError(f"{topo.name}: signed lifts need an edge-regular graph")
    starts = np.concatenate([[0], np.cumsum(deg)])
    slot = np.arange(src.size) - starts[src]
    table = np.empty((topo.n, k), dtype=np.int32)
    edge_slot = np.empty((topo.n, k), dtype=np.int32)
    table[src, slot] = dst.astype(np.int32)
    edge_slot[src, slot] = eid
    return table, edge_slot


# --------------------------------------------------------------------------
# jitted simulated-annealing flip refinement
# --------------------------------------------------------------------------

def _lam_estimator(table, shift: float, est_iters: int, objective: str,
                   backend: Optional[str] = None):
    """Traceable objective estimate: a small warm-started Lanczos solve.

    For ``objective="gap"`` the operator is A_s + shift·I (PSD for
    shift >= k) and the estimate is its top Ritz value − shift, i.e.
    lambda_max(A_s) — the eigenvalue binding the lift's rho2.  For
    ``"radius"`` the raw A_s tridiagonal is read at both ends,
    max(|lambda_min|, lambda_max) — the Ramanujan criterion.  The signed
    matvec routes through the :mod:`repro.kernels.spmv` dispatcher.  Returns
    (estimate, next warm vector).
    """
    bk = KS.resolve_backend(backend)

    def est(sg, v0):
        def op(x):
            y = KS.spmv(x, table, signs=sg, backend=bk)
            if objective == "gap":
                y = y + shift * x
            return y

        a, b, V = S._lanczos_scan(op, v0, est_iters)
        T = jnp.diag(a) + jnp.diag(b[:-1], 1) + jnp.diag(b[:-1], -1)
        w, y = jnp.linalg.eigh(T)
        if objective == "gap":
            lam = w[-1] - shift
            top = y[:, -1]
        else:
            idx = jnp.argmax(jnp.abs(w))
            lam = jnp.abs(w)[idx]
            top = jnp.take(y, idx, axis=1)
        ritz = V[:est_iters].T @ top
        nrm = jnp.linalg.norm(ritz)
        ritz = jnp.where(nrm > 1e-6, ritz / jnp.where(nrm > 1e-6, nrm, 1.0), v0)
        return lam, ritz

    return est


@functools.partial(jax.jit, static_argnames=("steps", "est_iters", "objective",
                                             "backend"))
def _anneal_signings(table, edge_slot, signings, key, shift, temp0, *,
                     steps: int, est_iters: int, objective: str,
                     backend: Optional[str] = None):
    """SA single-flip refinement of B signings, fully on-device.

    Each ``fori_loop`` step flips one random edge sign per candidate,
    re-estimates the objective with a warm-started ``est_iters``-step Lanczos
    solve, and accepts downhill moves always / uphill moves with probability
    exp(-delta / T_t) under geometric cooling from ``temp0``.  Estimates are
    noisy by design — the caller re-scores refined AND original candidates
    with the exact batched solve and keeps the per-candidate winner
    (elitism), so estimator bias can never lose ground.
    """
    obs.count("jit_trace/anneal_signings")       # trace-time increment
    Bc, m = signings.shape
    n = table.shape[0]
    est = _lam_estimator(table, shift, est_iters, objective,
                         backend=KS.resolve_backend(backend))

    key, k0 = jax.random.split(key)
    v0s = jax.random.normal(k0, (Bc, n), dtype=jnp.float32)
    obj, vecs = jax.vmap(est)(signings[:, edge_slot], v0s)

    def step(t, carry):
        signings, obj, vecs, key = carry
        key, k1, k2 = jax.random.split(key, 3)
        e = jax.random.randint(k1, (Bc,), 0, m)
        flipped = jax.vmap(lambda s, ei: s.at[ei].multiply(-1.0))(signings, e)
        new_obj, new_vecs = jax.vmap(est)(flipped[:, edge_slot], vecs)
        temp = temp0 * jnp.exp(-3.0 * t / steps)
        u = jax.random.uniform(k2, (Bc,))
        accept = (new_obj < obj) | \
            (u < jnp.exp(-(new_obj - obj) / jnp.maximum(temp, 1e-9)))
        signings = jnp.where(accept[:, None], flipped, signings)
        obj = jnp.where(accept, new_obj, obj)
        vecs = jnp.where(accept[:, None], new_vecs, vecs)
        return signings, obj, vecs, key

    signings, obj, _, _ = jax.lax.fori_loop(0, steps, step,
                                            (signings, obj, vecs, key))
    return signings, obj


def best_signing_batched(topo: Topology, batch: int = DEFAULT_BATCH,
                         steps: int = 400, est_iters: int = 10,
                         iters: int = 90, seed: int = 0,
                         temp0: float = 0.05, objective: str = "gap"
                         ) -> Tuple[np.ndarray, float, float]:
    """Best of ``batch`` random signings after jitted SA flip refinement.

    The batched successor of ``lifts.best_random_signing``: all candidates
    are drawn, refined, and finally scored together (the exact scoring is one
    :func:`repro.core.spectral.signed_extremes_batched` call over refined ∪
    initial candidates, so refinement can only help).  Deterministic in
    ``seed``.  Returns (signing (m,) float ±1, lambda_max(A_s), signed
    spectral radius) of the winner under ``objective`` ("gap" minimizes
    lambda_max — the lift-rho2 criterion; "radius" minimizes
    max|eig| — the Ramanujan criterion).
    """
    if objective not in ("gap", "radius"):
        raise ValueError(f"unknown signing objective {objective!r}")
    table, edge_slot = signed_slot_operands(topo)
    rng = np.random.default_rng(seed)
    init = rng.choice([-1.0, 1.0], size=(batch, topo.m)).astype(np.float32)
    refined = init
    if steps > 0:
        refined, _ = _anneal_signings(
            jnp.asarray(table), jnp.asarray(edge_slot), jnp.asarray(init),
            jax.random.PRNGKey(seed), jnp.float32(topo.radix),
            jnp.float32(temp0), steps=steps, est_iters=est_iters,
            objective=objective)
        refined = np.sign(np.asarray(refined, dtype=np.float64))
        cands = np.concatenate([refined, init], axis=0)
    else:
        cands = init
    slot_signs = cands[:, edge_slot]
    lmax, lmin = S.signed_extremes_batched(table, slot_signs, iters=iters,
                                           seed=seed + 1)
    radius = np.maximum(np.abs(lmin), lmax)
    score = lmax if objective == "gap" else radius
    best = int(np.argmin(score))
    return cands[best].astype(np.float64), float(lmax[best]), float(radius[best])


# --------------------------------------------------------------------------
# degree-preserving double-edge-swap rewiring
# --------------------------------------------------------------------------

def double_edge_swaps(edges: np.ndarray, swaps: int,
                      rng: np.random.Generator) -> np.ndarray:
    """Apply ``swaps`` random degree-preserving double-edge swaps.

    The classic Markov-chain move on simple graphs: edges {a,b}, {c,d} become
    {a,c}, {b,d} (orientation randomized), rejected when it would create a
    self-loop or parallel edge, so the result is again simple with the exact
    same degree sequence.  Caps proposals at 20x ``swaps``.
    """
    e = np.array(edges, dtype=np.int64, copy=True)
    m = e.shape[0]
    eset = {tuple(sorted(row)) for row in e.tolist()}
    if len(eset) != m:
        raise ValueError("double_edge_swaps needs a simple graph")
    done = attempts = 0
    while done < swaps and attempts < 20 * swaps:
        attempts += 1
        i, j = rng.integers(0, m, size=2)
        if i == j:
            continue
        a, b = e[i]
        c, d = e[j]
        if rng.random() < 0.5:
            c, d = d, c
        if a == c or b == d:
            continue
        n1, n2 = tuple(sorted((int(a), int(c)))), tuple(sorted((int(b), int(d))))
        if n1 in eset or n2 in eset:
            continue
        eset.discard(tuple(sorted((int(a), int(b)))))
        eset.discard(tuple(sorted((int(c), int(d)))))
        eset.add(n1)
        eset.add(n2)
        e[i] = n1
        e[j] = n2
        done += 1
    return e


def _batched_rho2_edges(n: int, edge_sets: List[np.ndarray], iters: int,
                        seed: int) -> np.ndarray:
    """rho2 of B same-order graphs given as edge arrays, one vmapped solve."""
    from .faults import stacked_operands

    topos = [Topology("cand", n, e) for e in edge_sets]
    tabs, ws, degs = stacked_operands(topos)
    return S.rho2_laplacian_batched(tabs, ws, degs, iters=iters, seed=seed)


# --------------------------------------------------------------------------
# the two search drivers + synthesize()
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SynthesisResult:
    """Outcome of one topology-design search."""
    topo: Topology              # the best graph found (regular, simple)
    method: str                 # "lift" or "rewire"
    n: int
    k: int
    rho2: float                 # measured on topo (dense or Lanczos verified)
    ramanujan_rho2: float       # k - 2 sqrt(k-1), the design optimum
    gap_fraction: float         # rho2 / ramanujan_rho2
    trajectory: List[float]     # predicted rho2 after each search stage
    evaluations: int            # candidate signings/graphs scored exactly
    seconds: float

    def to_dict(self) -> Dict:
        """JSON-ready summary (the topology itself is not serialized)."""
        return dict(name=self.topo.name, method=self.method, n=self.n,
                    k=self.k, rho2=round(self.rho2, 6),
                    ramanujan_rho2=round(self.ramanujan_rho2, 6),
                    gap_fraction=round(self.gap_fraction, 6),
                    trajectory=[round(x, 6) for x in self.trajectory],
                    evaluations=self.evaluations,
                    seconds=round(self.seconds, 3))

    def report(self) -> str:
        """Compact text block for CLI reports."""
        return "\n".join([
            f"synthesized     : {self.topo.name} (method={self.method})",
            f"nodes / radix   : {self.n} / {self.k}",
            f"rho2 (measured) : {self.rho2:.5f}",
            f"Ramanujan rho2  : {self.ramanujan_rho2:.5f} "
            f"({100 * self.gap_fraction:.1f}% achieved)",
            f"search          : {self.evaluations} exact evaluations, "
            f"{len(self.trajectory)} stages, {self.seconds:.1f}s",
        ])


def _lift_seed(n: int, k: int, seed: int) -> Tuple[Topology, int]:
    """Smallest valid 2-lift tower base: n = n0 * 2^t with n0 >= k+1 and
    n0*k even.  Returns (seed topology, t)."""
    from .topologies import complete, random_regular

    n0, t = n, 0
    while n0 % 2 == 0 and n0 // 2 >= k + 1 and ((n0 // 2) * k) % 2 == 0:
        n0 //= 2
        t += 1
    if t == 0:
        raise ValueError(
            f"lift synthesis cannot reach n={n} at k={k} (need n = n0 * 2^t "
            f"with n0 >= {k + 1} and n0*k even); use method='rewire'")
    g = complete(k + 1) if n0 == k + 1 else random_regular(n0, k, seed=seed)
    return g, t


@obs.traced("synthesis/lift_search", phase="execute")
def lift_search(n: int, k: int, budget: int = DEFAULT_LIFT_BUDGET,
                batch: int = DEFAULT_BATCH, seed: int = 0,
                iters: int = 90) -> Tuple[Topology, List[float], int]:
    """Grow an (n, k) expander by a tower of best-signed 2-lifts.

    ``budget`` is the total SA flip-refinement steps, split evenly across the
    tower's levels; each level additionally spends ``2 * batch`` exact signed
    Lanczos evaluations (one vmapped solve).  The rho2 trajectory uses the
    Bilu–Linial identity — lambda_2(lift) = max(lambda_2(base),
    lambda_max(A_s)) — so no intermediate full solves are needed.  Returns
    (topology, trajectory, exact evaluations).
    """
    g, t = _lift_seed(n, k, seed)
    lam2 = float(np.sort(S.adjacency_spectrum(g))[-2])
    traj = [k - lam2]
    lams, evals = [], 0
    steps = max(budget // t, 0)
    for lvl in range(t):
        s, top, _radius = best_signing_batched(
            g, batch=batch, steps=steps, iters=iters, seed=seed + 7 * lvl,
            objective="gap")
        evals += 2 * batch if steps > 0 else batch
        g = two_lift(g, s)
        lams.append(top)
        lam2 = max(lam2, top)
        traj.append(k - lam2)
    g.name = f"xpander({n},{k})"
    g.meta["lift_lams"] = lams
    g.meta["k"] = k
    g.meta["seed"] = seed
    return g, traj, evals


@obs.traced("synthesis/rewire_search", phase="execute")
def rewire_search(n: int, k: int, budget: int = DEFAULT_REWIRE_BUDGET,
                  batch: int = DEFAULT_BATCH, seed: int = 0,
                  iters: int = 160, swap_fraction: float = 0.05
                  ) -> Tuple[Topology, List[float], int]:
    """Hill-climb the double-edge-swap Markov chain toward maximum rho2.

    Starts from a random k-regular graph; each round proposes ``batch``
    candidates (each ``swap_fraction * m`` swaps away from the incumbent) and
    scores incumbent + candidates in ONE vmapped Laplacian Lanczos solve,
    moving to the best.  ``budget`` is the total candidate evaluations
    (rounds = budget // (batch + 1)).  Reaches any (n, k) with n*k even —
    the sizes a power-of-two lift tower cannot hit.  Returns (topology,
    rho2 trajectory, exact evaluations).
    """
    from .topologies import random_regular

    if (n * k) % 2 or n <= k:
        raise ValueError(f"no {k}-regular graph on {n} vertices")
    rng = np.random.default_rng(seed)
    g = random_regular(n, k, seed=seed)
    edges = g.edges
    swaps = max(1, int(round(swap_fraction * edges.shape[0])))
    rounds = max(budget // (batch + 1), 1)
    rho2_cur = float(_batched_rho2_edges(n, [edges], iters, seed)[0])
    traj = [rho2_cur]
    evals = 1
    for rnd in range(rounds):
        cands = [double_edge_swaps(edges, swaps, rng) for _ in range(batch)]
        vals = _batched_rho2_edges(n, [edges] + cands, iters, seed + 1 + rnd)
        evals += batch + 1
        best = int(np.argmax(vals))
        if best > 0:
            edges = cands[best - 1]
        rho2_cur = float(vals[best])
        traj.append(rho2_cur)
    topo = Topology(f"rewired({n},{k})", n, edges,
                    meta=dict(k=k, seed=seed, swaps_per_candidate=swaps))
    return topo, traj, evals


def synthesize(n: int, k: int, method: str = "lift",
               budget: Optional[int] = None, batch: int = DEFAULT_BATCH,
               seed: int = 0, iters: Optional[int] = None) -> SynthesisResult:
    """Design a k-regular n-vertex topology with maximum spectral gap.

    ``method="lift"`` grows a Bilu–Linial 2-lift tower (needs n = n0 * 2^t);
    ``method="rewire"`` runs the degree-preserving double-edge-swap search
    (any n*k even).  ``budget`` scales search effort: total SA flip steps
    (lift, default 2400) or total candidate evaluations (rewire, default
    288).  Deterministic in ``seed``.  The returned
    :class:`SynthesisResult` carries the measured rho2 (re-verified on the
    final graph), the per-stage rho2 trajectory, and the achieved fraction
    of the Ramanujan-bound gap ``k - 2 sqrt(k-1)``.
    """
    if k < 3:
        raise ValueError("synthesis needs radix k >= 3")
    t0 = time.time()
    if method == "lift":
        topo, traj, evals = lift_search(
            n, k, budget=DEFAULT_LIFT_BUDGET if budget is None else budget,
            batch=batch, seed=seed, iters=iters or 90)
    elif method == "rewire":
        topo, traj, evals = rewire_search(
            n, k, budget=DEFAULT_REWIRE_BUDGET if budget is None else budget,
            batch=batch, seed=seed, iters=iters or 160)
    else:
        raise ValueError(f"unknown synthesis method {method!r} "
                         "(known: 'lift', 'rewire')")
    rho2 = S.algebraic_connectivity(topo, seed=seed)
    opt = B.ramanujan_rho2(k)
    return SynthesisResult(
        topo=topo, method=method, n=topo.n, k=k, rho2=rho2,
        ramanujan_rho2=opt, gap_fraction=rho2 / opt, trajectory=traj,
        evaluations=evals, seconds=time.time() - t0)


# --------------------------------------------------------------------------
# first-class registry families: designed topologies survey like built ones
# --------------------------------------------------------------------------

def _cf_xpander(n: int, k: int, seed: int = 0,
                budget: int = DEFAULT_LIFT_BUDGET) -> dict:
    return dict(nodes=n, radix=k)


def _cf_rewired(n: int, k: int, seed: int = 0,
                budget: int = DEFAULT_REWIRE_BUDGET) -> dict:
    return dict(nodes=n, radix=k)


@register("xpander", params=dict(n=int, k=int, seed=int, budget=int),
          defaults=dict(seed=0, budget=DEFAULT_LIFT_BUDGET),
          closed_forms=_cf_xpander, default_instance="xpander(32,4,0,160)")
def xpander(n: int, k: int, seed: int = 0,
            budget: int = DEFAULT_LIFT_BUDGET) -> Topology:
    """Lift-synthesized expander: best-signed Bilu–Linial 2-lift tower at (n, k)."""
    res = synthesize(n, k, method="lift", budget=budget, seed=seed)
    res.topo.meta["synthesis"] = res.to_dict()
    return res.topo


@register("rewired", params=dict(n=int, k=int, seed=int, budget=int),
          defaults=dict(seed=0, budget=DEFAULT_REWIRE_BUDGET),
          closed_forms=_cf_rewired, default_instance="rewired(40,4,0,80)")
def rewired(n: int, k: int, seed: int = 0,
            budget: int = DEFAULT_REWIRE_BUDGET) -> Topology:
    """Rewire-synthesized expander: double-edge-swap rho2 hill-climb at (n, k)."""
    res = synthesize(n, k, method="rewire", budget=budget, seed=seed)
    res.topo.meta["synthesis"] = res.to_dict()
    return res.topo
