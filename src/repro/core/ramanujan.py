"""Ramanujan graphs: the LPS construction X^{p,q} (§3.1.1) and certificates.

Definition 1: a k-regular G is Ramanujan iff lambda(G) <= 2 sqrt(k-1), where
lambda(G) is the largest-magnitude adjacency eigenvalue != ±k.

LPS (Lubotzky-Phillips-Sarnak): for distinct primes p, q ≡ 1 (mod 4), X^{p,q}
is the (q+1)-regular Cayley graph of PSL(2, F_p) (if q is a QR mod p; n =
p(p^2-1)/2, non-bipartite) or PGL(2, F_p) (otherwise; n = p(p^2-1), bipartite)
with generators built from the q+1 integer quaternion solutions of
a0^2+a1^2+a2^2+a3^2 = q with a0 odd positive, a1..a3 even.
"""
from __future__ import annotations

import math
from collections import Counter
from typing import List, Optional, Tuple

import numpy as np

from ..api.registry import register
from .graphs import Topology

__all__ = ["lps", "lps_size", "is_ramanujan", "ramanujan_bound", "alon_boppana_lb",
           "legendre"]

Mat = Tuple[int, int, int, int]  # row-major 2x2 over F_p


def legendre(a: int, p: int) -> int:
    """Legendre symbol (a/p) for odd prime p."""
    a %= p
    if a == 0:
        return 0
    return 1 if pow(a, (p - 1) // 2, p) == 1 else -1


def _sqrt_minus_one(p: int) -> int:
    """An integer i with i^2 ≡ -1 (mod p), p ≡ 1 (mod 4)."""
    for a in range(2, p):
        if legendre(a, p) == -1:
            return pow(a, (p - 1) // 4, p)
    raise ValueError("no quadratic non-residue found")


def _quaternion_solutions(q: int) -> List[Tuple[int, int, int, int]]:
    """All (a0,a1,a2,a3), a0 odd > 0, a1..a3 even, with sum of squares = q.

    Jacobi's four-square theorem gives exactly q+1 of them for prime
    q ≡ 1 (mod 4).
    """
    sols = set()
    r = int(math.isqrt(q))
    evens = [v for v in range(-r, r + 1) if v % 2 == 0]
    for a0 in range(1, r + 1, 2):
        for a1 in evens:
            s01 = q - a0 * a0 - a1 * a1
            if s01 < 0:
                continue
            for a2 in evens:
                rem = s01 - a2 * a2
                if rem < 0:
                    continue
                a3 = int(math.isqrt(rem))
                if a3 * a3 == rem and a3 % 2 == 0:
                    sols.add((a0, a1, a2, a3))
                    if a3:
                        sols.add((a0, a1, a2, -a3))
    out = sorted(sols)
    assert len(out) == q + 1, f"expected q+1={q + 1} solutions, got {len(out)}"
    return out


def _mul(m: Mat, g: Mat, p: int) -> Mat:
    a, b, c, d = m
    e, f, gg, h = g
    return ((a * e + b * gg) % p, (a * f + b * h) % p,
            (c * e + d * gg) % p, (c * f + d * h) % p)


def _canon(m: Mat, p: int) -> Mat:
    """Canonical PGL(2,p) representative: scale so first nonzero entry is 1."""
    for v in m:
        if v:
            inv = pow(v, p - 2, p)
            return tuple((x * inv) % p for x in m)  # type: ignore
    raise ValueError("zero matrix")


def lps_size(p: int, q: int) -> int:
    return p * (p * p - 1) // 2 if legendre(q, p) == 1 else p * (p * p - 1)


def _cf_lps(p: int, q: int) -> dict:
    """Registry closed forms: exact size/radix + the Ramanujan rho2 floor
    (Definition 1 gives lambda <= 2 sqrt(q), hence rho2 >= q + 1 - 2 sqrt(q))."""
    k = q + 1
    return dict(nodes=lps_size(p, q), radix=k,
                rho2_lb=k - 2.0 * math.sqrt(k - 1.0))


@register("lps", params=dict(p=int, q=int), closed_forms=_cf_lps,
          tags=("vertex_transitive",), aliases=("ramanujan",),
          default_instance="lps(5,13)")
def lps(p: int, q: int) -> Topology:
    """The LPS Ramanujan graph X^{p,q} (Definition 2)."""
    for x, nm in ((p, "p"), (q, "q")):
        if x % 4 != 1 or any(x % f == 0 for f in range(2, int(math.isqrt(x)) + 1)):
            raise ValueError(f"{nm}={x} must be a prime ≡ 1 (mod 4)")
    if p == q:
        raise ValueError("p and q must be distinct")
    i = _sqrt_minus_one(p)
    gens: List[Mat] = []
    for a0, a1, a2, a3 in _quaternion_solutions(q):
        gens.append(((a0 + i * a1) % p, (a2 + i * a3) % p,
                     (-a2 + i * a3) % p, (a0 - i * a1) % p))
    ident: Mat = (1, 0, 0, 1)
    index = {ident: 0}
    reps: List[Mat] = [ident]
    directed: Counter = Counter()
    head = 0
    while head < len(reps):
        m = reps[head]
        u = head
        for g in gens:
            key = _canon(_mul(m, g, p), p)
            v = index.get(key)
            if v is None:
                v = len(reps)
                index[key] = v
                reps.append(key)
            directed[(u, v)] += 1
        head += 1
    n = len(reps)
    expected = lps_size(p, q)
    assert n == expected, f"LPS({p},{q}): enumerated {n} != expected {expected}"
    # S is symmetric (the conjugate quaternion is the inverse generator), so the
    # directed multiset satisfies directed[(u,v)] == directed[(v,u)]; the
    # undirected multiplicity of {u,v} is directed[(u,v)] (one generator per
    # incident edge-end, Cayley degree = |S| = q+1).
    edges = []
    loops = np.zeros(n)
    for (u, v), c in sorted(directed.items()):
        if u == v:
            loops[u] += c        # identity generators (rare; only if p^2 | q - a0^2)
        elif u < v:
            assert directed[(v, u)] == c, "generator set not symmetric"
            edges.extend([(u, v)] * c)
    topo = Topology(f"lps({p},{q})", n, np.array(edges, dtype=np.int64),
                    loops=loops if loops.any() else None,
                    meta=dict(p=p, q=q, bipartite=legendre(q, p) == -1, k=q + 1))
    return topo


def ramanujan_bound(k: int) -> float:
    """2 sqrt(k-1): the Alon–Boppana asymptotic optimum."""
    return 2.0 * math.sqrt(k - 1)


def alon_boppana_lb(k: int, diam: int) -> float:
    """lambda >= 2 sqrt(k-1) (1 - 2/D) - 2/D  (§3, Alon–Boppana theorem)."""
    return 2.0 * math.sqrt(k - 1) * (1 - 2.0 / diam) - 2.0 / diam


def is_ramanujan(topo: Topology, spectrum: Optional[np.ndarray] = None,
                 tol: float = 1e-8) -> Tuple[bool, float]:
    """Certificate: returns (is_ramanujan, lambda(G)).

    ``spectrum``: optional precomputed adjacency spectrum (ascending).
    Excludes eigenvalues equal to ±k (trivial / bipartite-trivial).
    """
    k = topo.radix
    if spectrum is None:
        spectrum = np.linalg.eigvalsh(topo.adjacency())
    nontriv = spectrum[np.abs(np.abs(spectrum) - k) > 1e-6]
    lam = float(np.max(np.abs(nontriv)))
    return bool(lam <= ramanujan_bound(k) + tol), lam
