"""ML-workload lowering: model config + parallelism spec -> executed step time.

The paper's claim is that the spectral gap predicts interconnect performance;
:mod:`repro.core.simulate` (PR 5) executes synthetic collectives, but a real
training job is a *mix* of collectives with byte counts fixed by the model
architecture and the parallelism layout.  This module lowers the dormant seed
model stack into that mix:

1. :func:`parse_workload` parses ``"kimi_k2_1t@dp=64,tp=8,ep=16"`` into a
   :class:`WorkloadSpec` — an architecture from :mod:`repro.configs` plus a
   (data, tensor, expert)-parallel layout over ``world = dp * tp`` ranks.
2. :func:`plan_workload` emits the per-training-step :class:`CommPlan`: one
   :class:`CommPhase` per collective stream, with closed-form byte counts —

   * **DP gradient all-reduce** — sized by the analytic parameter count
     (``ArchConfig.param_count``), divided by each parameter's tensor-parallel
     shard factor read from the *live* sharding rules
     (:func:`repro.parallel.sharding.param_pspecs`), and bucketized
     (:data:`BUCKET_BYTES`);
   * **TP all-gather / reduce-scatter per layer** — one pair per
     ``'model'``-sharded matmul pair found in the sharding rules (attention
     wq/wo, dense-MLP wg/wd, mamba in_proj/out_proj), moving the full
     activation ``tokens x d_model`` per direction (sequence-parallel
     lowering of the Megatron all-reduce), forward and backward;
   * **MoE all-to-all** — the padded ``(E, C, D)`` slot-tensor exchange of
     :mod:`repro.parallel.ep_moe` (capacity ``C`` from
     :func:`repro.models.moe.capacity`), dispatched in
     ``cfg.moe_dispatch_dtype`` and returned/back-propagated in the compute
     dtype, over expert-parallel groups of size ``ep`` carved from the data
     axis.

3. :func:`simulate_workload` compiles the plan onto ANY topology: logical
   ranks map to physical nodes via :func:`repro.core.placement.place_ranks`,
   each phase lowers to a logical demand matrix (ring rounds for
   all-reduce/all-gather/reduce-scatter, the full pair demand for
   all-to-all), and :func:`repro.core.simulate._lower_demand_rounds` ECMP-routes
   it onto the padded gather-table slots the round engine drains.
4. :func:`hlo_crosscheck` re-emits the plan as a synthetic post-partitioning
   HLO module (:meth:`CommPlan.to_hlo`) and checks the per-kind byte totals
   against the independent :func:`repro.launch.hlo_analysis.analyze_hlo`
   accounting.

Units: bytes for payloads, seconds for times, tokens = sequence positions.
Modeled: the three phase families above, compute time from the 6*N*T FLOP
convention (:data:`repro.launch.hlo_analysis.HW` peak), DP/backward overlap
(:data:`DP_OVERLAP_FRACTION`).  NOT modeled: embedding/loss collectives,
router aux losses, pipeline parallelism, HBM time (see docs/workloads.md).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro import obs

from .collectives import LINK_BW, PER_HOP_LATENCY
from .graphs import Topology
from .placement import place_ranks
from .routing import DEFAULT_SOURCE_CHUNK, RoutingResult, analyze_routing
from .simulate import Schedule, _lower_demand_rounds, _unpack_topo, run_schedule

__all__ = [
    "WorkloadSpec", "WorkloadSpecError", "CommPhase", "CommPlan",
    "WorkloadResult", "parse_workload", "plan_workload", "simulate_workload",
    "hlo_crosscheck", "spectral_rank_correlation", "BUCKET_BYTES",
    "DP_OVERLAP_FRACTION",
]

#: DP gradient all-reduce bucket size (bytes) — the plan splits the gradient
#: into ceil(total/BUCKET_BYTES) equal all-reduces, the standard overlap
#: granularity of data-parallel trainers.
BUCKET_BYTES = float(1 << 27)

#: fraction of the compute step the DP gradient all-reduce can hide behind
#: (the backward pass is ~2/3 of a fwd+bwd step at the 6*N*T FLOP convention,
#: and gradient buckets stream out as backward produces them).
DP_OVERLAP_FRACTION = 2.0 / 3.0

_DTYPE_BYTES = {
    "float32": 4, "bfloat16": 2, "float16": 2,
    "float8_e4m3fn": 1, "float8_e5m2": 1,
}

#: jax dtype name -> HLO shape element type (repro.launch.hlo_analysis keys)
_HLO_DTYPE = {
    "float32": "f32", "bfloat16": "bf16", "float16": "f16",
    "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2",
}

#: phase collective -> HLO instruction mnemonic (analyze_hlo's accounting
#: keys: all-gather counts the gathered OUTPUT bytes, the rest sum operands)
_HLO_OP = {
    "all_reduce": "all-reduce", "all_gather": "all-gather",
    "reduce_scatter": "reduce-scatter", "all_to_all": "all-to-all",
}

_DEFAULT_SHAPE = "train_4k"


class WorkloadSpecError(ValueError):
    """Malformed or inconsistent workload spec string."""


# --------------------------------------------------------------------------
# spec parsing
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One parsed training-job layout: architecture + parallelism degrees.

    ``arch`` is the canonical :mod:`repro.configs` registry name;
    ``dp``/``tp``/``ep`` are the data-, tensor- and expert-parallel degrees
    (``world = dp * tp``; EP groups are size-``ep`` slices of the data axis);
    ``shape`` names the :data:`repro.configs.base.SHAPES` training shape.
    """
    arch: str
    dp: int = 1
    tp: int = 1
    ep: int = 1
    shape: str = _DEFAULT_SHAPE

    @property
    def world(self) -> int:
        """Total rank count dp * tp (EP reuses data-axis ranks)."""
        return self.dp * self.tp

    @property
    def spec(self) -> str:
        """Canonical round-trippable spec string."""
        s = f"{self.arch}@dp={self.dp},tp={self.tp},ep={self.ep}"
        if self.shape != _DEFAULT_SHAPE:
            s += f",shape={self.shape}"
        return s


def _norm(name: str) -> str:
    return name.strip().lower().replace("-", "_").replace(".", "_")


def _resolve_arch(name: str) -> str:
    """Registry name from a normalized exact or unique-prefix match."""
    from repro.configs.base import list_configs

    want = _norm(name)
    if not want:
        raise WorkloadSpecError("workload spec needs a model name before '@'")
    names = list_configs()
    exact = [c for c in names if _norm(c) == want]
    if exact:
        return exact[0]
    prefixed = [c for c in names if _norm(c).startswith(want)]
    if len(prefixed) == 1:
        return prefixed[0]
    if prefixed:
        raise WorkloadSpecError(
            f"ambiguous model name {name!r}: matches {prefixed}")
    raise WorkloadSpecError(
        f"unknown model {name!r}; registered configs: {names}")


def _positive_int(key: str, value: str) -> int:
    try:
        v = int(value)
    except ValueError:
        raise WorkloadSpecError(f"{key}= must be an integer, got {value!r}") \
            from None
    if v < 1:
        raise WorkloadSpecError(f"{key}= must be >= 1, got {v}")
    return v


def parse_workload(spec: Union[str, "WorkloadSpec"]) -> "WorkloadSpec":
    """Parse ``"kimi_k2_1t@dp=64,tp=8,ep=16"`` into a :class:`WorkloadSpec`.

    Grammar: ``<model>[@<key>=<value>,...]`` with keys ``dp``/``tp``/``ep``
    (positive ints, default 1) and ``shape`` (a ``kind="train"`` entry of
    :data:`repro.configs.base.SHAPES`, default ``train_4k``).  ``<model>`` is
    a registry name, matched case-insensitively with ``-``/``.``/``_``
    interchangeable; a unique prefix (``kimi_k2_1t`` for ``kimi-k2-1t-a32b``)
    resolves too.

    Validated invariants (raising :class:`WorkloadSpecError`):
    ``global_batch % dp == 0``; ``ep > 1`` needs an MoE arch with
    ``dp % ep == 0`` and ``n_experts % ep == 0``.
    """
    if isinstance(spec, WorkloadSpec):
        return spec
    from repro.configs.base import SHAPES, get_config

    name, _, params = str(spec).partition("@")
    kv: Dict[str, Any] = dict(dp=1, tp=1, ep=1, shape=_DEFAULT_SHAPE)
    if params.strip():
        for part in params.split(","):
            key, sep, value = part.partition("=")
            key = key.strip().lower()
            if not sep or not value.strip():
                raise WorkloadSpecError(
                    f"bad workload parameter {part!r} (expect key=value)")
            if key in ("dp", "tp", "ep"):
                kv[key] = _positive_int(key, value.strip())
            elif key == "shape":
                kv["shape"] = value.strip()
            else:
                raise WorkloadSpecError(
                    f"unknown workload key {key!r} (known: dp, tp, ep, shape)")
    arch = _resolve_arch(name)
    cfg = get_config(arch)
    if kv["shape"] not in SHAPES:
        raise WorkloadSpecError(
            f"unknown shape {kv['shape']!r} (known: {sorted(SHAPES)})")
    shape = SHAPES[kv["shape"]]
    if shape.kind != "train":
        raise WorkloadSpecError(
            f"workload shapes must be training shapes, {shape.name!r} is "
            f"kind={shape.kind!r}")
    if shape.global_batch % kv["dp"]:
        raise WorkloadSpecError(
            f"dp={kv['dp']} must divide global_batch={shape.global_batch} "
            f"of shape {shape.name!r}")
    if kv["ep"] > 1:
        if cfg.n_experts == 0:
            raise WorkloadSpecError(
                f"{arch} has no experts; ep={kv['ep']} needs an MoE arch")
        if kv["dp"] % kv["ep"]:
            raise WorkloadSpecError(
                f"ep={kv['ep']} must divide dp={kv['dp']} (EP groups are "
                "slices of the data axis)")
        if cfg.n_experts % kv["ep"]:
            raise WorkloadSpecError(
                f"ep={kv['ep']} must divide n_experts={cfg.n_experts}")
    return WorkloadSpec(arch=arch, dp=kv["dp"], tp=kv["tp"], ep=kv["ep"],
                        shape=kv["shape"])


# --------------------------------------------------------------------------
# sharding-rule consultation
# --------------------------------------------------------------------------

class _LogicalMesh:
    """Duck-typed stand-in for ``jax.sharding.Mesh`` carrying only the two
    attributes :mod:`repro.parallel.sharding` reads (``shape``,
    ``axis_names``) — so the workload planner consults the LIVE sharding
    rules without materializing dp*tp devices."""

    def __init__(self, dp: int, tp: int) -> None:
        self.axis_names = ("data", "model")
        self.shape = {"data": dp, "model": tp}


def _iter_param_specs(spec: WorkloadSpec) -> Iterator[Tuple[str, Tuple[int, ...], Any]]:
    """Yield (name, shape, PartitionSpec) for every parameter leaf, pairing
    :func:`repro.models.model.param_shapes` with the PartitionSpecs that
    :func:`repro.parallel.sharding.param_pspecs` assigns on the logical
    (data=dp, model=tp) mesh."""
    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.parallel import sharding

    cfg = get_config(spec.arch)
    mesh = _LogicalMesh(spec.dp, spec.tp)
    shapes = M.param_shapes(cfg)
    pspecs = sharding.param_pspecs(cfg, mesh)

    def walk(sh, ps, name=""):
        if isinstance(sh, M.Shape):
            yield name, tuple(sh), ps
        elif isinstance(sh, dict):
            for key in sh:
                yield from walk(sh[key], ps[key], key)
        else:  # list of pattern-position blocks
            for s, p in zip(sh, ps):
                yield from walk(s, p, name)

    yield from walk(shapes, pspecs)


def _model_shard_factor(pspec: Any, tp: int) -> int:
    """Product of 'model' mesh-axis sizes a PartitionSpec consumes (the
    tensor-parallel shard factor of that parameter)."""
    factor = 1
    for entry in tuple(pspec):
        axes = entry if isinstance(entry, tuple) else (entry,)
        for ax in axes:
            if ax == "model":
                factor *= tp
    return factor


def _has_model_axis(pspec: Any) -> bool:
    return _model_shard_factor(pspec, 2) > 1


# --------------------------------------------------------------------------
# the communication plan
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CommPhase:
    """One collective stream of a training step.

    ``bytes_per_rank`` is the logical payload per op in the HLO accounting
    convention (all-reduce / reduce-scatter / all-to-all: operand bytes;
    all-gather: gathered output bytes); ``ops_per_step`` repeats it;
    ``group_axis`` in {"dp", "tp", "ep"} picks the rank grouping (group size
    ``group_size``, ``n_groups`` concurrent groups).
    """
    name: str
    collective: str          # all_reduce | all_gather | reduce_scatter | all_to_all
    group_axis: str          # dp | tp | ep
    group_size: int
    n_groups: int
    bytes_per_rank: float
    ops_per_step: int
    dtype: str
    note: str = ""

    @property
    def total_bytes(self) -> float:
        """Logical payload bytes per rank over the whole step."""
        return self.bytes_per_rank * self.ops_per_step


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """The per-training-step communication plan of one workload.

    ``phases`` hold the closed-form byte counts; ``compute_seconds`` is the
    topology-independent FLOP term (6 * active params * tokens per rank at
    :data:`repro.launch.hlo_analysis.HW` peak).  Compile onto a topology with
    :func:`simulate_workload`; audit the byte accounting with
    :func:`hlo_crosscheck`.
    """
    spec: WorkloadSpec
    world: int
    tokens_per_step: int          # global tokens (batch * seq)
    tokens_per_rank: int          # per data shard
    param_bytes: float            # total parameter bytes (param_dtype)
    grad_bytes_per_rank: float    # DP all-reduce operand bytes per rank
    phases: Tuple[CommPhase, ...]
    flops_per_rank: float
    compute_seconds: float

    def phase(self, name: str) -> CommPhase:
        for p in self.phases:
            if p.name == name:
                return p
        raise KeyError(f"no phase {name!r} in plan (have: "
                       f"{[p.name for p in self.phases]})")

    def collective_byte_totals(self) -> Dict[str, float]:
        """Per-HLO-kind logical byte totals (the figures
        :func:`repro.launch.hlo_analysis.analyze_hlo` recovers from
        :meth:`to_hlo`)."""
        out: Dict[str, float] = {}
        for p in self.phases:
            kind = _HLO_OP[p.collective]
            out[kind] = out.get(kind, 0.0) + p.total_bytes
        return out

    def to_hlo(self) -> str:
        """Synthetic post-partitioning HLO text with one collective per phase
        (repeated ops as a while loop with ``known_trip_count``), shaped so
        the independent parser of :mod:`repro.launch.hlo_analysis` recovers
        exactly :meth:`collective_byte_totals`."""
        lines = [f"HloModule workload_{_norm(self.spec.arch)}", ""]
        entry: List[str] = []
        for i, p in enumerate(self.phases):
            dt = _HLO_DTYPE[p.dtype]
            numel = p.bytes_per_rank / _DTYPE_BYTES[p.dtype]
            trips = p.ops_per_step
            if abs(numel - round(numel)) > 1e-6:
                # bucketized phases can have fractional per-op element
                # counts; collapse to one instruction carrying the exact
                # phase total so the parsed bytes still match
                numel *= trips
                trips = 1
            numel = int(round(numel))
            shape = f"{dt}[{numel}]"
            op = _HLO_OP[p.collective]
            body = f"wl_body.{i}"
            cond = f"wl_cond.{i}"
            lines += [
                f"%{cond} (carg.{i}: {shape}) -> pred[] {{",
                f"  %clt.{i} = pred[] constant(false)",
                "}", "",
                f"%{body} (barg.{i}: {shape}) -> {shape} {{",
                f"  %arg.{i} = {shape} parameter(0)",
                f"  ROOT %coll.{i} = {shape} {op}(%arg.{i})",
                "}", "",
            ]
            entry.append(
                f"  %init.{i} = {shape} constant(0)")
            entry.append(
                f"  %while.{i} = {shape} while(%init.{i}), "
                f"condition=%{cond}, body=%{body}, backend_config="
                f'{{"known_trip_count":{{"n":"{trips}"}}}}')
        lines += ["ENTRY %main () -> f32[] {"] + entry + [
            "  ROOT %done = f32[] constant(0)", "}"]
        return "\n".join(lines)

    def report(self) -> str:
        """Compact text block for CLI reports."""
        s = self.spec
        lines = [
            f"workload        : {s.spec}",
            f"ranks           : {self.world} (dp={s.dp} x tp={s.tp}, "
            f"ep={s.ep})",
            f"tokens/step     : {self.tokens_per_step:,} "
            f"({self.tokens_per_rank:,} per data shard)",
            f"compute/rank    : {self.flops_per_rank / 1e12:.1f} TFLOP "
            f"-> {self.compute_seconds * 1e3:.2f} ms at HW peak",
        ]
        for p in self.phases:
            lines.append(
                f"  {p.name:<16}: {p.collective} x{p.ops_per_step} over "
                f"{p.group_axis} groups of {p.group_size}, "
                f"{p.bytes_per_rank / 1e6:.2f} MB/op ({p.dtype})")
        return "\n".join(lines)


@obs.traced("workloads/plan", phase="compile")
def plan_workload(spec: Union[str, WorkloadSpec]) -> CommPlan:
    """Lower one workload spec into its per-step :class:`CommPlan`.

    Byte counts come from the seed model stack (see the module docstring);
    every count is closed-form, so tests can pin them exactly:

    * DP all-reduce total == parameter bytes / TP shard factor (== parameter
      bytes when ``tp == 1``);
    * each TP all-gather/reduce-scatter op moves ``tokens_per_rank * d_model``
      activation elements;
    * each MoE all-to-all op moves the padded slot tensor
      ``groups_per_rank * E * capacity * d_model/tp`` elements.
    """
    from repro.configs.base import SHAPES, get_config
    from repro.launch.hlo_analysis import HW
    from repro.models.moe import capacity

    ws = parse_workload(spec)
    cfg = get_config(ws.arch)
    shape = SHAPES[ws.shape]
    dp, tp, ep = ws.dp, ws.tp, ws.ep
    world = ws.world
    tokens = shape.global_batch * shape.seq_len
    tokens_rank = tokens // dp
    grad_bytes = _DTYPE_BYTES[cfg.param_dtype]
    comp_bytes = _DTYPE_BYTES[cfg.compute_dtype]

    # -- DP gradient all-reduce, sized through the live sharding rules ------
    param_elems = 0
    grad_elems_per_rank = 0.0
    tp_pairs_per_block: Dict[int, int] = {}
    for name, sh, ps in _iter_param_specs(ws):
        numel = int(np.prod(sh))
        param_elems += numel
        grad_elems_per_rank += numel / _model_shard_factor(ps, tp)
    # TP matmul pairs per pattern position: re-walk block-structured pspecs
    from repro.parallel import sharding
    pspecs = sharding.param_pspecs(cfg, _LogicalMesh(dp, tp))
    for i, blk in enumerate(pspecs["blocks"]):
        pairs = 0
        if "attn" in blk and _has_model_axis(blk["attn"]["wq"]):
            pairs += 1
        if "mamba" in blk and _has_model_axis(blk["mamba"]["in_proj"]):
            pairs += 1
        if "mlp" in blk and _has_model_axis(blk["mlp"]["wg"]):
            pairs += 1
        tp_pairs_per_block[i] = pairs
    blocks_seen = len(pspecs["blocks"])
    assert blocks_seen == len(cfg.pattern)

    total_grad_bytes = grad_elems_per_rank * grad_bytes
    n_buckets = max(1, int(math.ceil(total_grad_bytes / BUCKET_BYTES)))
    phases: List[CommPhase] = []
    if dp > 1:
        phases.append(CommPhase(
            name="dp_allreduce", collective="all_reduce", group_axis="dp",
            group_size=dp, n_groups=tp,
            bytes_per_rank=total_grad_bytes / n_buckets,
            ops_per_step=n_buckets, dtype=cfg.param_dtype,
            note=f"gradient bucketized x{n_buckets} "
                 f"({BUCKET_BYTES / 1e6:.0f} MB buckets)"))

    # -- TP per-layer all-gather + reduce-scatter ---------------------------
    if tp > 1:
        n_pairs = sum(tp_pairs_per_block[i] * cfg.n_repeats
                      for i in range(len(cfg.pattern)))
        if n_pairs:
            act_bytes = float(tokens_rank) * cfg.d_model * comp_bytes
            # fwd + bwd: 2 sequence-parallel all-reduces per pair, each
            # lowered as one all-gather + one reduce-scatter of the full
            # activation
            ops = 2 * n_pairs
            phases.append(CommPhase(
                name="tp_allgather", collective="all_gather", group_axis="tp",
                group_size=tp, n_groups=dp, bytes_per_rank=act_bytes,
                ops_per_step=ops, dtype=cfg.compute_dtype,
                note=f"{n_pairs} model-sharded matmul pairs"))
            phases.append(CommPhase(
                name="tp_reducescatter", collective="reduce_scatter",
                group_axis="tp", group_size=tp, n_groups=dp,
                bytes_per_rank=act_bytes, ops_per_step=ops,
                dtype=cfg.compute_dtype,
                note=f"{n_pairs} model-sharded matmul pairs"))

    # -- MoE all-to-all over EP groups --------------------------------------
    moe_layers = sum(1 for s in cfg.pattern if s.moe) * cfg.n_repeats
    if ep > 1 and moe_layers:
        E, k = cfg.n_experts, cfg.experts_per_token
        C = capacity(shape.seq_len, E, k, cfg.capacity_factor)
        groups_per_rank = shape.global_batch // dp
        d_share = cfg.d_model // tp if cfg.d_model % tp == 0 else cfg.d_model
        slots = groups_per_rank * E * C
        phases.append(CommPhase(
            name="moe_dispatch", collective="all_to_all", group_axis="ep",
            group_size=ep, n_groups=(dp // ep) * tp,
            bytes_per_rank=float(slots) * d_share
                * _DTYPE_BYTES[cfg.moe_dispatch_dtype],
            ops_per_step=moe_layers, dtype=cfg.moe_dispatch_dtype,
            note=f"E={E} C={C} padded slots, fwd dispatch"))
        phases.append(CommPhase(
            name="moe_combine", collective="all_to_all", group_axis="ep",
            group_size=ep, n_groups=(dp // ep) * tp,
            bytes_per_rank=float(slots) * d_share * comp_bytes,
            ops_per_step=3 * moe_layers, dtype=cfg.compute_dtype,
            note="fwd return + bwd dispatch/return"))

    flops = 6.0 * cfg.active_param_count() * tokens / world
    return CommPlan(
        spec=ws, world=world, tokens_per_step=tokens,
        tokens_per_rank=tokens_rank,
        param_bytes=float(param_elems) * grad_bytes,
        grad_bytes_per_rank=total_grad_bytes,
        phases=tuple(phases), flops_per_rank=flops,
        compute_seconds=flops / HW["peak_flops"])


# --------------------------------------------------------------------------
# rank groups and logical demand
# --------------------------------------------------------------------------

def _phase_groups(plan: CommPlan, axis: str) -> List[np.ndarray]:
    """Rank-id groups for one group axis.  Rank layout: ``r = d * tp + t``
    (TP fastest-varying, so TP groups are contiguous rank blocks)."""
    dp, tp, ep = plan.spec.dp, plan.spec.tp, plan.spec.ep
    if axis == "tp":
        return [np.arange(d * tp, (d + 1) * tp) for d in range(dp)]
    if axis == "dp":
        return [np.arange(dp) * tp + t for t in range(tp)]
    if axis == "ep":
        return [(b * ep + np.arange(ep)) * tp + t
                for b in range(dp // ep) for t in range(tp)]
    raise ValueError(f"unknown group axis {axis!r}")


def _phase_demand(phase: CommPhase, groups: List[np.ndarray],
                  node_of: np.ndarray, n: int) -> Tuple[np.ndarray, int]:
    """(node-level logical demand matrix, round count) for one phase.

    Ring lowering for all-reduce (2(g-1) rounds of 1/g payload per edge),
    all-gather / reduce-scatter (g-1 rounds); full pair demand in a single
    round for all-to-all.  Demand between ranks co-located on one node is
    free (diagonal, dropped by the ECMP lowering).
    """
    D = np.zeros((n, n), dtype=np.float64)
    g = phase.group_size
    if phase.collective == "all_to_all":
        per_pair = phase.bytes_per_rank / g
        for grp in groups:
            nodes = node_of[grp]
            for a in nodes:
                D[a, nodes] += per_pair
        rounds = phase.ops_per_step
    else:
        per_edge = phase.bytes_per_rank / g
        for grp in groups:
            nodes = node_of[grp]
            D[nodes, np.roll(nodes, -1)] += per_edge
        per_op = 2 * (g - 1) if phase.collective == "all_reduce" else g - 1
        rounds = per_op * phase.ops_per_step
    np.fill_diagonal(D, 0.0)
    return D, rounds


# --------------------------------------------------------------------------
# executing a plan on a topology
# --------------------------------------------------------------------------

@dataclasses.dataclass
class WorkloadResult:
    """Executed step-time breakdown of one plan on one topology.

    ``phase_rows`` carry the measured per-phase link time (seconds);
    ``step_seconds`` composes them with the compute term: TP and MoE
    collectives sit on the critical path, the DP all-reduce overlaps with
    :data:`DP_OVERLAP_FRACTION` of compute and only its exposed remainder
    counts.  ``exposed_comm_fraction = (step - compute) / step``.
    """
    plan: CommPlan
    name: str                       # topology name
    n: int
    placement: str
    phase_rows: List[Dict[str, Any]]
    compute_seconds: float
    comm_seconds: float             # sum of all phase link times
    dp_seconds: float
    tp_seconds: float
    moe_seconds: float
    exposed_dp_seconds: float
    step_seconds: float
    exposed_comm_fraction: float
    dropped_frac: float             # demand to unreachable node pairs
    seconds: float                  # wall time (lowering + engine)

    def phase_seconds(self) -> Dict[str, float]:
        """phase name -> measured link seconds."""
        return {r["name"]: r["seconds"] for r in self.phase_rows}

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready summary."""
        return dict(
            workload=self.plan.spec.spec, topology=self.name, n=self.n,
            placement=self.placement, world=self.plan.world,
            compute_ms=round(self.compute_seconds * 1e3, 6),
            comm_ms=round(self.comm_seconds * 1e3, 6),
            dp_ms=round(self.dp_seconds * 1e3, 6),
            tp_ms=round(self.tp_seconds * 1e3, 6),
            moe_ms=round(self.moe_seconds * 1e3, 6),
            exposed_dp_ms=round(self.exposed_dp_seconds * 1e3, 6),
            step_ms=round(self.step_seconds * 1e3, 6),
            exposed_comm_fraction=round(self.exposed_comm_fraction, 6),
            dropped_frac=round(self.dropped_frac, 6),
            phases=[dict(r, seconds=round(r["seconds"], 9))
                    for r in self.phase_rows],
            seconds=round(self.seconds, 3))

    def report(self) -> str:
        """Compact text block for CLI reports."""
        lines = [
            f"workload        : {self.plan.spec.spec} on {self.name} "
            f"(n={self.n}, {self.plan.world} ranks, "
            f"placement={self.placement})",
            f"step time       : {self.step_seconds * 1e3:.3f} ms "
            f"(compute {self.compute_seconds * 1e3:.3f} + comm exposed "
            f"{(self.step_seconds - self.compute_seconds) * 1e3:.3f})",
            f"exposed comm    : {self.exposed_comm_fraction:.1%} of the step",
        ]
        for r in self.phase_rows:
            lines.append(
                f"  {r['name']:<16}: {r['seconds'] * 1e3:8.3f} ms "
                f"({r['collective']}, {r['rounds']} rounds)")
        if self.dropped_frac > 0:
            lines.append(f"dropped demand  : {self.dropped_frac:.2%} "
                         "(unreachable pairs)")
        return "\n".join(lines)


@obs.traced("workloads/simulate", phase="execute")
def simulate_workload(topo: Union[Topology, Tuple[np.ndarray, int]],
                      workload: Union[str, WorkloadSpec, CommPlan], *,
                      placement: str = "linear", seed: int = 0,
                      routing: Optional[RoutingResult] = None,
                      link_bw: float = LINK_BW,
                      hop_latency: float = PER_HOP_LATENCY,
                      overlap_fraction: float = DP_OVERLAP_FRACTION,
                      chunk: int = DEFAULT_SOURCE_CHUNK) -> WorkloadResult:
    """Compile a communication plan onto a topology and execute it.

    Ranks map to nodes via :func:`repro.core.placement.place_ranks`
    (``placement`` strategy, co-located traffic free); each phase's logical
    demand is ECMP-lowered onto the padded gather-table slots and run through
    the jitted round engine of :mod:`repro.core.simulate` at the plan's real
    byte counts.

    Args:
        topo: a :class:`Topology` or ``(table, n)`` padded pair (the degraded
            entry point used by :func:`repro.core.faults.fault_sweep`).
        workload: spec string, :class:`WorkloadSpec`, or prebuilt
            :class:`CommPlan`.
        placement: rank->node strategy (``linear`` / ``round_robin`` /
            ``random``; see :func:`repro.core.placement.place_ranks`).
        seed: placement RNG seed (``random`` strategy only).
        routing: reuse an all-sources :class:`RoutingResult`.
        link_bw / hop_latency: engine constants (bytes/s, s/hop).
        overlap_fraction: fraction of compute the DP all-reduce hides behind.
        chunk: ECMP sources per jitted call (memory knob).

    Returns:
        :class:`WorkloadResult` with the per-phase and composed step times.
    """
    t0 = time.time()
    plan = workload if isinstance(workload, CommPlan) else \
        plan_workload(workload)
    name, n, table = _unpack_topo(topo)
    if routing is None:
        routing = analyze_routing((table, n), chunk=chunk)
    node_of = place_ranks(n, plan.world, strategy=placement, seed=seed)
    phase_rows: List[Dict[str, Any]] = []
    axis_seconds = {"dp": 0.0, "tp": 0.0, "ep": 0.0}
    dropped_total = 0.0
    demand_total = 0.0
    for phase in plan.phases:
        groups = _phase_groups(plan, phase.group_axis)
        D, rounds = _phase_demand(phase, groups, node_of, n)
        lowered, counts, hops, dropped = _lower_demand_rounds(
            table, routing, [(D, rounds, 1.0)], chunk)
        sched = Schedule(
            name=name, collective=f"workload:{phase.name}", algorithm="ecmp",
            n=n, k=int(table.shape[1]), round_bytes=lowered, counts=counts,
            hops=hops, dropped_demand=dropped)
        res = run_schedule(sched, payloads=1.0, link_bw=link_bw,
                           hop_latency=hop_latency)
        secs = float(res.time_seconds[0])
        axis_seconds[phase.group_axis] += secs
        dropped_total += dropped
        demand_total += rounds * float(D.sum())
        phase_rows.append(dict(
            name=phase.name, collective=phase.collective,
            group_axis=phase.group_axis, group_size=phase.group_size,
            ops=phase.ops_per_step, rounds=int(rounds),
            bytes_per_rank=phase.bytes_per_rank, dtype=phase.dtype,
            seconds=secs,
            max_link_bytes=float(lowered.max())))
    dp_s, tp_s, moe_s = (axis_seconds["dp"], axis_seconds["tp"],
                         axis_seconds["ep"])
    exposed_dp = max(0.0, dp_s - overlap_fraction * plan.compute_seconds)
    step = plan.compute_seconds + tp_s + moe_s + exposed_dp
    return WorkloadResult(
        plan=plan, name=name, n=n, placement=placement,
        phase_rows=phase_rows, compute_seconds=plan.compute_seconds,
        comm_seconds=dp_s + tp_s + moe_s, dp_seconds=dp_s, tp_seconds=tp_s,
        moe_seconds=moe_s, exposed_dp_seconds=exposed_dp, step_seconds=step,
        exposed_comm_fraction=(step - plan.compute_seconds) / step
            if step > 0 else 0.0,
        dropped_frac=dropped_total / demand_total if demand_total > 0 else 0.0,
        seconds=time.time() - t0)


# --------------------------------------------------------------------------
# byte-accounting cross-check against launch/hlo_analysis
# --------------------------------------------------------------------------

def hlo_crosscheck(plan: Union[str, WorkloadSpec, CommPlan],
                   rel_tol: float = 1e-9) -> Dict[str, Any]:
    """Audit the plan's byte accounting against the independent HLO parser.

    Emits the plan as synthetic HLO (:meth:`CommPlan.to_hlo`), runs
    :func:`repro.launch.hlo_analysis.analyze_hlo` over the text, and compares
    the recovered per-kind collective bytes against
    :meth:`CommPlan.collective_byte_totals`.

    Returns a dict with ``ok`` plus per-kind
    ``{plan_bytes, hlo_bytes, ok}`` rows.
    """
    from repro.launch.hlo_analysis import analyze_hlo

    if not isinstance(plan, CommPlan):
        plan = plan_workload(plan)
    stats = analyze_hlo(plan.to_hlo())
    want = plan.collective_byte_totals()
    rows: Dict[str, Dict[str, Any]] = {}
    ok = True
    for kind in sorted(set(want) | {k for k, v in
                                    stats.collective_bytes.items() if v}):
        p = want.get(kind, 0.0)
        h = stats.collective_bytes.get(kind, 0.0)
        good = abs(p - h) <= rel_tol * max(1.0, abs(p))
        ok &= good
        rows[kind] = dict(plan_bytes=p, hlo_bytes=h, ok=good)
    return dict(ok=ok, kinds=rows)


# --------------------------------------------------------------------------
# spectral-prediction agreement
# --------------------------------------------------------------------------

def spectral_rank_correlation(rows: Sequence[Dict[str, Any]],
                              rho2_key: str = "rho2",
                              step_key: str = "step_ms") -> Optional[float]:
    """Spearman rank correlation between the spectral gap and SLOWNESS.

    Larger rho2 should mean a *smaller* step time, so the correlation between
    the rho2 ranking (descending) and the step-time ranking (ascending) is
    +1 when the spectral prediction orders the executed workload perfectly.
    Returns None with fewer than 2 rows.
    """
    pairs = [(float(r[rho2_key]), float(r[step_key])) for r in rows
             if r.get(rho2_key) is not None and r.get(step_key) is not None]
    if len(pairs) < 2:
        return None
    rho2 = np.array([p[0] for p in pairs])
    step = np.array([p[1] for p in pairs])

    def ranks(x: np.ndarray) -> np.ndarray:
        order = np.argsort(x, kind="stable")
        r = np.empty(len(x))
        r[order] = np.arange(len(x), dtype=np.float64)
        # average ties so the statistic is exact for tied values
        for v in np.unique(x):
            m = x == v
            if m.sum() > 1:
                r[m] = r[m].mean()
        return r

    a = ranks(-rho2)      # best gap first
    b = ranks(step)       # fastest step first
    a = a - a.mean()
    b = b - b.mean()
    denom = float(np.sqrt((a * a).sum() * (b * b).sum()))
    if denom == 0.0:
        return None
    return float((a * b).sum() / denom)
