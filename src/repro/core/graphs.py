"""Core graph representation for interconnect topologies.

Graphs are small host-side objects (numpy edge lists).  All *device-scale*
numerics (Lanczos, matvec) consume the derived ``neighbor_table`` which is the
gather-friendly form used by the JAX/Pallas spectral layer.

Conventions
-----------
* Undirected multigraphs with optional weighted self-loops.  Self-loops
  contribute their weight once to the adjacency diagonal (paper convention:
  a self-loop regularizes the degree but never affects bisection/diameter).
* ``edges``  : (m, 2) int64 array of undirected edges (u, v), u != v.
             Parallel edges are repeated rows.  (``__post_init__`` casts to
             int64; the int32 narrowing happens only in ``neighbor_table`` /
             ``gather_operands``, the device-facing forms.)
* ``loops``  : (n,) float64 array of self-loop weights (usually 0/1, may be -1
             for the signed graphs of the CCC analysis).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

__all__ = ["Topology"]


@dataclasses.dataclass
class Topology:
    name: str
    n: int
    edges: np.ndarray                      # (m, 2) int64, u != v
    loops: Optional[np.ndarray] = None     # (n,) float64 self-loop weights
    meta: Dict = dataclasses.field(default_factory=dict)

    # -- construction -----------------------------------------------------
    def __post_init__(self):
        self.edges = np.asarray(self.edges, dtype=np.int64).reshape(-1, 2)
        if np.any(self.edges[:, 0] == self.edges[:, 1]):
            raise ValueError("self-loops must go in `loops`, not `edges`")
        if self.edges.size and (self.edges.min() < 0 or self.edges.max() >= self.n):
            raise ValueError("edge endpoint out of range")
        if self.loops is not None:
            self.loops = np.asarray(self.loops, dtype=np.float64).reshape(self.n)

    # -- basic invariants --------------------------------------------------
    @property
    def m(self) -> int:
        """Number of undirected non-loop edges (parallel edges counted)."""
        return int(self.edges.shape[0])

    def degrees(self, include_loops: bool = True) -> np.ndarray:
        deg = np.bincount(self.edges.reshape(-1), minlength=self.n).astype(np.float64)
        if include_loops and self.loops is not None:
            deg = deg + np.abs(self.loops)
        return deg

    def is_regular(self) -> bool:
        d = self.degrees()
        return bool(np.all(d == d[0]))

    @property
    def radix(self) -> int:
        d = self.degrees()
        if not np.all(d == d[0]):
            raise ValueError(f"{self.name} is irregular (deg {d.min()}..{d.max()})")
        return int(d[0])

    # -- matrix forms -------------------------------------------------------
    def adjacency(self) -> np.ndarray:
        """Dense (n, n) float64 adjacency (small graphs / oracles only)."""
        A = np.zeros((self.n, self.n), dtype=np.float64)
        np.add.at(A, (self.edges[:, 0], self.edges[:, 1]), 1.0)
        np.add.at(A, (self.edges[:, 1], self.edges[:, 0]), 1.0)
        if self.loops is not None:
            A[np.arange(self.n), np.arange(self.n)] += self.loops
        return A

    def laplacian(self) -> np.ndarray:
        """Combinatorial Laplacian L = D - A.  Self-loops cancel (standard)."""
        A = self.adjacency()
        if self.loops is not None:       # loops do not change L: D and A both get w
            np.fill_diagonal(A, np.diag(A) - self.loops)
        D = np.diag(A.sum(axis=1))
        return D - A

    def normalized_laplacian(self) -> np.ndarray:
        L = self.laplacian()
        d = np.clip(L.diagonal().copy(), 1e-12, None)
        dinv = 1.0 / np.sqrt(d)
        return L * dinv[:, None] * dinv[None, :]

    # -- gather form for device-scale spectral work --------------------------
    def _slot_fill(self):
        """Vectorized slot assignment shared by the table builders.

        Returns ``(src, dst, slot, deg, k)`` where slot (i) runs over each
        vertex's table row in *edge-scan order* — the order a Python loop over
        ``self.edges`` would fill (u's slot before v's within one edge):
        row-major flattening of ``edges`` is exactly that scan order, and the
        stable argsort groups by vertex while preserving it.  O(m log m)
        instead of the former O(m) Python-level loop (the constant matters:
        datacenter-scale graphs have ~10^6 edges).
        """
        deg = np.bincount(self.edges.reshape(-1), minlength=self.n)
        k = int(deg.max()) if deg.size else 0
        src = self.edges.reshape(-1)                       # u0,v0,u1,v1,...
        dst = self.edges[:, ::-1].reshape(-1)              # v0,u0,v1,u1,...
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        starts = np.concatenate([[0], np.cumsum(deg)[:-1]])
        slot = np.arange(src.size) - starts[src]
        return src, dst, slot, deg, k

    def neighbor_table(self) -> np.ndarray:
        """(n, k) int32 table: row i lists the neighbors of i (with multiplicity).

        Requires regularity *excluding* loop weights; loop weights are handled
        separately by the matvec.  This is the operand format of the Pallas
        spmv kernel: ``(A x)[i] = sum_j x[table[i, j]] + loops[i]*x[i]``.
        Cached per instance (edge lists never mutate after construction).
        """
        cached = self.__dict__.get("_neighbor_table_cache")
        if cached is not None:
            return cached
        src, dst, slot, deg, k = self._slot_fill()
        if not np.all(deg == k):
            raise ValueError(f"{self.name}: neighbor_table needs edge-regularity;"
                             " use gather_operands() for loop-regularized graphs")
        table = np.empty((self.n, k), dtype=np.int32)
        table[src, slot] = dst.astype(np.int32)
        self.__dict__["_neighbor_table_cache"] = table
        return table

    def gather_operands(self):
        """(table, loop_weights) valid for ANY multigraph: rows with fewer
        edge-neighbors are padded with the vertex's own index and the padding
        is compensated in the returned loop weights, so
        ``(A x)[i] = sum_j x[table[i,j]] + w[i] * x[i]`` holds exactly.
        Cached per instance (edge lists never mutate after construction)."""
        cached = self.__dict__.get("_gather_operands_cache")
        if cached is not None:
            return cached
        src, dst, slot, deg, k = self._slot_fill()
        table = np.repeat(np.arange(self.n, dtype=np.int32)[:, None], k, axis=1)
        table[src, slot] = dst.astype(np.int32)
        pad = (k - deg).astype(np.float64)
        w = (self.loops if self.loops is not None else np.zeros(self.n)) - pad
        self.__dict__["_gather_operands_cache"] = (table, w)
        return table, w

    # -- misc ---------------------------------------------------------------
    def edge_count_between(self, X: np.ndarray, Y: np.ndarray) -> float:
        """e(X, Y) of the paper's discrepancy property (loops ignored).

        Counts edges with one endpoint in X and the other in Y; edges inside
        X ∩ Y are counted twice, matching the spectral convention.
        """
        inX = np.zeros(self.n, dtype=bool)
        inX[X] = True
        inY = np.zeros(self.n, dtype=bool)
        inY[Y] = True
        u, v = self.edges[:, 0], self.edges[:, 1]
        return float(np.sum(inX[u] & inY[v]) + np.sum(inY[u] & inX[v]))

    def to_networkx(self):
        import networkx as nx

        G = nx.MultiGraph()
        G.add_nodes_from(range(self.n))
        G.add_edges_from(self.edges.tolist())
        return G

    def __repr__(self) -> str:  # pragma: no cover
        return f"Topology({self.name}, n={self.n}, m={self.m})"
