"""Constructions of every supercomputing topology surveyed in the paper (§4).

Each constructor returns a :class:`repro.core.graphs.Topology`.  The
constructions follow the paper's definitions exactly (Definitions 3-13); where
an implementation has degree irregularities the paper regularizes with
self-loops, and we do the same (Data Vortex inner/outer rings).

Every family is registered with :mod:`repro.api.registry` via the
``@register`` decorators below, carrying its parameter schema and analytic
Table-1 closed forms, so consumers build instances from spec strings
(``repro.api.build("slimfly(q=13)")``) instead of dispatching by hand.
"""
from __future__ import annotations

import itertools
import math
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from ..api.registry import register
from .bounds import TABLE1 as _T1
from .graphs import Topology

__all__ = [
    "path", "path_looped", "cycle", "complete", "hypercube", "generalized_grid",
    "torus", "butterfly", "data_vortex", "cube_connected", "cube_connected_cycles",
    "clex", "g_connected_h", "dragonfly", "slimfly", "petersen_torus",
    "fat_tree", "random_regular", "petersen",
]


# --------------------------------------------------------------------------
# closed-form adapters for the registry.  Table-1 families reuse bounds.TABLE1
# (the analytic content stays in bounds.py); the elemental graphs have exact
# spectra, flagged with rho2_exact=True so tests assert equality, not <=.
# --------------------------------------------------------------------------

def _cf_exact(table_entry: Callable[..., dict]) -> Callable[..., dict]:
    """Table-1 entry whose rho2_ub is attained exactly by the construction."""
    def forms(**params) -> dict:
        return dict(table_entry(**params), rho2_exact=True)
    return forms


def _cf_path(n: int) -> dict:
    return dict(nodes=n, rho2_ub=2.0 * (1 - math.cos(math.pi / n)),
                rho2_exact=True, diameter=n - 1)


def _cf_path_looped(n: int) -> dict:
    return dict(nodes=n, radix=2, rho2_ub=2.0 * (1 - math.cos(math.pi / n)),
                rho2_exact=True, diameter=n - 1)


def _cf_cycle(n: int) -> dict:
    return dict(nodes=n, radix=2, rho2_ub=2.0 * (1 - math.cos(2 * math.pi / n)),
                rho2_exact=True, diameter=n // 2)


def _cf_complete(n: int) -> dict:
    return dict(nodes=n, radix=n - 1, rho2_ub=float(n), rho2_exact=True,
                bw_ub=float((n // 2) * (n - n // 2)), diameter=1)


def _cf_petersen() -> dict:
    return dict(nodes=10, radix=3, rho2_ub=2.0, rho2_exact=True, diameter=2)


def _cf_grid(*ks: int) -> dict:
    return dict(nodes=int(np.prod(ks)),
                rho2_ub=2.0 * (1 - math.cos(math.pi / max(ks))),
                rho2_exact=True, diameter=int(sum(k - 1 for k in ks)))


def _cf_fat_tree(depth: int, base_mult: int = 1) -> dict:
    return dict(nodes=2 ** (depth + 1) - 1)


def _cf_random_regular(n: int, k: int, seed: int = 0) -> dict:
    return dict(nodes=n, radix=k)


def _cf_dragonfly(h: str = "complete(6)") -> dict:
    """Corollary 2 for DragonFly(H); bw_ub only when H is complete."""
    from ..api.registry import parse_spec

    fam, bound = parse_spec(h)
    if fam.name == "complete":
        hn = bound["n"]
        h_edges = hn * (hn - 1) // 2
        h_bw = (hn // 2) * (hn - hn // 2)
        return _T1["dragonfly"](h_nodes=hn, h_edges=h_edges, h_bw=h_bw)
    H = fam.build(**bound)
    return dict(nodes=(H.n + 1) * H.n, radix=2.0 * H.m / H.n + 1,
                rho2_ub=1.0 + H.n / (2.0 * H.m))


# --------------------------------------------------------------------------
# elemental graphs (§2): path, looped path, cycle — the factors of grid-likes
# --------------------------------------------------------------------------

@register("path", params=dict(n=int), closed_forms=_cf_path,
          default_instance="path(7)")
def path(n: int) -> Topology:
    """P_n: the path on n vertices (length n-1).  Adjacency spectrum 2cos(pi j/(n+1))."""
    e = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    return Topology(f"path({n})", n, e)


@register("path_looped", params=dict(n=int), closed_forms=_cf_path_looped,
          default_instance="path_looped(6)")
def path_looped(n: int) -> Topology:
    """P'_n: path with self-loops at both endpoints.  Spectrum 2cos(pi j/n)."""
    e = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    loops = np.zeros(n)
    loops[0] = loops[-1] = 1.0
    return Topology(f"path_looped({n})", n, e, loops=loops)


@register("cycle", params=dict(n=int), closed_forms=_cf_cycle,
          tags=("vertex_transitive",), default_instance="cycle(8)")
def cycle(n: int) -> Topology:
    """C_n.  Adjacency spectrum 2cos(2 pi j / n)."""
    if n < 3:
        raise ValueError("cycle needs n >= 3")
    e = np.stack([np.arange(n), (np.arange(n) + 1) % n], axis=1)
    return Topology(f"cycle({n})", n, e)


@register("complete", params=dict(n=int), closed_forms=_cf_complete,
          tags=("vertex_transitive",), default_instance="complete(8)")
def complete(n: int) -> Topology:
    """K_n: the complete graph (rho2 = n exactly)."""
    e = np.array(list(itertools.combinations(range(n), 2)), dtype=np.int64)
    return Topology(f"complete({n})", n, e)


@register("petersen", closed_forms=_cf_petersen, tags=("vertex_transitive",),
          default_instance="petersen")
def petersen() -> Topology:
    """The Petersen graph, labeled: outer 5-cycle 0-4, inner pentagram 5-9, spokes i~i+5."""
    outer = [(i, (i + 1) % 5) for i in range(5)]
    inner = [(5 + i, 5 + (i + 2) % 5) for i in range(5)]
    spokes = [(i, i + 5) for i in range(5)]
    return Topology("petersen", 10, np.array(outer + inner + spokes))


# --------------------------------------------------------------------------
# products (§4.1)
# --------------------------------------------------------------------------

def _cartesian_product(a: Topology, b: Topology, name: str) -> Topology:
    """G □ H — adjacency A_G ⊗ I + I ⊗ A_H (vertex (u, v) ↦ u * |H| + v)."""
    nb = b.n
    # edges from G: (u,u') x each v  |  edges from H: each u x (v,v')
    eg = (a.edges[:, None, :] * nb + np.arange(nb)[None, :, None]).reshape(-1, 2)
    eh = (b.edges[None, :, :] + (np.arange(a.n) * nb)[:, None, None]).reshape(-1, 2)
    loops = None
    if a.loops is not None or b.loops is not None:
        la = a.loops if a.loops is not None else np.zeros(a.n)
        lb = b.loops if b.loops is not None else np.zeros(b.n)
        loops = (la[:, None] + lb[None, :]).reshape(-1)
    return Topology(name, a.n * nb, np.concatenate([eg, eh], axis=0), loops=loops)


def generalized_grid(ks: Sequence[int]) -> Topology:
    """G_{k_1..k_d} = P_{k_1} □ ... □ P_{k_d} (Definition 4)."""
    ks = list(ks)
    if not ks:
        raise ValueError("grid needs at least one extent")
    g = path(ks[0])
    for k in ks[1:]:
        g = _cartesian_product(g, path(k), "tmp")
    g.name = f"grid({'x'.join(map(str, ks))})"
    return g


@register("grid", params=dict(ks=int), variadic=True, closed_forms=_cf_grid,
          aliases=("generalized_grid",), default_instance="grid(3,4,2)")
def _grid_from_spec(*ks: int) -> Topology:
    """Registry entry point for :func:`generalized_grid` — ``grid(3,4,2)``."""
    return generalized_grid(ks)


@register("hypercube", params=dict(d=int), closed_forms=_cf_exact(_T1["hypercube"]),
          tags=("vertex_transitive",), default_instance="hypercube(5)")
def hypercube(d: int) -> Topology:
    """Q_d = P_2^{□ d} (Definition 3).  rho_2 = 2, BW = 2^{d-1}."""
    g = generalized_grid([2] * d)
    g.name = f"hypercube({d})"
    g.meta = dict(d=d)
    return g


@register("torus", params=dict(k=int, d=int), closed_forms=_cf_exact(_T1["torus"]),
          tags=("vertex_transitive",), default_instance="torus(6,2)")
def torus(k: int, d: int) -> Topology:
    """C_k^{□ d} (Definition 5).  2d-regular on k^d vertices; rho2 = 2(1-cos(2 pi /k))."""
    if k < 3:
        raise ValueError("torus needs k >= 3 (non-degenerate cycles, paper §5)")
    g = cycle(k)
    for _ in range(d - 1):
        g = _cartesian_product(g, cycle(k), "tmp")
    g.name = f"torus({k},{d})"
    g.meta = dict(k=k, d=d)
    return g


# --------------------------------------------------------------------------
# grid variants (§4.2)
# --------------------------------------------------------------------------

@register("butterfly", params=dict(k=int, s=int),
          closed_forms=lambda **p: _T1["butterfly"](**p),
          default_instance="butterfly(3,3)")
def butterfly(k: int, s: int) -> Topology:
    """k-ary s-fly Butterfly, cyclic arrangement (Definition 6).

    Switches indexed by [s] x [k]^s; (i, a) ~ (i+1 mod s, a') where a' agrees
    with a off coordinate i (a'_i ranges over all k values).  2k-regular on
    s*k^s vertices.
    """
    n_digits = k ** s
    n = s * n_digits
    # vertex index = layer * k^s + digit-string (base-k, digit 0 most significant)
    digits = np.arange(n_digits)
    pow_i = np.array([k ** (s - 1 - i) for i in range(s)], dtype=np.int64)
    edges = []
    for i in range(s):
        j = (i + 1) % s
        di = (digits // pow_i[i]) % k          # current i-th digit
        base = digits - di * pow_i[i]          # digit i zeroed
        for v in range(k):
            tgt = base + v * pow_i[i]
            edges.append(np.stack([i * n_digits + digits, j * n_digits + tgt], axis=1))
    e = np.concatenate(edges, axis=0)
    t = Topology(f"butterfly({k},{s})", n, e, meta=dict(k=k, s=s))
    return t


@register("data_vortex", params=dict(A=int, C=int),
          closed_forms=lambda **p: _T1["data_vortex"](**p),
          default_instance="data_vortex(5,4)")
def data_vortex(A: int, C: int) -> Topology:
    """Data Vortex (Definition 7) with the paper's self-loop regularization.

    Vertices: Z_A x Z_C x Z_2^{C-1}.  Rings are a *path* in the cylinder
    coordinate (c -> c+1 transitions, no wrap), heights flip bit c within ring
    c >= 1, ring 0 has angular-only edges.  Outer/inner rings get self-loops to
    reach degree 4 (Proposition 2's convention).
    """
    H = 1 << (C - 1)
    n = A * C * H

    def vid(a, c, h):
        return (a % A) * C * H + c * H + h

    a = np.arange(A)
    h = np.arange(H)
    aa, hh = np.meshgrid(a, h, indexing="ij")
    aa, hh = aa.ravel(), hh.ravel()
    edges = []
    # rule 1: (a, c, h) ~ (a+1, c+1, h) for c in 0..C-2
    for c in range(C - 1):
        edges.append(np.stack([vid(aa, c, hh), vid(aa + 1, c + 1, hh)], axis=1))
    # rule 2: (a, c, h) ~ (a+1, c, h ^ bit(c-1)) for c in 1..C-1
    for c in range(1, C):
        edges.append(np.stack([vid(aa, c, hh), vid(aa + 1, c, hh ^ (1 << (c - 1)))], axis=1))
    # rule 3: (a, 0, h) ~ (a+1, 0, h)
    edges.append(np.stack([vid(aa, 0, hh), vid(aa + 1, 0, hh)], axis=1))
    e = np.concatenate(edges, axis=0)
    deg = np.bincount(e.reshape(-1), minlength=n)
    loops = (4 - deg).astype(np.float64)  # outer/inner rings are degree 3
    assert loops.min() >= 0 and loops.max() <= 1
    return Topology(f"data_vortex({A},{C})", n, e, loops=loops, meta=dict(A=A, C=C))


def cube_connected(G: Topology, name: Optional[str] = None) -> Topology:
    """CC(G, d) for |V(G)| = d (Definition 8, CCC semantics).

    Vertex set V(G) x {0,1}^d; copies of G at fixed height; vertex i of G
    flips hypercube bit i: (i, x) ~ (i, x XOR e_i).  The Riess-Strehl-Wanka
    factorization (Theorem 4) holds for this graph.
    """
    d = G.n
    H = 1 << d
    n = d * H
    x = np.arange(H)
    # G-edges within each height
    eg = (G.edges[None, :, :] * H + x[:, None, None]).reshape(-1, 2)
    # cube edges: (i, x) ~ (i, x ^ (1<<i)); count each once via bit test
    cube = []
    for i in range(d):
        sel = x[(x >> i) & 1 == 0]
        cube.append(np.stack([i * H + sel, i * H + (sel ^ (1 << i))], axis=1))
    e = np.concatenate([eg.reshape(-1, 2)] + cube, axis=0)
    # vertex (i, x) ↦ i * H + x
    return Topology(name or f"cube_connected({G.name})", n, e, meta=dict(d=d))


@register("ccc", params=dict(d=int), closed_forms=lambda **p: _T1["ccc"](**p),
          aliases=("cube_connected_cycles",), default_instance="ccc(4)")
def cube_connected_cycles(d: int) -> Topology:
    """CCC(d) = CC(C_d, d): 3-regular on d * 2^d vertices."""
    g = cube_connected(cycle(d), name=f"ccc({d})")
    g.meta = dict(d=d)
    return g


@register("clex", params=dict(k=int, ell=int),
          closed_forms=lambda **p: _T1["clex"](**p),
          default_instance="clex(3,3)")
def clex(k: int, ell: int, G: Optional[Topology] = None) -> Topology:
    """(Generalized) CLEX C(G, ell) on k^ell vertices (Definition 9 / Lemma 3).

    Undirected multigraph form: every directed edge of the digraph becomes an
    undirected edge, so cross-level pairs ((v..., i), (v..., j, v_l)) carry
    weight per Lemma 3's M operator (weight 2 when i=b, j=a both hold).
    Regular of degree t + 2k(ell-1) for t-regular G (K_k: 2*ell*k - k - 1).
    """
    if G is None:
        G = complete(k)
    if G.n != k:
        raise ValueError("G must have k vertices")
    n = k ** ell
    idx = np.arange(n)
    edges = [
        # G acts on the most significant digit: A_G ⊗ I_{k^{ell-1}}
        (G.edges[:, None, :] * (k ** (ell - 1)) + np.arange(k ** (ell - 1))[None, :, None]).reshape(-1, 2)
    ]
    loops = np.zeros(n)
    # cross-level operator M on digit pair (j, j+1): I_{k^j} ⊗ M ⊗ I_{k^{ell-2-j}}
    # M_{(i,j),(a,b)} = [i=b] + [j=a]  (so (i,j)<->(j,i) has weight 2).
    # Edge set: for all digit pairs (p, q) at positions (j, j+1) and all values c:
    # connect (.., p, q, ..) to (.., c, p, ..) — i.e. new pair (a,b)=(c,p): checks
    # i=b (p=p ✓) always; weight 2 iff additionally j=a i.e. q=c.
    for j in range(ell - 1):
        hi = k ** j                   # digits above the pair
        mid = k ** (ell - 2 - j)      # digits below the pair
        pair_stride = mid             # value of digit (j+1) position
        top_stride = mid * k          # value of digit j position
        rest = idx
        dj = (rest // top_stride) % k       # digit j   ("i" of M-row)
        dj1 = (rest // pair_stride) % k     # digit j+1 ("j" of M-row)
        base = rest - dj * top_stride - dj1 * pair_stride
        for c in range(k):
            tgt = base + c * top_stride + dj * pair_stride   # (a,b) = (c, d_j)
            # Each *type-1 ordered pair* (u -> v with v's digit j+1 == u's digit
            # j) is generated exactly once over the (u, c) loop.  The unordered
            # M-weight is [type-1(u,v)] + [type-1(v,u)], so the multiset of
            # generated pairs, read as undirected edges, realizes M exactly:
            # "swap" pairs (weight 2) appear from both directions, weight-1
            # pairs once.  Diagonal (u1 == u2, c == u1): M[(p,p),(p,p)] = 2.
            u = rest
            same = tgt == u
            if same.any():
                loops[u[same]] += 2.0
            uu, tt = u[~same], tgt[~same]
            edges.append(np.stack([uu, tt], axis=1))
    e = np.concatenate(edges, axis=0)
    e = np.sort(e, axis=1)  # canonical undirected orientation (multiset kept)
    return Topology(f"clex({k},{ell})" if G.name == f"complete({k})" else f"clex({G.name},{ell})",
                    n, e, loops=loops if loops.any() else None,
                    meta=dict(k=k, ell=ell))


# --------------------------------------------------------------------------
# miscellaneous (§4.3)
# --------------------------------------------------------------------------

def g_connected_h(G: Topology, H: Topology, k: int = 1,
                  name: Optional[str] = None) -> Topology:
    """k-fold G-connected-H (Definition 10).

    Requires G d-regular and |V(H)| = t*d.  Ports of each H-copy are split
    into d groups of t by residue mod d; the group for incident edge e of
    vertex g is indexed by e's rank among g's incident edges.  Matching edges
    pair port-groups elementwise with multiplicity k.
    """
    d = G.radix
    if H.n % d != 0:
        raise ValueError(f"|V(H)|={H.n} must be a multiple of deg(G)={d}")
    t = H.n // d
    n = G.n * H.n
    edges = []
    # copies of H
    eh = (H.edges[None, :, :] + (np.arange(G.n) * H.n)[:, None, None]).reshape(-1, 2)
    edges.append(eh)
    # rank of each edge at each endpoint
    rank = {}
    cnt = np.zeros(G.n, dtype=np.int64)
    for ei, (u, v) in enumerate(G.edges):
        rank[(ei, int(u))] = int(cnt[u]); cnt[u] += 1
        rank[(ei, int(v))] = int(cnt[v]); cnt[v] += 1
    ports = [np.arange(H.n)[np.arange(H.n) % d == r] for r in range(d)]
    match = []
    for ei, (u, v) in enumerate(G.edges):
        pu = ports[rank[(ei, int(u))]] + int(u) * H.n
        pv = ports[rank[(ei, int(v))]] + int(v) * H.n
        pair = np.stack([pu, pv], axis=1)
        match.append(np.repeat(pair, k, axis=0))
    edges.append(np.concatenate(match, axis=0))
    e = np.concatenate(edges, axis=0)
    return Topology(name or f"gch({G.name},{H.name},k={k})", n, e,
                    meta=dict(k=k, t=t, d=d))


def dragonfly(H: Topology) -> Topology:
    """DragonFly(H) = K_{|H|+1} ~ H (Definition 12).

    |H|+1 copies of H; global links: copy a, local vertex (b-1 if b>a else b)
    connects to copy b, local vertex (a if a<b else a-1) — the canonical
    all-to-all group wiring; each vertex has exactly one global port.
    """
    g = H.n          # group size = number of global ports per group = n_groups-1...
    ng = H.n + 1     # number of groups
    n = ng * H.n
    eh = (H.edges[None, :, :] + (np.arange(ng) * H.n)[:, None, None]).reshape(-1, 2)
    glob = []
    for a in range(ng):
        for b in range(a + 1, ng):
            pa = a * H.n + (b - 1)          # port of group a towards b
            pb = b * H.n + a                # port of group b towards a
            glob.append((pa, pb))
    e = np.concatenate([eh, np.array(glob, dtype=np.int64)], axis=0)
    return Topology(f"dragonfly({H.name})", n, e, meta=dict(groups=ng))


@register("dragonfly", params=dict(h=str), defaults=dict(h="complete(6)"),
          closed_forms=_cf_dragonfly,
          default_instance="dragonfly(h='complete(6)')")
def _dragonfly_from_spec(h: str = "complete(6)") -> Topology:
    """Registry entry point for :func:`dragonfly` — the group graph H is
    itself a spec string, e.g. ``dragonfly(h='complete(6)')``."""
    from ..api.registry import build as _build

    return dragonfly(_build(h))


@register("slimfly", params=dict(q=int), closed_forms=_cf_exact(_T1["slimfly"]),
          tags=("vertex_transitive",), default_instance="slimfly(5)")
def slimfly(q: int) -> Topology:
    """SlimFly MMS graph (Definition 13) for prime q ≡ 1 (mod 4).

    (3q-1)/2-regular on 2q^2 vertices; rho_2 = q exactly (Proposition 9).
    """
    if q % 4 != 1:
        raise ValueError("q must be ≡ 1 (mod 4)")
    # check primality (prime-power fields not implemented; paper's instances are prime)
    if any(q % f == 0 for f in range(2, int(q ** 0.5) + 1)):
        raise NotImplementedError("prime powers need GF(q) arithmetic; use prime q")
    # primitive root
    def is_primitive(z):
        seen, x = set(), 1
        for _ in range(q - 1):
            x = x * z % q
            seen.add(x)
        return len(seen) == q - 1
    zeta = next(z for z in range(2, q) if is_primitive(z))
    powers = [pow(zeta, i, q) for i in range(q - 1)]
    X = sorted(set(powers[0::2]))   # even powers (incl zeta^0 = 1)
    Xp = sorted(set(powers[1::2]))  # odd powers
    # q ≡ 1 (mod 4) ⟹ -1 = zeta^{(q-1)/2} is an even power, so both generator
    # sets are symmetric and the blocks are undirected Cayley graphs.
    assert (q - 1) in X, "generator set X must be symmetric"

    def vid(s, a, b):
        return s * q * q + a * q + b

    edges = []
    # intra-block edges: (0,x,y) ~ (0,x,y') iff y-y' ∈ X (X symmetric since -1∈X)
    for s, gen in ((0, X), (1, Xp)):
        for x in range(q):
            for y in range(q):
                for g in gen:
                    y2 = (y + g) % q
                    if y < y2:
                        edges.append((vid(s, x, y), vid(s, x, y2)))
    # cross edges: (0,x,y) ~ (1,m,c) iff y = m x + c
    for x in range(q):
        for y in range(q):
            for m in range(q):
                c = (y - m * x) % q
                edges.append((vid(0, x, y), vid(1, m, c)))
    return Topology(f"slimfly({q})", 2 * q * q, np.array(edges, dtype=np.int64),
                    meta=dict(q=q))


@register("petersen_torus", params=dict(a=int, b=int),
          closed_forms=lambda **p: _T1["petersen_torus"](**p),
          default_instance="petersen_torus(5,4)")
def petersen_torus(a: int, b: int) -> Topology:
    """Petersen Torus PT(a, b) (Definition 11); 4-regular on 10ab vertices.

    Historically exported under the ``peterson_torus`` misspelling; that
    alias went through a deprecation cycle and has been removed (the paper's
    graph is Petersen's, so only the correctly-spelled name remains).
    """
    if not (a >= 2 and b >= 2 and (a % 2 == 1 or b % 2 == 1)):
        raise ValueError("need a,b >= 2 with at least one odd")
    P = petersen()
    n = a * b * 10

    def vid(x, y, p):
        return ((x % a) * b + (y % b)) * 10 + p

    xs, ys = np.meshgrid(np.arange(a), np.arange(b), indexing="ij")
    xs, ys = xs.ravel(), ys.ravel()
    edges = []
    for (p, q) in P.edges:                       # internal
        edges.append(np.stack([vid(xs, ys, p), vid(xs, ys, q)], axis=1))
    edges.append(np.stack([vid(xs, ys, 6), vid(xs, ys + 1, 9)], axis=1))       # longitudinal
    edges.append(np.stack([vid(xs, ys, 1), vid(xs + 1, ys, 4)], axis=1))       # latitudinal
    edges.append(np.stack([vid(xs, ys, 2), vid(xs + 1, ys + 1, 3)], axis=1))   # diagonal
    edges.append(np.stack([vid(xs, ys, 7), vid(xs - 1, ys + 1, 8)], axis=1))   # reverse diag
    edges.append(np.stack([vid(xs, ys, 0), vid(xs + a // 2, ys + b // 2, 5)], axis=1))  # diameter
    e = np.concatenate(edges, axis=0)
    return Topology(f"petersen_torus({a},{b})", n, e, meta=dict(a=a, b=b))


@register("fat_tree", params=dict(depth=int, base_mult=int),
          defaults=dict(base_mult=1), closed_forms=_cf_fat_tree,
          default_instance="fat_tree(3)")
def fat_tree(depth: int, base_mult: int = 1) -> Topology:
    """Binary fat tree of given depth (Fig. 3's reduction example).

    Edge multiplicity doubles toward the root: leaves attach with ``base_mult``
    parallel links, the root level has ``base_mult * 2^(depth-1)``.
    """
    n = 2 ** (depth + 1) - 1
    edges = []
    for v in range(1, n):
        parent = (v - 1) // 2
        level_from_leaf = depth - int(np.floor(np.log2(v + 1)))
        mult = base_mult * (2 ** level_from_leaf)
        for _ in range(mult):
            edges.append((parent, v))
    return Topology(f"fat_tree({depth})", n, np.array(edges, dtype=np.int64),
                    meta=dict(depth=depth))


@register("random_regular", params=dict(n=int, k=int, seed=int),
          defaults=dict(seed=0), closed_forms=_cf_random_regular,
          aliases=("jellyfish",), default_instance="random_regular(64,4,seed=1)")
def random_regular(n: int, k: int, seed: int = 0) -> Topology:
    """Jellyfish-style random k-regular graph (configuration model, simple)."""
    import networkx as nx

    G = nx.random_regular_graph(k, n, seed=seed)
    e = np.array(list(G.edges()), dtype=np.int64)
    return Topology(f"random_regular({n},{k})", n, e, meta=dict(k=k, seed=seed))
