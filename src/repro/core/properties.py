"""Exact / witnessed structural properties: diameter, bisection cuts, e(X,Y).

The spectral *bounds* live in bounds.py; these are the combinatorial quantities
they bound, computed exactly (BFS) or witnessed (Fiedler sweep cuts give an
upper-bound bisection; Fiedler's theorem gives the lower bound).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .graphs import Topology
from .spectral import fiedler_vector

__all__ = ["diameter", "eccentricity", "bisection_witness", "bisection_fiedler"]


def eccentricity(topo: Topology, source: int = 0) -> int:
    """Max BFS distance from ``source`` (equals diameter for vertex-transitive G)."""
    n = topo.n
    dist = np.full(n, -1, dtype=np.int64)
    dist[source] = 0
    frontier = np.array([source])
    d = 0
    # CSR-ish adjacency for fast BFS
    order = np.argsort(topo.edges[:, 0], kind="stable")
    e_fwd = topo.edges[order]
    order2 = np.argsort(topo.edges[:, 1], kind="stable")
    e_bwd = topo.edges[order2][:, ::-1]
    alle = np.concatenate([e_fwd, e_bwd], axis=0)
    order3 = np.argsort(alle[:, 0], kind="stable")
    alle = alle[order3]
    starts = np.searchsorted(alle[:, 0], np.arange(n + 1))
    while frontier.size:
        d += 1
        nbrs = np.concatenate([alle[starts[u]:starts[u + 1], 1] for u in frontier]) \
            if frontier.size else np.array([], dtype=np.int64)
        nbrs = np.unique(nbrs)
        new = nbrs[dist[nbrs] < 0]
        if new.size == 0:
            break
        dist[new] = d
        frontier = new
    if np.any(dist < 0):
        raise ValueError("graph is disconnected")
    return int(dist.max())


def diameter(topo: Topology, vertex_transitive: Optional[bool] = None,
             sample: int = 16, seed: int = 0) -> int:
    """Exact diameter for small n; for vertex-transitive topologies a single
    eccentricity suffices; otherwise max over sampled sources (lower bound,
    flagged in meta)."""
    if vertex_transitive:
        return eccentricity(topo, 0)
    if topo.n <= 20000:
        rng = np.random.default_rng(seed)
        if topo.n <= 2000:
            sources = range(topo.n)
        else:
            sources = rng.choice(topo.n, size=min(sample * 8, topo.n), replace=False)
        return max(eccentricity(topo, int(s)) for s in sources)
    rng = np.random.default_rng(seed)
    sources = rng.choice(topo.n, size=sample, replace=False)
    return max(eccentricity(topo, int(s)) for s in sources)


def bisection_witness(topo: Topology, X_mask: np.ndarray) -> float:
    """Edges crossing the cut (X, ~X)."""
    u, v = topo.edges[:, 0], topo.edges[:, 1]
    return float(np.sum(X_mask[u] != X_mask[v]))


def bisection_fiedler(topo: Topology) -> Tuple[float, np.ndarray]:
    """Balanced sweep cut along the Fiedler vector: a certified *upper bound*
    on the bisection bandwidth (it is an actual bisection)."""
    f = fiedler_vector(topo)
    order = np.argsort(f, kind="stable")
    mask = np.zeros(topo.n, dtype=bool)
    mask[order[: topo.n // 2]] = True
    return bisection_witness(topo, mask), mask
