"""Synthetic traffic patterns + routing-scheme link-load accounting.

The routing layer (:mod:`repro.core.routing`) measures where shortest paths
*are*; this module loads them.  Each traffic pattern is a demand matrix
``D[s, t]`` normalized so every node injects at most 1 unit of traffic
(``sum_t D[s, t] <= 1``).  Four routing schemes (:data:`ROUTING_SCHEMES`)
turn demands into directed link loads:

* ``minimal`` — all minimal paths, equal weight per path (ECMP, the
  SpectralFly evaluation model): the flow from s to t crossing edge (u, v)
  on a shortest-path DAG is ``D[s,t] * sigma(s,u) * sigma(v,t) / sigma(s,t)``,
  computed by a Brandes-style backward accumulation over BFS layers — one
  vectorized gather per layer, batched over sources;
* ``valiant`` — Valiant load balancing: every unit s → t detours through a
  uniformly random intermediate w (two minimal-ECMP legs s → w, w → t),
  evaluated in expectation over all intermediates;
* ``ugal`` — UGAL-style adaptive selection: each pair routes minimally
  unless the estimated minimal-channel load exceeds the Valiant
  alternative's (``d_min * q_min > h_val * q_val``), in which case it
  diverts to Valiant;
* ``ksp`` — k-shortest-path non-minimal ECMP: equal splitting over every
  path of length at most ``dist(s, t) + slack`` (near-minimal layers of the
  same frontier-BFS DP).

:func:`mcf_throughput_ub` bounds all of them from above with a
multi-commodity-flow LP on the directed link-capacity polytope (scipy
linprog; optional dependency).

Units
-----
* demands and link loads are in *injection units*: load 1.0 on a directed
  link means it carries exactly one node's full injection rate;
* ``saturation_throughput`` = 1 / max link load: the factor every node can
  scale its injection by before the hottest link saturates (unit link
  capacity), dimensionless;
* conservation: the sum of all directed link loads equals
  ``sum_{s,t} D[s,t] * hops(s,t)`` exactly — each unit of flow occupies one
  unit of load per hop traversed.

Patterns (:data:`TRAFFIC_PATTERNS`)
-----------------------------------
* ``uniform``        — all-to-all, ``D[s, t] = 1/(n-1)``
* ``bit_complement`` — permutation ``t = (n-1) - s`` (bitwise complement when
  n is a power of two)
* ``transpose``      — permutation ``(a, b) → (b, a)`` for n = m*m (matrix
  transpose); raises for non-square n
* ``neighbor``       — nearest-neighbor stencil: half a unit to each of
  ``s ± 1 (mod n)``
* ``adversarial``    — spectrally adversarial permutation: vertices sorted by
  Fiedler value are matched first-to-last, forcing every flow across the
  sparsest (Fiedler) cut
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro import obs

from .graphs import Topology
from .routing import (DEFAULT_SOURCE_CHUNK, RoutingResult, analyze_routing,
                      reverse_slot_index)
from repro.kernels import spmv as KS

try:                                   # optional: only the MCF LP bound
    from scipy import sparse as _scipy_sparse
    from scipy.optimize import linprog as _scipy_linprog
except ImportError:                    # pragma: no cover - scipy-less CI
    _scipy_sparse = None
    _scipy_linprog = None

__all__ = [
    "TRAFFIC_PATTERNS", "ROUTING_SCHEMES", "TrafficResult", "demand_matrix",
    "demand_rows", "ecmp_link_loads", "scheme_link_loads",
    "valiant_link_loads", "ugal_link_loads", "ksp_link_loads",
    "mcf_throughput_ub", "evaluate_traffic", "spectral_throughput_estimate",
]

TRAFFIC_PATTERNS = ("uniform", "bit_complement", "transpose", "neighbor",
                    "adversarial")

#: routing schemes understood by :func:`evaluate_traffic` /
#: :func:`scheme_link_loads` (and, through them, the simulator's schedule
#: compiler and the survey's thpt_* columns).
ROUTING_SCHEMES = ("minimal", "valiant", "ugal", "ksp")


# --------------------------------------------------------------------------
# demand matrices
# --------------------------------------------------------------------------

def _permutation_demands(perm: np.ndarray) -> np.ndarray:
    """Demand matrix of a permutation: one unit from s to perm[s] (fixed
    points send nothing — a node never loads the network talking to itself)."""
    n = perm.size
    D = np.zeros((n, n))
    s = np.arange(n)
    keep = perm != s
    D[s[keep], perm[keep]] = 1.0
    return D


def _pattern_permutation(pattern: str, n: int, *,
                         fiedler: Optional[np.ndarray] = None) -> np.ndarray:
    """The permutation behind a permutation-type pattern (O(n log n), no
    (n, n) matrix — the scalable core shared by matrix and row builders)."""
    if pattern == "bit_complement":
        return n - 1 - np.arange(n)
    if pattern == "transpose":
        m = math.isqrt(n)
        if m * m != n:
            raise ValueError(f"transpose traffic needs square n, got {n}")
        s = np.arange(n)
        return (s % m) * m + s // m
    if pattern == "adversarial":
        if fiedler is None:
            raise ValueError("adversarial traffic needs the Fiedler vector")
        f = np.asarray(fiedler, dtype=np.float64)
        # Canonicalize before pairing: on degenerate Fiedler eigenspaces the
        # raw eigenvector differs across eigensolver paths / BLAS builds, and
        # argsort ties make the permutation (hence thpt_adversarial) drift.
        # Quantizing to 6 decimals of the max-normalized vector collapses
        # cross-backend jitter (~1e-13) into identical keys; the index
        # tie-break then makes the ordering fully deterministic, and the
        # leading-sign flip removes the eigenvector's sign ambiguity.
        amax = np.max(np.abs(f)) if f.size else 0.0
        q = np.round(f / amax, 6) if amax > 0 else np.zeros_like(f)
        nz = np.flatnonzero(q)
        if nz.size and q[nz[0]] < 0:
            q = -q
        order = np.lexsort((np.arange(n), q))
        perm = np.empty(n, dtype=np.int64)
        perm[order] = order[::-1]
        return perm
    raise ValueError(f"unknown traffic pattern {pattern!r} "
                     f"(known: {TRAFFIC_PATTERNS})")


def demand_rows(pattern: str, n: int, sources: Sequence[int], *,
                fiedler: Optional[np.ndarray] = None) -> np.ndarray:
    """The ``sources`` rows of :func:`demand_matrix` without materializing it.

    This is the datacenter-scale entry point: an (n, n) float64 demand matrix
    at n = 65536 is 32 GiB, but a sampled traffic evaluation only ever routes
    the S sampled source rows.  Row order follows ``sources``.  Exactly equal
    to ``demand_matrix(pattern, n)[sources]`` (tested), so the sampled path
    inherits every pattern's semantics.
    """
    srcs = np.asarray(list(sources), dtype=np.int64)
    S = srcs.size
    rows = np.arange(S)
    if pattern == "uniform":
        if n < 2:
            raise ValueError("uniform traffic needs n >= 2")
        D = np.full((S, n), 1.0 / (n - 1))
        D[rows, srcs] = 0.0
        return D
    if pattern == "neighbor":
        D = np.zeros((S, n))
        np.add.at(D, (rows, (srcs + 1) % n), 0.5)
        np.add.at(D, (rows, (srcs - 1) % n), 0.5)
        D[rows, srcs] = 0.0
        return D
    perm = _pattern_permutation(pattern, n, fiedler=fiedler)
    D = np.zeros((S, n))
    keep = perm[srcs] != srcs
    D[rows[keep], perm[srcs[keep]]] = 1.0
    return D


def demand_matrix(pattern: str, n: int, *,
                  fiedler: Optional[np.ndarray] = None) -> np.ndarray:
    """Build the (n, n) demand matrix of a named synthetic pattern.

    Args:
        pattern: one of :data:`TRAFFIC_PATTERNS`.
        n: number of nodes.
        fiedler: (n,) Fiedler vector, required by ``adversarial`` (it defines
            the cut the permutation stresses).

    Returns:
        (n, n) float64 demands in injection units; row sums are <= 1 and the
        diagonal is 0.
    """
    if pattern == "uniform":
        if n < 2:
            raise ValueError("uniform traffic needs n >= 2")
        D = np.full((n, n), 1.0 / (n - 1))
        np.fill_diagonal(D, 0.0)
        return D
    if pattern == "neighbor":
        D = np.zeros((n, n))
        s = np.arange(n)
        D[s, (s + 1) % n] += 0.5
        D[s, (s - 1) % n] += 0.5
        np.fill_diagonal(D, 0.0)   # n <= 2 degenerates to self-traffic
        return D
    return _permutation_demands(_pattern_permutation(pattern, n,
                                                     fiedler=fiedler))


# --------------------------------------------------------------------------
# ECMP link loads (Brandes-style backward accumulation, batched over sources)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def _ecmp_loads_chunk(table: jnp.ndarray, dist: jnp.ndarray,
                      sigma: jnp.ndarray, w: jnp.ndarray,
                      backend: Optional[str] = None) -> jnp.ndarray:
    """Summed per-edge ECMP loads for a (S, n) block of sources.

    For each source: backward accumulation over BFS layers d = dmax..1 of
    ``g(v) = w(v) + sigma(v) * sum_{v' in succ(v)} g(v')/sigma(v')`` (the
    demand subtree routed through v) — the per-layer neighbor sum is one spmv
    through the :mod:`repro.kernels.spmv` dispatcher — then the per-slot
    directed edge loads ``load[u, j] = sigma(u) * g(v)/sigma(v)`` for
    ``v = table[u, j]`` one hop further out.  Self-padded slots have equal
    dist and drop out of the mask.  Returns the (n, k) load table summed over
    the block's sources.
    """
    obs.count("jit_trace/ecmp")                  # trace-time increment
    bk = KS.resolve_backend(backend)
    dmax = jnp.maximum(dist.max(), 0)

    def one(dist_s, sigma_s, w_s):
        sigma_safe = jnp.where(sigma_s > 0, sigma_s, 1.0)

        def back(i, g):
            d = dmax - i
            h = jnp.where(dist_s == d, g / sigma_safe, 0.0)
            inc = KS.spmv(h, table, backend=bk)
            return jnp.where(dist_s == d - 1, g + sigma_s * inc, g)

        g = jax.lax.fori_loop(0, dmax, back, w_s)
        ratio = jnp.where(dist_s > 0, g / sigma_safe, 0.0)
        succ = dist_s[table] == (dist_s[:, None] + 1)
        return sigma_s[:, None] * jnp.where(succ, ratio[table], 0.0)

    return jax.vmap(one)(dist, sigma, w).sum(axis=0)


def ecmp_link_loads(table: np.ndarray, dist: np.ndarray, sigma: np.ndarray,
                    demands: np.ndarray,
                    chunk: int = DEFAULT_SOURCE_CHUNK,
                    backend: Optional[str] = None) -> np.ndarray:
    """Directed link loads under minimal-path ECMP routing of ``demands``.

    Args:
        table: (n, k) padded neighbor table (``gather_operands()[0]``).
        dist: (S, n) BFS distances from :func:`repro.core.routing.bfs_distances`.
        sigma: (S, n) minimal-path counts matching ``dist``.
        demands: (S, n) demand rows in injection units, one per BFS source
            (row s holds D[s, :]).  Demands to unreachable targets are ignored
            (dropped, reported by :func:`evaluate_traffic`).
        chunk: sources per jitted call.

    Returns:
        (n, k) float64 directed loads aligned with the table slots: entry
        ``[u, j]`` is the load on directed link u → table[u, j] (padding slots
        stay 0; parallel edges each get their ECMP share).
    """
    table = np.asarray(table)
    tab = jnp.asarray(table, dtype=jnp.int32)
    # a demand to an unreachable target would otherwise sit in g forever
    demands = np.where(dist >= 0, demands, 0.0)
    loads = np.zeros(table.shape, dtype=np.float64)
    for lo in range(0, dist.shape[0], chunk):
        hi = min(lo + chunk, dist.shape[0])
        loads += np.asarray(_ecmp_loads_chunk(
            tab, jnp.asarray(dist[lo:hi]),
            jnp.asarray(sigma[lo:hi], dtype=jnp.float32),
            jnp.asarray(demands[lo:hi], dtype=jnp.float32),
            backend=backend), dtype=np.float64)
    return loads


@functools.partial(jax.jit, static_argnames=("backend",))
def _ecmp_loads_cand_chunk(table: jnp.ndarray, dist: jnp.ndarray,
                           sigma: jnp.ndarray, w: jnp.ndarray,
                           cand: jnp.ndarray,
                           backend: Optional[str] = None) -> jnp.ndarray:
    """*Per-source* ECMP loads at M candidate flat slots — (S, M).

    Same backward accumulation as :func:`_ecmp_loads_chunk`, but instead of
    summing over the block it gathers each source's contribution to the M
    candidate ``(u, j)`` slots (flat indices into the (n, k) load table).
    This is the second pass of the sampled max-load bootstrap: resampling
    source rows of the (S, M) matrix rebuilds the max statistic's sampling
    distribution without ever storing (S, n, k).
    """
    obs.count("jit_trace/ecmp_candidates")       # trace-time increment
    bk = KS.resolve_backend(backend)
    dmax = jnp.maximum(dist.max(), 0)

    def one(dist_s, sigma_s, w_s):
        sigma_safe = jnp.where(sigma_s > 0, sigma_s, 1.0)

        def back(i, g):
            d = dmax - i
            h = jnp.where(dist_s == d, g / sigma_safe, 0.0)
            inc = KS.spmv(h, table, backend=bk)
            return jnp.where(dist_s == d - 1, g + sigma_s * inc, g)

        g = jax.lax.fori_loop(0, dmax, back, w_s)
        ratio = jnp.where(dist_s > 0, g / sigma_safe, 0.0)
        succ = dist_s[table] == (dist_s[:, None] + 1)
        full = sigma_s[:, None] * jnp.where(succ, ratio[table], 0.0)
        return full.ravel()[cand]

    return jax.vmap(one)(dist, sigma, w)


def _max_link_load_ucb(table: np.ndarray, routing: RoutingResult,
                       served: np.ndarray, loads_scaled: np.ndarray, *,
                       chunk: int, backend: Optional[str],
                       bootstrap: int = 200, confidence: float = 0.95,
                       candidates: int = 256) -> float:
    """One-sided bootstrap upper confidence bound for the full-census max
    directed-link load under sampled-source routing.

    The n/S correction is unbiased per-slot, but ``max`` over slots of an
    estimate is biased low (unsampled sources contribute nothing to the true
    hottest link).  This reruns the load accumulation restricted to the
    ``candidates`` hottest slots of the point estimate, keeping *per-source*
    contributions, then bootstrap-resamples source rows and takes the
    ``confidence`` quantile of the replicate maxima.  Caveat: links outside
    the candidate set are invisible to the bound; with the default 256 slots
    the true argmax is overwhelmingly among them for the smooth load
    profiles ECMP produces (documented in docs/scale.md).
    """
    n, k = table.shape
    S = routing.dist.shape[0]
    flat = loads_scaled.ravel()
    M = int(min(candidates, flat.size))
    cand = np.argsort(flat)[-M:]
    tab = jnp.asarray(table, dtype=jnp.int32)
    cand_j = jnp.asarray(cand, dtype=jnp.int32)
    demands = np.where(routing.dist >= 0, served, 0.0)
    # the (inner, n, k) per-source intermediate is the footprint here
    inner = max(1, min(chunk, (64 << 20) // max(4 * n * k, 1)))
    C = np.zeros((S, M), dtype=np.float64)
    for lo in range(0, S, inner):
        hi = min(lo + inner, S)
        C[lo:hi] = np.asarray(_ecmp_loads_cand_chunk(
            tab, jnp.asarray(routing.dist[lo:hi]),
            jnp.asarray(routing.sigma[lo:hi], dtype=jnp.float32),
            jnp.asarray(demands[lo:hi], dtype=jnp.float32),
            cand_j, backend=backend), dtype=np.float64)
    rng = np.random.default_rng((routing.seed or 0) + 0x10AD)
    idx = rng.integers(0, S, size=(bootstrap, S))
    rep_max = (n / S) * C[idx].sum(axis=1).max(axis=1)
    ucb = float(np.quantile(rep_max, confidence))
    return max(ucb, float(loads_scaled.max()))


# --------------------------------------------------------------------------
# non-minimal & adaptive schemes: Valiant, UGAL, k-shortest-path ECMP
# --------------------------------------------------------------------------

def valiant_link_loads(table: np.ndarray, routing: RoutingResult,
                       served: np.ndarray, *,
                       chunk: int = DEFAULT_SOURCE_CHUNK,
                       backend: Optional[str] = None
                       ) -> Tuple[np.ndarray, float, int]:
    """Valiant load balancing in expectation over all intermediates.

    Every unit s → t is routed s → w → t for a uniformly random intermediate
    w, each leg minimal-ECMP.  Rather than sampling w, both legs are routed
    in expectation: leg 1 sends ``out(s)/n`` from s to every w; leg 2 sends
    ``in(t)/S`` from every *sampled* source row (the intermediate pool under
    sampling — all n rows when exact, so both legs reduce to the exact
    ``/n`` split) to every t.  The caller's single n/S correction then makes
    both legs unbiased estimators of the full-census Valiant loads.

    Returns ``(loads (n, k) float64 — unscaled, hops_weighted, max_hops)``
    where ``hops_weighted`` counts both legs (conservation: equals the load
    sum) and ``max_hops`` = worst leg-1 distance + worst leg-2 distance (the
    simulator's round-latency bound).
    """
    dist = routing.dist
    S, n = served.shape
    out_s = served.sum(axis=1)
    in_t = served.sum(axis=0)
    D1 = np.broadcast_to(out_s[:, None] / n, (S, n)).copy()
    D2 = np.broadcast_to(in_t[None, :] / S, (S, n)).copy()
    loads = ecmp_link_loads(table, dist, routing.sigma, D1,
                            chunk=chunk, backend=backend)
    loads += ecmp_link_loads(table, dist, routing.sigma, D2,
                             chunk=chunk, backend=backend)
    reach = dist >= 0
    dpos = np.where(reach, dist, 0)
    hops = float((np.where(reach, D1, 0.0) * dpos).sum()
                 + (np.where(reach, D2, 0.0) * dpos).sum())
    h1 = int(dpos[out_s > 0].max()) if bool((out_s > 0).any()) else 0
    h2 = int(dpos[:, in_t > 0].max()) if bool((in_t > 0).any()) else 0
    return loads, hops, h1 + h2


@jax.jit
def _ugal_qmin_chunk(table: jnp.ndarray, load_in: jnp.ndarray,
                     dist: jnp.ndarray) -> jnp.ndarray:
    """Peak minimal-DAG link load q_min(s, t) for a (S, n) block of sources.

    Layered max-DP over the BFS DAG: ``M(v)`` at layer d is the max over
    predecessor slots (neighbors one layer closer) of
    ``max(M(pred), load(pred → v))`` — the largest link load anywhere on the
    union of minimal paths s → v.  ``load_in[v, j]`` is the load of the
    incoming directed link ``table[v, j] → v`` (gathered host-side through
    :func:`repro.core.routing.reverse_slot_index`).  Self-padded slots never
    qualify as predecessors (their dist equals the row's own).
    """
    obs.count("jit_trace/ugal_qmin")             # trace-time increment
    dmax = jnp.maximum(dist.max(), 0)

    def one(dist_s):
        def body(d, M):
            pred = dist_s[table] == (d - 1)
            cand = jnp.where(pred, jnp.maximum(M[table], load_in), 0.0)
            return jnp.where(dist_s == d, cand.max(axis=1), M)

        return jax.lax.fori_loop(1, dmax + 1, body,
                                 jnp.zeros(dist_s.shape, load_in.dtype))

    return jax.vmap(one)(dist)


def _ugal_decision(table: np.ndarray, routing: RoutingResult,
                   served: np.ndarray, *, chunk: int,
                   backend: Optional[str]
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """UGAL's per-pair choice: ``(minimal_mask (S, n) bool, L_min (n, k))``.

    One-shot UGAL-L-style estimate: channel loads are estimated from routing
    the *entire* offered demand all-minimal (q_min = peak load on the pair's
    minimal DAG) vs all-Valiant (q_val = global peak).  A pair stays minimal
    iff ``d_min * q_min <= h_val * q_val`` with ``h_val = E_w[d(s,w)] +
    E_w[d(w,t)]`` the expected Valiant path length; ties route minimal.
    Both sides scale identically under the sampled n/S correction, so the
    decision is taken on unscaled loads.
    """
    dist = routing.dist
    S, n = served.shape
    L_min = ecmp_link_loads(table, dist, routing.sigma, served,
                            chunk=chunk, backend=backend)
    rev = reverse_slot_index(table)
    load_in = L_min[table, rev]        # (n, k): load on link table[v,j] -> v
    L_val, _, _ = valiant_link_loads(table, routing, served,
                                     chunk=chunk, backend=backend)
    q_val = float(L_val.max())
    reach = dist >= 0
    dpos = np.where(reach, dist, 0)
    n_reach_row = np.maximum(reach.sum(axis=1), 1)
    n_reach_col = np.maximum(reach.sum(axis=0), 1)
    a_s = (dpos * reach).sum(axis=1) / n_reach_row   # E_w d(s, w)
    b_t = (dpos * reach).sum(axis=0) / n_reach_col   # E_w d(w, t)
    tab = jnp.asarray(table, dtype=jnp.int32)
    lin = jnp.asarray(load_in, dtype=jnp.float32)
    qmin = np.zeros((S, n), dtype=np.float64)
    for lo in range(0, S, chunk):
        hi = min(lo + chunk, S)
        qmin[lo:hi] = np.asarray(_ugal_qmin_chunk(
            tab, lin, jnp.asarray(dist[lo:hi])), dtype=np.float64)
    lhs = dpos * qmin
    rhs = (a_s[:, None] + b_t[None, :]) * q_val
    return (lhs <= rhs) | ~reach, L_min


def ugal_link_loads(table: np.ndarray, routing: RoutingResult,
                    served: np.ndarray, *,
                    chunk: int = DEFAULT_SOURCE_CHUNK,
                    backend: Optional[str] = None
                    ) -> Tuple[np.ndarray, float, int]:
    """UGAL adaptive routing: per-pair minimal vs Valiant by estimated load.

    Splits the served demand by :func:`_ugal_decision`, routes the minimal
    share ECMP and the diverted share Valiant, and sums the loads.  When
    nothing diverts (e.g. uniform traffic on every symmetric family — the
    minimal channel estimate never exceeds the doubled-hop Valiant one) the
    all-minimal loads computed for the decision are reused as-is, making
    UGAL degenerate to ``minimal`` exactly.

    Returns ``(loads, hops_weighted, max_hops)`` as
    :func:`valiant_link_loads`.
    """
    dist = routing.dist
    minimal_mask, L_min = _ugal_decision(table, routing, served,
                                         chunk=chunk, backend=backend)
    D_min = np.where(minimal_mask, served, 0.0)
    D_val = served - D_min
    reach = dist >= 0
    dpos = np.where(reach, dist, 0)
    sm = np.where(reach, D_min, 0.0)
    hops_min = float((sm * dpos).sum())
    mh_min = int(dpos[sm > 0].max()) if bool((sm > 0).any()) else 0
    if not D_val.any():
        return L_min, hops_min, mh_min
    loads = ecmp_link_loads(table, dist, routing.sigma, D_min,
                            chunk=chunk, backend=backend)
    lv, hv, mhv = valiant_link_loads(table, routing, D_val,
                                     chunk=chunk, backend=backend)
    return loads + lv, hops_min + hv, max(mh_min, mhv)


@functools.partial(jax.jit, static_argnames=("Lmax", "slack", "backend"))
def _ksp_loads_chunk(table: jnp.ndarray, nopad: jnp.ndarray,
                     dist: jnp.ndarray, demand: jnp.ndarray,
                     Lmax: int, slack: int,
                     backend: Optional[str] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Near-minimal path ECMP loads for a (S, n) block — forward/backward
    walk DP over length layers.

    Forward: ``W[h]`` = walks of length h from the source (one spmv per
    layer, pad slots masked by ``nopad``), stacked to (Lmax+1, n).  Every
    walk to t of length in ``[dist(t), dist(t)+slack]`` is an admitted path
    with equal weight ``D[t] / P(t)`` (``P`` = total admitted walks).  For
    ``slack <= 1`` every admitted walk is a simple path (a repeated vertex
    implies a closed subwalk of length >= 2, i.e. total length >=
    dist + 2); larger slacks admit backtracking walks — a derouting model.
    Backward: ``G[h](v)`` = downstream credit of being at v at step h;
    the load on slot (u, j) accumulates ``W[h][u] * G[h+1][table[u,j]]``.
    ``slack=0`` reproduces minimal ECMP exactly (equal weight per minimal
    path — the same model as :func:`_ecmp_loads_chunk`).
    """
    obs.count("jit_trace/ksp")                   # trace-time increment
    bk = KS.resolve_backend(backend)
    n, k = table.shape

    def one(dist_s, d_s):
        src = (dist_s == 0).astype(nopad.dtype)

        def fwd(W, _):
            return KS.spmv(W, table, None, nopad, backend=bk), W

        _, Ws = jax.lax.scan(fwd, src, None, length=Lmax + 1)
        dpos = jnp.maximum(dist_s, 0)
        P = jnp.zeros_like(d_s)
        wsum = jnp.zeros_like(d_s)     # sum_e (d+e) * W[d+e]
        for e in range(slack + 1):
            idx = jnp.minimum(dpos + e, Lmax)
            cnt = jnp.where((dist_s >= 0) & (dpos + e <= Lmax),
                            jnp.take_along_axis(Ws, idx[None, :], axis=0)[0],
                            0.0)
            P = P + cnt
            wsum = wsum + (dpos + e) * cnt
        credit = d_s / jnp.where(P > 0, P, 1.0)
        hops_s = jnp.sum(credit * wsum)

        def bwd(carry, xs):
            g_next, loads = carry      # G at h+1, running (n, k) loads
            wh, h = xs
            loads = loads + nopad * wh[:, None] * g_next[table]
            admit = (dist_s >= 0) & (h >= dist_s) & (h <= dist_s + slack)
            g = jnp.where(admit, credit, 0.0) + \
                KS.spmv(g_next, table, None, nopad, backend=bk)
            return (g, loads), None

        (_, loads_s), _ = jax.lax.scan(
            bwd, (jnp.zeros(n, d_s.dtype), jnp.zeros((n, k), d_s.dtype)),
            (Ws, jnp.arange(Lmax + 1)), reverse=True)
        return loads_s, hops_s

    loads, hops = jax.vmap(one)(dist, demand)
    return loads.sum(axis=0), hops.sum()


def ksp_link_loads(table: np.ndarray, routing: RoutingResult,
                   served: np.ndarray, *, slack: int = 1,
                   chunk: int = DEFAULT_SOURCE_CHUNK,
                   backend: Optional[str] = None
                   ) -> Tuple[np.ndarray, float, int]:
    """k-shortest-path non-minimal ECMP: equal split over every path of
    length <= ``dist(s, t) + slack``.

    Returns ``(loads (n, k) float64 — unscaled, hops_weighted, max_hops)``.
    The DP runs in float64 (``enable_x64`` scope — walk counts overflow
    float32 fast) with the source chunk re-sized so the per-source
    (Lmax+1, n) walk stacks stay within a fixed byte budget.
    """
    if slack < 0:
        raise ValueError(f"slack must be >= 0 (got {slack})")
    table = np.asarray(table)
    n, k = table.shape
    dist = routing.dist
    served = np.where(dist >= 0, served, 0.0)
    if not served.any():
        return np.zeros((n, k), dtype=np.float64), 0.0, 0
    Lmax = int(dist[served > 0].max()) + int(slack)
    nopad = table != np.arange(n)[:, None]
    per_src = 8 * n * (Lmax + 2 + k)   # walk stack + load table, f64
    inner = max(1, min(chunk, (256 << 20) // max(per_src, 1)))
    tab = jnp.asarray(table, dtype=jnp.int32)
    loads = np.zeros((n, k), dtype=np.float64)
    hops = 0.0
    with enable_x64():
        npd = jnp.asarray(nopad, dtype=jnp.float64)
        for lo in range(0, dist.shape[0], inner):
            hi = min(lo + inner, dist.shape[0])
            lc, hc = _ksp_loads_chunk(
                tab, npd, jnp.asarray(dist[lo:hi]),
                jnp.asarray(served[lo:hi], dtype=jnp.float64),
                Lmax=Lmax, slack=int(slack), backend=backend)
            loads += np.asarray(lc, dtype=np.float64)
            hops += float(hc)
    return loads, hops, Lmax


def scheme_link_loads(table: np.ndarray, routing: RoutingResult,
                      served: np.ndarray, scheme: str = "minimal", *,
                      slack: int = 1, chunk: int = DEFAULT_SOURCE_CHUNK,
                      backend: Optional[str] = None
                      ) -> Tuple[np.ndarray, float, int]:
    """Route served demand rows under one of :data:`ROUTING_SCHEMES`.

    The shared dispatch used by :func:`evaluate_traffic` and the simulator's
    schedule compiler.  ``served`` is (S, n) demand rows aligned with
    ``routing.sources`` (diagonal zeroed, unreachable targets dropped).

    Returns ``(loads, hops_weighted, max_hops)``: (n, k) float64 directed
    slot loads *before* any n/S sampling correction, the demand-weighted hop
    total (equals the load sum — conservation), and the worst per-flow hop
    count (the simulator's round-latency bound).
    """
    table = np.asarray(table)
    dist = routing.dist
    if scheme == "minimal":
        loads = ecmp_link_loads(table, dist, routing.sigma, served,
                                chunk=chunk, backend=backend)
        reach = dist >= 0
        dpos = np.where(reach, dist, 0)
        sm = np.where(reach, served, 0.0)
        hops = float((sm * dpos).sum())
        mh = int(dpos[sm > 0].max()) if bool((sm > 0).any()) else 0
        return loads, hops, mh
    if scheme == "valiant":
        return valiant_link_loads(table, routing, served,
                                  chunk=chunk, backend=backend)
    if scheme == "ugal":
        return ugal_link_loads(table, routing, served,
                               chunk=chunk, backend=backend)
    if scheme == "ksp":
        return ksp_link_loads(table, routing, served, slack=slack,
                              chunk=chunk, backend=backend)
    raise ValueError(f"unknown routing scheme {scheme!r} "
                     f"(known: {ROUTING_SCHEMES})")


# --------------------------------------------------------------------------
# multi-commodity-flow LP throughput ceiling
# --------------------------------------------------------------------------

@obs.traced("traffic/mcf_throughput_ub", phase="execute")
def mcf_throughput_ub(topo: Union[Topology, Tuple[np.ndarray, int]],
                      pattern: str = "uniform", *,
                      fiedler: Optional[np.ndarray] = None,
                      demands: Optional[np.ndarray] = None,
                      groups: Optional[int] = None) -> float:
    """LP upper bound on saturation throughput over *all* routings.

    Maximize theta s.t. theta-scaled demands admit a fractional
    multi-commodity flow respecting unit capacity on every directed link
    (one capacity unit per non-padding gather-table slot — parallel edges
    each count, matching the ECMP slot semantics).  Commodities are grouped
    by source into ``groups`` buckets (contiguous in Fiedler order when
    ``fiedler`` is given, index order otherwise): merging commodities only
    *relaxes* the flow polytope, so the grouped optimum is a valid upper
    bound on the true per-commodity MCF optimum — which in turn dominates
    every realizable routing scheme — for any group count.  ``groups >= n``
    is the exact per-commodity LP.

    The LP has ``1 + groups * E`` variables (scipy sparse + HiGHS); the
    default caps at 8 groups (~25k variables on the largest bench
    instances) — HiGHS wall time grows super-linearly with the group count
    on these highly-degenerate instances while the bound barely tightens,
    and a coarse grouping is still a certified (just looser) ceiling.
    Tiny instances (``n <= 8``) get the exact per-commodity LP under the
    same cap.  Assumes a connected
    topology (demand between disconnected components makes the LP
    infeasible).  Raises ``RuntimeError`` with a clear message when scipy is
    unavailable — callers (survey, benches) catch it and skip the column.

    Returns theta* (``inf`` when there is no demand).
    """
    if _scipy_linprog is None:
        raise RuntimeError(
            "mcf_throughput_ub needs scipy (scipy.optimize.linprog) which is "
            "not installed — the MCF LP bound is skipped; install scipy to "
            "enable it")
    if isinstance(topo, Topology):
        n = topo.n
        table = topo.gather_operands()[0]
    else:
        table, n = np.asarray(topo[0]), int(topo[1])
    if demands is None:
        D = demand_rows(pattern, n, np.arange(n), fiedler=fiedler)
    else:
        D = np.asarray(demands, dtype=np.float64).copy()
        if D.shape != (n, n):
            raise ValueError(f"demands must be ({n}, {n}), got {D.shape}")
        D[np.arange(n), np.arange(n)] = 0.0
    if D.sum() <= 0:
        return float("inf")
    mask = (table != np.arange(n)[:, None]).ravel()
    tail = np.repeat(np.arange(n), table.shape[1])[mask]
    head = table.ravel()[mask]
    E = tail.size
    if groups is None:
        # HiGHS wall time grows super-linearly in the group count while the
        # bound barely tightens past a handful of groups (hypercube(8):
        # identical UB at 2..12 groups, 0.1s vs minutes) — cap at 8
        groups = max(2, min(n, 25_000 // max(E, 1), 8))
    G = max(1, min(int(groups), n))
    order = np.arange(n)
    if fiedler is not None and G < n:
        f = np.asarray(fiedler, dtype=np.float64)
        amax = np.max(np.abs(f))
        q = np.round(f / amax, 6) if amax > 0 else np.zeros_like(f)
        order = np.lexsort((order, q))
    buckets = np.array_split(order, G)
    out = D.sum(axis=1)
    sup = np.zeros((G, n))
    for g, b in enumerate(buckets):
        sup[g, b] += out[b]
        sup[g] -= D[b].sum(axis=0)
    e_idx = np.arange(E)
    inc = _scipy_sparse.coo_matrix(
        (np.r_[np.ones(E), -np.ones(E)],
         (np.r_[tail, head], np.r_[e_idx, e_idx])), shape=(n, E)).tocsr()
    A_eq = _scipy_sparse.hstack(
        [_scipy_sparse.csr_matrix(-sup.reshape(G * n, 1)),
         _scipy_sparse.block_diag([inc] * G, format="csr")], format="csr")
    eye = _scipy_sparse.eye(E, format="csr")
    A_ub = _scipy_sparse.hstack(
        [_scipy_sparse.csr_matrix((E, 1))] + [eye] * G, format="csr")
    c = np.zeros(1 + G * E)
    c[0] = -1.0
    res = _scipy_linprog(c, A_ub=A_ub, b_ub=np.ones(E),
                         A_eq=A_eq, b_eq=np.zeros(G * n), method="highs")
    if res.status == 3:                # unbounded: no capacity ever binds
        return float("inf")
    if not res.success:
        raise RuntimeError(f"MCF LP failed (status {res.status}): "
                           f"{res.message}")
    return float(-res.fun)


# --------------------------------------------------------------------------
# evaluation driver
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TrafficResult:
    """Link-load accounting of one pattern on one topology.

    ``max_link_load``/``mean_link_load`` are per *directed* link in injection
    units (each undirected edge is two directed links, loaded independently);
    ``saturation_throughput`` = 1/max load; ``conservation_error`` is the
    relative gap between the load sum and the demand-weighted hop count
    (should be float32-roundoff small).
    """
    name: str
    pattern: str
    n: int
    total_demand: float            # injection units offered (reachable pairs)
    dropped_demand: float          # injection units to unreachable targets
    avg_hops: float                # demand-weighted mean shortest-path hops
    link_loads: np.ndarray         # (n, k) directed loads (gather-table slots)
    max_link_load: float
    mean_link_load: float          # over loaded (non-padding) directed slots
    saturation_throughput: float   # 1 / max_link_load (inf if no load)
    conservation_error: float
    seconds: float
    exact: bool = True             # False = sampled-source estimate
    sample_correction: float = 1.0  # n/S factor applied to loads and totals
    scheme: str = "minimal"        # routing scheme the loads were routed by
    max_link_load_ucb: float = 0.0  # bootstrap UCB (== max when exact)

    def to_dict(self) -> Dict:
        """JSON-ready summary (drops the (n, k) load table)."""
        return dict(
            name=self.name, pattern=self.pattern, scheme=self.scheme,
            n=self.n, exact=self.exact,
            total_demand=round(self.total_demand, 6),
            dropped_demand=round(self.dropped_demand, 6),
            avg_hops=round(self.avg_hops, 6),
            max_link_load=round(self.max_link_load, 6),
            max_link_load_ucb=round(self.max_link_load_ucb, 6),
            mean_link_load=round(self.mean_link_load, 6),
            saturation_throughput=round(self.saturation_throughput, 6),
            conservation_error=self.conservation_error,
            seconds=round(self.seconds, 3))

    def report(self) -> str:
        """Compact text block for CLI reports."""
        return "\n".join([
            f"traffic         : {self.pattern} via {self.scheme} "
            f"({self.total_demand:.1f} units offered, "
            f"{self.avg_hops:.3f} avg hops)",
            f"max link load   : {self.max_link_load:.4f} "
            f"(mean {self.mean_link_load:.4f}) injection units",
            f"saturation thpt : {self.saturation_throughput:.4f} "
            f"injection fraction/node",
        ])


@obs.traced("traffic/evaluate", phase="execute")
def evaluate_traffic(topo: Union[Topology, Tuple[np.ndarray, int]],
                     pattern: str = "uniform", *,
                     scheme: str = "minimal",
                     slack: int = 1,
                     routing: Optional[RoutingResult] = None,
                     fiedler: Optional[np.ndarray] = None,
                     demands: Optional[np.ndarray] = None,
                     chunk: int = DEFAULT_SOURCE_CHUNK,
                     backend: Optional[str] = None) -> TrafficResult:
    """Route one synthetic pattern over a topology and account link loads.

    Args:
        topo: a :class:`Topology` or ``(table, n)`` padded-table pair.
        pattern: name from :data:`TRAFFIC_PATTERNS` (ignored when ``demands``
            is given, which then also names the result's pattern ``custom``).
        scheme: routing scheme from :data:`ROUTING_SCHEMES` (default
            ``minimal`` — the historical ECMP behaviour).
        slack: extra hops the ``ksp`` scheme admits beyond minimal
            (``dist + slack`` path budget); ignored by the other schemes.
        routing: reuse a :class:`RoutingResult` (e.g. the one a lazy Analysis
            session already computed); computed here if absent.  A *sampled*
            routing result (``exact=False``) is accepted: only its S source
            rows are routed and every extensive figure (loads, totals) is
            scaled by the unbiasedness correction n/S — uniform sources make
            the scaled per-link loads and totals unbiased estimators of the
            full-census figures.  ``max_link_load`` is then a noisy order
            statistic (biased low: unsampled sources contribute nothing);
            for the ``minimal`` scheme a bootstrap upper confidence bound
            ``max_link_load_ucb`` is computed over candidate hot slots and
            ``saturation_throughput`` uses *it*, so the sampled figure errs
            conservative rather than optimistic (other schemes keep the
            point estimate as the bound — see docs/scale.md).
        fiedler: Fiedler vector for the ``adversarial`` pattern.
        demands: explicit (n, n) demand matrix in injection units, overriding
            ``pattern`` (sampled routing uses its S source rows).
        chunk: sources per jitted call.
        backend: spmv backend for the load accumulation (default:
            dispatcher's).

    Returns:
        :class:`TrafficResult` with per-directed-link loads and the
        max-load / saturation-throughput summary.
    """
    t0 = time.time()
    if scheme not in ROUTING_SCHEMES:
        raise ValueError(f"unknown routing scheme {scheme!r} "
                         f"(known: {ROUTING_SCHEMES})")
    if isinstance(topo, Topology):
        name, n = topo.name, topo.n
        table = topo.gather_operands()[0]
    else:
        table, n = np.asarray(topo[0]), int(topo[1])
        name = f"table(n={n})"
    if routing is None:
        routing = analyze_routing((table, n), chunk=chunk)
    srcs = routing.sources
    S = srcs.size
    scale = 1.0 if routing.exact else n / S
    if demands is None:
        D = demand_rows(pattern, n, srcs, fiedler=fiedler)
    else:
        D = np.asarray(demands, dtype=np.float64)
        if D.shape != (n, n):
            raise ValueError(f"demands must be ({n}, {n}), got {D.shape}")
        D = D[srcs]
        pattern = "custom"
    reachable = routing.dist >= 0
    served = np.where(reachable, D, 0.0)
    served[np.arange(S), srcs] = 0.0
    total = float(served.sum())
    dropped = float(D.sum() - D[np.arange(S), srcs].sum() - total)
    loads, hops_weighted, _ = scheme_link_loads(
        table, routing, served, scheme, slack=slack, chunk=chunk,
        backend=backend)
    load_sum = float(loads.sum())
    # conservation holds per source row, so check it *before* the n/S scale
    conservation = abs(load_sum - hops_weighted) / max(hops_weighted, 1e-12)
    loads = loads * scale
    max_load = float(loads.max()) if loads.size else 0.0
    ucb = max_load
    if not routing.exact and scheme == "minimal" and max_load > 0:
        ucb = _max_link_load_ucb(table, routing, served, loads,
                                 chunk=chunk, backend=backend)
    sat_denom = max_load if routing.exact else ucb
    loaded = loads[loads > 0]
    return TrafficResult(
        name=name, pattern=pattern, n=n, total_demand=total * scale,
        dropped_demand=dropped * scale,
        avg_hops=hops_weighted / total if total > 0 else 0.0,
        link_loads=loads, max_link_load=max_load,
        mean_link_load=float(loaded.mean()) if loaded.size else 0.0,
        saturation_throughput=1.0 / sat_denom if sat_denom > 0
        else float("inf"),
        conservation_error=conservation,
        seconds=time.time() - t0,
        exact=routing.exact, sample_correction=scale,
        scheme=scheme, max_link_load_ucb=ucb)


def spectral_throughput_estimate(n: int, rho2: float) -> float:
    """Uniform-traffic saturation throughput predicted from the spectral gap.

    Uniform all-to-all pushes ``|X| * |Y| / (n-1)`` injection units across any
    (X, Y) cut per direction; supporting that over the Fiedler bisection floor
    (Theorem 2, ``rho2 * n / 4`` links at unit capacity) needs
    ``theta = BW * (n-1) / (n/2)^2 ≈ rho2`` — the spectral prediction the
    measured ECMP figure is compared against.  Deliberately uncapped, exactly
    like :attr:`TrafficResult.saturation_throughput` (both can exceed 1: a
    node injects over all ``radix`` links at once).  Dimensionless, same
    units as the measured figure.
    """
    lo, hi = n // 2, n - n // 2
    bw = rho2 * n / 4.0
    return bw * (n - 1) / float(lo * hi)
