"""Synthetic traffic patterns + minimal-path ECMP link-load accounting.

The routing layer (:mod:`repro.core.routing`) measures where shortest paths
*are*; this module loads them.  Each traffic pattern is a demand matrix
``D[s, t]`` normalized so every node injects at most 1 unit of traffic
(``sum_t D[s, t] <= 1``); flows follow **all** minimal paths with equal
splitting at every branch (ECMP, the SpectralFly evaluation model): the flow
from s to t crossing edge (u, v) on a shortest-path DAG is
``D[s,t] * sigma(s,u) * sigma(v,t) / sigma(s,t)``, computed by a Brandes-style
backward accumulation over BFS layers — one vectorized gather per layer,
batched over sources.

Units
-----
* demands and link loads are in *injection units*: load 1.0 on a directed
  link means it carries exactly one node's full injection rate;
* ``saturation_throughput`` = 1 / max link load: the factor every node can
  scale its injection by before the hottest link saturates (unit link
  capacity), dimensionless;
* conservation: the sum of all directed link loads equals
  ``sum_{s,t} D[s,t] * hops(s,t)`` exactly — each unit of flow occupies one
  unit of load per hop traversed.

Patterns (:data:`TRAFFIC_PATTERNS`)
-----------------------------------
* ``uniform``        — all-to-all, ``D[s, t] = 1/(n-1)``
* ``bit_complement`` — permutation ``t = (n-1) - s`` (bitwise complement when
  n is a power of two)
* ``transpose``      — permutation ``(a, b) → (b, a)`` for n = m*m (matrix
  transpose); raises for non-square n
* ``neighbor``       — nearest-neighbor stencil: half a unit to each of
  ``s ± 1 (mod n)``
* ``adversarial``    — spectrally adversarial permutation: vertices sorted by
  Fiedler value are matched first-to-last, forcing every flow across the
  sparsest (Fiedler) cut
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .graphs import Topology
from .routing import DEFAULT_SOURCE_CHUNK, RoutingResult, analyze_routing
from repro.kernels import spmv as KS

__all__ = [
    "TRAFFIC_PATTERNS", "TrafficResult", "demand_matrix", "demand_rows",
    "ecmp_link_loads", "evaluate_traffic", "spectral_throughput_estimate",
]

TRAFFIC_PATTERNS = ("uniform", "bit_complement", "transpose", "neighbor",
                    "adversarial")


# --------------------------------------------------------------------------
# demand matrices
# --------------------------------------------------------------------------

def _permutation_demands(perm: np.ndarray) -> np.ndarray:
    """Demand matrix of a permutation: one unit from s to perm[s] (fixed
    points send nothing — a node never loads the network talking to itself)."""
    n = perm.size
    D = np.zeros((n, n))
    s = np.arange(n)
    keep = perm != s
    D[s[keep], perm[keep]] = 1.0
    return D


def _pattern_permutation(pattern: str, n: int, *,
                         fiedler: Optional[np.ndarray] = None) -> np.ndarray:
    """The permutation behind a permutation-type pattern (O(n log n), no
    (n, n) matrix — the scalable core shared by matrix and row builders)."""
    if pattern == "bit_complement":
        return n - 1 - np.arange(n)
    if pattern == "transpose":
        m = math.isqrt(n)
        if m * m != n:
            raise ValueError(f"transpose traffic needs square n, got {n}")
        s = np.arange(n)
        return (s % m) * m + s // m
    if pattern == "adversarial":
        if fiedler is None:
            raise ValueError("adversarial traffic needs the Fiedler vector")
        order = np.argsort(np.asarray(fiedler, dtype=np.float64), kind="stable")
        perm = np.empty(n, dtype=np.int64)
        perm[order] = order[::-1]
        return perm
    raise ValueError(f"unknown traffic pattern {pattern!r} "
                     f"(known: {TRAFFIC_PATTERNS})")


def demand_rows(pattern: str, n: int, sources: Sequence[int], *,
                fiedler: Optional[np.ndarray] = None) -> np.ndarray:
    """The ``sources`` rows of :func:`demand_matrix` without materializing it.

    This is the datacenter-scale entry point: an (n, n) float64 demand matrix
    at n = 65536 is 32 GiB, but a sampled traffic evaluation only ever routes
    the S sampled source rows.  Row order follows ``sources``.  Exactly equal
    to ``demand_matrix(pattern, n)[sources]`` (tested), so the sampled path
    inherits every pattern's semantics.
    """
    srcs = np.asarray(list(sources), dtype=np.int64)
    S = srcs.size
    rows = np.arange(S)
    if pattern == "uniform":
        if n < 2:
            raise ValueError("uniform traffic needs n >= 2")
        D = np.full((S, n), 1.0 / (n - 1))
        D[rows, srcs] = 0.0
        return D
    if pattern == "neighbor":
        D = np.zeros((S, n))
        np.add.at(D, (rows, (srcs + 1) % n), 0.5)
        np.add.at(D, (rows, (srcs - 1) % n), 0.5)
        D[rows, srcs] = 0.0
        return D
    perm = _pattern_permutation(pattern, n, fiedler=fiedler)
    D = np.zeros((S, n))
    keep = perm[srcs] != srcs
    D[rows[keep], perm[srcs[keep]]] = 1.0
    return D


def demand_matrix(pattern: str, n: int, *,
                  fiedler: Optional[np.ndarray] = None) -> np.ndarray:
    """Build the (n, n) demand matrix of a named synthetic pattern.

    Args:
        pattern: one of :data:`TRAFFIC_PATTERNS`.
        n: number of nodes.
        fiedler: (n,) Fiedler vector, required by ``adversarial`` (it defines
            the cut the permutation stresses).

    Returns:
        (n, n) float64 demands in injection units; row sums are <= 1 and the
        diagonal is 0.
    """
    if pattern == "uniform":
        if n < 2:
            raise ValueError("uniform traffic needs n >= 2")
        D = np.full((n, n), 1.0 / (n - 1))
        np.fill_diagonal(D, 0.0)
        return D
    if pattern == "neighbor":
        D = np.zeros((n, n))
        s = np.arange(n)
        D[s, (s + 1) % n] += 0.5
        D[s, (s - 1) % n] += 0.5
        np.fill_diagonal(D, 0.0)   # n <= 2 degenerates to self-traffic
        return D
    return _permutation_demands(_pattern_permutation(pattern, n,
                                                     fiedler=fiedler))


# --------------------------------------------------------------------------
# ECMP link loads (Brandes-style backward accumulation, batched over sources)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def _ecmp_loads_chunk(table: jnp.ndarray, dist: jnp.ndarray,
                      sigma: jnp.ndarray, w: jnp.ndarray,
                      backend: Optional[str] = None) -> jnp.ndarray:
    """Summed per-edge ECMP loads for a (S, n) block of sources.

    For each source: backward accumulation over BFS layers d = dmax..1 of
    ``g(v) = w(v) + sigma(v) * sum_{v' in succ(v)} g(v')/sigma(v')`` (the
    demand subtree routed through v) — the per-layer neighbor sum is one spmv
    through the :mod:`repro.kernels.spmv` dispatcher — then the per-slot
    directed edge loads ``load[u, j] = sigma(u) * g(v)/sigma(v)`` for
    ``v = table[u, j]`` one hop further out.  Self-padded slots have equal
    dist and drop out of the mask.  Returns the (n, k) load table summed over
    the block's sources.
    """
    bk = KS.resolve_backend(backend)
    dmax = jnp.maximum(dist.max(), 0)

    def one(dist_s, sigma_s, w_s):
        sigma_safe = jnp.where(sigma_s > 0, sigma_s, 1.0)

        def back(i, g):
            d = dmax - i
            h = jnp.where(dist_s == d, g / sigma_safe, 0.0)
            inc = KS.spmv(h, table, backend=bk)
            return jnp.where(dist_s == d - 1, g + sigma_s * inc, g)

        g = jax.lax.fori_loop(0, dmax, back, w_s)
        ratio = jnp.where(dist_s > 0, g / sigma_safe, 0.0)
        succ = dist_s[table] == (dist_s[:, None] + 1)
        return sigma_s[:, None] * jnp.where(succ, ratio[table], 0.0)

    return jax.vmap(one)(dist, sigma, w).sum(axis=0)


def ecmp_link_loads(table: np.ndarray, dist: np.ndarray, sigma: np.ndarray,
                    demands: np.ndarray,
                    chunk: int = DEFAULT_SOURCE_CHUNK,
                    backend: Optional[str] = None) -> np.ndarray:
    """Directed link loads under minimal-path ECMP routing of ``demands``.

    Args:
        table: (n, k) padded neighbor table (``gather_operands()[0]``).
        dist: (S, n) BFS distances from :func:`repro.core.routing.bfs_distances`.
        sigma: (S, n) minimal-path counts matching ``dist``.
        demands: (S, n) demand rows in injection units, one per BFS source
            (row s holds D[s, :]).  Demands to unreachable targets are ignored
            (dropped, reported by :func:`evaluate_traffic`).
        chunk: sources per jitted call.

    Returns:
        (n, k) float64 directed loads aligned with the table slots: entry
        ``[u, j]`` is the load on directed link u → table[u, j] (padding slots
        stay 0; parallel edges each get their ECMP share).
    """
    table = np.asarray(table)
    tab = jnp.asarray(table, dtype=jnp.int32)
    # a demand to an unreachable target would otherwise sit in g forever
    demands = np.where(dist >= 0, demands, 0.0)
    loads = np.zeros(table.shape, dtype=np.float64)
    for lo in range(0, dist.shape[0], chunk):
        hi = min(lo + chunk, dist.shape[0])
        loads += np.asarray(_ecmp_loads_chunk(
            tab, jnp.asarray(dist[lo:hi]),
            jnp.asarray(sigma[lo:hi], dtype=jnp.float32),
            jnp.asarray(demands[lo:hi], dtype=jnp.float32),
            backend=backend), dtype=np.float64)
    return loads


# --------------------------------------------------------------------------
# evaluation driver
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TrafficResult:
    """Link-load accounting of one pattern on one topology.

    ``max_link_load``/``mean_link_load`` are per *directed* link in injection
    units (each undirected edge is two directed links, loaded independently);
    ``saturation_throughput`` = 1/max load; ``conservation_error`` is the
    relative gap between the load sum and the demand-weighted hop count
    (should be float32-roundoff small).
    """
    name: str
    pattern: str
    n: int
    total_demand: float            # injection units offered (reachable pairs)
    dropped_demand: float          # injection units to unreachable targets
    avg_hops: float                # demand-weighted mean shortest-path hops
    link_loads: np.ndarray         # (n, k) directed loads (gather-table slots)
    max_link_load: float
    mean_link_load: float          # over loaded (non-padding) directed slots
    saturation_throughput: float   # 1 / max_link_load (inf if no load)
    conservation_error: float
    seconds: float
    exact: bool = True             # False = sampled-source estimate
    sample_correction: float = 1.0  # n/S factor applied to loads and totals

    def to_dict(self) -> Dict:
        """JSON-ready summary (drops the (n, k) load table)."""
        return dict(
            name=self.name, pattern=self.pattern, n=self.n, exact=self.exact,
            total_demand=round(self.total_demand, 6),
            dropped_demand=round(self.dropped_demand, 6),
            avg_hops=round(self.avg_hops, 6),
            max_link_load=round(self.max_link_load, 6),
            mean_link_load=round(self.mean_link_load, 6),
            saturation_throughput=round(self.saturation_throughput, 6),
            conservation_error=self.conservation_error,
            seconds=round(self.seconds, 3))

    def report(self) -> str:
        """Compact text block for CLI reports."""
        return "\n".join([
            f"traffic         : {self.pattern} "
            f"({self.total_demand:.1f} units offered, "
            f"{self.avg_hops:.3f} avg hops)",
            f"max link load   : {self.max_link_load:.4f} "
            f"(mean {self.mean_link_load:.4f}) injection units",
            f"saturation thpt : {self.saturation_throughput:.4f} "
            f"injection fraction/node",
        ])


def evaluate_traffic(topo: Union[Topology, Tuple[np.ndarray, int]],
                     pattern: str = "uniform", *,
                     routing: Optional[RoutingResult] = None,
                     fiedler: Optional[np.ndarray] = None,
                     demands: Optional[np.ndarray] = None,
                     chunk: int = DEFAULT_SOURCE_CHUNK,
                     backend: Optional[str] = None) -> TrafficResult:
    """Route one synthetic pattern over a topology and account link loads.

    Args:
        topo: a :class:`Topology` or ``(table, n)`` padded-table pair.
        pattern: name from :data:`TRAFFIC_PATTERNS` (ignored when ``demands``
            is given, which then also names the result's pattern ``custom``).
        routing: reuse a :class:`RoutingResult` (e.g. the one a lazy Analysis
            session already computed); computed here if absent.  A *sampled*
            routing result (``exact=False``) is accepted: only its S source
            rows are routed and every extensive figure (loads, totals) is
            scaled by the unbiasedness correction n/S — uniform sources make
            the scaled per-link loads and totals unbiased estimators of the
            full-census figures.  ``max_link_load`` is then a noisy order
            statistic (biased low: unsampled sources contribute nothing), so
            treat sampled saturation throughput as an optimistic estimate.
        fiedler: Fiedler vector for the ``adversarial`` pattern.
        demands: explicit (n, n) demand matrix in injection units, overriding
            ``pattern`` (sampled routing uses its S source rows).
        chunk: sources per jitted call.
        backend: spmv backend for the load accumulation (default:
            dispatcher's).

    Returns:
        :class:`TrafficResult` with per-directed-link loads and the
        max-load / saturation-throughput summary.
    """
    t0 = time.time()
    if isinstance(topo, Topology):
        name, n = topo.name, topo.n
        table = topo.gather_operands()[0]
    else:
        table, n = np.asarray(topo[0]), int(topo[1])
        name = f"table(n={n})"
    if routing is None:
        routing = analyze_routing((table, n), chunk=chunk)
    srcs = routing.sources
    S = srcs.size
    scale = 1.0 if routing.exact else n / S
    if demands is None:
        D = demand_rows(pattern, n, srcs, fiedler=fiedler)
    else:
        D = np.asarray(demands, dtype=np.float64)
        if D.shape != (n, n):
            raise ValueError(f"demands must be ({n}, {n}), got {D.shape}")
        D = D[srcs]
        pattern = "custom"
    reachable = routing.dist >= 0
    served = np.where(reachable, D, 0.0)
    served[np.arange(S), srcs] = 0.0
    total = float(served.sum())
    dropped = float(D.sum() - D[np.arange(S), srcs].sum() - total)
    loads = ecmp_link_loads(table, routing.dist, routing.sigma, served,
                            chunk=chunk, backend=backend)
    hops_weighted = float((served * np.maximum(routing.dist, 0)).sum())
    load_sum = float(loads.sum())
    # conservation holds per source row, so check it *before* the n/S scale
    conservation = abs(load_sum - hops_weighted) / max(hops_weighted, 1e-12)
    loads = loads * scale
    max_load = float(loads.max()) if loads.size else 0.0
    loaded = loads[loads > 0]
    return TrafficResult(
        name=name, pattern=pattern, n=n, total_demand=total * scale,
        dropped_demand=dropped * scale,
        avg_hops=hops_weighted / total if total > 0 else 0.0,
        link_loads=loads, max_link_load=max_load,
        mean_link_load=float(loaded.mean()) if loaded.size else 0.0,
        saturation_throughput=1.0 / max_load if max_load > 0 else float("inf"),
        conservation_error=conservation,
        seconds=time.time() - t0,
        exact=routing.exact, sample_correction=scale)


def spectral_throughput_estimate(n: int, rho2: float) -> float:
    """Uniform-traffic saturation throughput predicted from the spectral gap.

    Uniform all-to-all pushes ``|X| * |Y| / (n-1)`` injection units across any
    (X, Y) cut per direction; supporting that over the Fiedler bisection floor
    (Theorem 2, ``rho2 * n / 4`` links at unit capacity) needs
    ``theta = BW * (n-1) / (n/2)^2 ≈ rho2`` — the spectral prediction the
    measured ECMP figure is compared against.  Deliberately uncapped, exactly
    like :attr:`TrafficResult.saturation_throughput` (both can exceed 1: a
    node injects over all ``radix`` links at once).  Dimensionless, same
    units as the measured figure.
    """
    lo, hi = n // 2, n - n // 2
    bw = rho2 * n / 4.0
    return bw * (n - 1) / float(lo * hi)
