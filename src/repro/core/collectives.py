"""Topology-aware collective cost model — the paper's thesis, operationalized.

For a training step the roofline collective term depends on *which physical
topology* carries the traffic.  This module predicts the time of the standard
collectives on an arbitrary topology from exactly the quantities the paper
studies:

* **bandwidth terms** are limited by (a) per-node injection (radix x link_bw)
  and (b) the bisection bandwidth — lower-bounded spectrally via Fiedler
  (Theorem 2: BW >= rho2 n/4), which is the *guaranteed* figure a scheduler
  can rely on, or an exact/witnessed figure when known;
* **latency terms** scale with the diameter (Theorem 1 bounds it by rho2);
* on an *alpha-fraction of nodes* (job placement / degraded operation after
  faults) the Ramanujan discrepancy property (§3) keeps a guaranteed bisection;
  arbitrary topologies fall back to their worst observed subset cut.

Time model per collective, for payload B bytes per node over n nodes:
    t = max(t_injection, t_bisection) + t_latency
with the per-algorithm traffic factors below.  This is an (alpha, beta) model;
it does not simulate routing/congestion beyond the bisection abstraction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

from .bounds import expected_degraded_rho2, fiedler_bw_lb, ramanujan_rho2
from .graphs import Topology

__all__ = ["NetworkModel", "network_from_topology", "tpu_v5e_ici",
           "COLLECTIVE_FACTORS"]

# v5e-class constants (per system prompt)
LINK_BW = 50e9           # bytes/s per ICI link
PER_HOP_LATENCY = 1e-6   # seconds


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Abstract interconnect: everything the cost model needs.

    Units: ``link_bw`` bytes/second per link; ``hop_latency`` seconds per hop;
    ``diameter``/``avg_hops`` hops; ``bisection_links``/``radix`` link counts;
    every ``all_reduce``-style method returns **seconds**.
    """
    name: str
    n: int                  # nodes (chips)
    radix: int              # links per node (as built)
    bisection_links: float  # links crossing the worst balanced cut (guaranteed)
    diameter: int           # hops; measured (routing) or bounded (Theorem 1)
    link_bw: float = LINK_BW
    hop_latency: float = PER_HOP_LATENCY
    rho2: Optional[float] = None          # algebraic connectivity, if known
    effective_radix: Optional[float] = None  # surviving links/node (degraded)
    fault_rate: float = 0.0               # cumulative fraction already failed
    avg_hops: Optional[float] = None      # measured mean shortest-path hops

    # ---- collective times (payload = bytes per node) ----------------------
    def _bw_time(self, inj_bytes: float, cross_bytes: float) -> float:
        """Bandwidth term: max of per-node injection and bisection bottleneck.

        Args: bytes each node must inject / bytes that must cross the worst
        balanced cut.  Returns seconds.
        """
        inj_links = self.effective_radix if self.effective_radix is not None \
            else self.radix
        t_inj = inj_bytes / (inj_links * self.link_bw)
        t_cut = cross_bytes / (self.bisection_links * self.link_bw)
        return max(t_inj, t_cut)

    @property
    def permute_hops(self) -> float:
        """Hops a point-to-point permutation flow travels: the *measured*
        average shortest-path length when a routing analysis supplied one,
        else the diameter (the conservative fallback).  Dimensionless (hops).
        """
        return self.avg_hops if self.avg_hops is not None else float(self.diameter)

    # ---- degraded operation ----------------------------------------------
    def degrade(self, fault_rate: float, model: str = "link") -> "NetworkModel":
        """View of this network after ``fault_rate`` of its links ("link") or
        routers ("node") have failed — collective predictions then reflect the
        guaranteed degraded bisection.

        Args:
            fault_rate: fraction of links/routers failed, in [0, 1).
            model: ``"link"`` (iid link death) or ``"node"`` (router death;
                the surviving machine shrinks to ``round(n * (1-r))`` nodes).

        Returns:
            A new frozen :class:`NetworkModel`; ``degrade(0.0)`` is an exact
            no-op (returns ``self``) and successive calls compose.

        Under iid link failure E[L_degraded] = (1 - r) L, so the certified
        figure is the Fiedler floor at the expected degraded gap
        rho2 * (1 - r) — equivalently the healthy bisection scaled by (1 - r)
        (node failure kills a cut link when either endpoint dies: (1 - r)^2).
        Injection capacity degrades to ``effective_radix = radix * (1 - r)``
        and, when rho2 is known, the diameter is bumped to the Theorem-1
        (Alon–Milman) upper bound at the degraded gap — for a *measured*
        degraded diameter instead of this analytic cap, route the degraded
        topology itself (``Analysis.fault_sweep(routing=True)``).  A measured
        healthy ``avg_hops`` is dropped (it no longer describes the degraded
        paths), falling latency terms back to the diameter.
        """
        if not 0.0 <= fault_rate < 1.0:
            raise ValueError(f"fault rate must be in [0, 1), got {fault_rate}")
        if model not in ("link", "node"):
            raise ValueError(f"degrade model must be 'link' or 'node', "
                             f"got {model!r}")
        if fault_rate == 0.0:
            return self
        s = 1.0 - fault_rate
        n = self.n if model == "link" else max(int(round(self.n * s)), 2)
        cut_survival = s if model == "link" else s * s
        rho2_deg = None if self.rho2 is None \
            else expected_degraded_rho2(self.rho2, fault_rate)
        diameter = self.diameter
        if rho2_deg is not None and rho2_deg > 0:
            from .bounds import alon_milman_diameter_ub
            kmax = self.effective_radix if self.effective_radix is not None \
                else self.radix
            diameter = max(self.diameter,
                           int(alon_milman_diameter_ub(n, kmax, rho2_deg)))
        inj = self.effective_radix if self.effective_radix is not None \
            else float(self.radix)
        return dataclasses.replace(
            self, name=f"{self.name}!{model}@{fault_rate:g}", n=n,
            bisection_links=max(self.bisection_links * cut_survival, 1e-9),
            diameter=diameter, rho2=rho2_deg,
            effective_radix=inj * s, avg_hops=None,
            fault_rate=1.0 - (1.0 - self.fault_rate) * s)

    def _lat(self, steps: float) -> float:
        """Latency term: ``steps`` hops at ``hop_latency`` each.  Seconds."""
        return steps * self.hop_latency

    def all_reduce(self, bytes_per_node: float) -> float:
        """Predicted all-reduce time (reduce-scatter + all-gather).

        Args: ``bytes_per_node`` — payload each node contributes (bytes).
        Returns seconds.  Each node moves 2B(n-1)/n; 2B crosses every
        bisection (reduced data out + result back).
        """
        b = bytes_per_node
        return self._bw_time(2 * b * (self.n - 1) / self.n, 2 * b) \
            + self._lat(2 * self.diameter + 2 * math.log2(max(self.n, 2)))

    def reduce_scatter(self, bytes_per_node: float) -> float:
        """Predicted reduce-scatter time for B input bytes/node.  Seconds."""
        b = bytes_per_node
        return self._bw_time(b * (self.n - 1) / self.n, b) \
            + self._lat(self.diameter + math.log2(max(self.n, 2)))

    def all_gather(self, bytes_per_node_out: float) -> float:
        """Predicted all-gather time; each node ends with B total gathered
        bytes (B/n contributed each).  Returns seconds."""
        b = bytes_per_node_out
        return self._bw_time(b * (self.n - 1) / self.n, b) \
            + self._lat(self.diameter + math.log2(max(self.n, 2)))

    def broadcast(self, bytes_total: float) -> float:
        """Predicted one-to-all broadcast time for B total bytes.  Seconds.
        The root injects B once over its own links, B crosses every bisection
        once, and propagation needs at least ecc(root) >= radius >=
        ceil(diam/2) hops — the model is root-agnostic, so it charges that
        certified floor (the diameter itself would over-promise for a
        central root).  A lower bound any executed broadcast tree obeys."""
        b = bytes_total
        return self._bw_time(b, b) + self._lat(math.ceil(self.diameter / 2))

    def all_to_all(self, bytes_per_node: float) -> float:
        """Predicted all-to-all time for B bytes sent per node (split across
        all peers).  Returns seconds.  Cross-traffic = (n/2 senders x B/2
        destined across) = n*B/4 over the cut."""
        b = bytes_per_node
        return self._bw_time(b * (self.n - 1) / self.n, self.n * b / 4.0) \
            + self._lat(self.diameter)

    def collective_time(self, kind: str, bytes_per_node: float) -> float:
        """Dispatch by collective name (keys of :data:`COLLECTIVE_FACTORS`).

        Args: ``kind`` collective name; ``bytes_per_node`` payload (bytes).
        Returns seconds.  ``collective-permute`` travels the *measured*
        average hop count when known (:attr:`permute_hops`), else the
        diameter.
        """
        return {
            "all-reduce": self.all_reduce,
            "all-gather": self.all_gather,
            "reduce-scatter": self.reduce_scatter,
            "all-to-all": self.all_to_all,
            "broadcast": self.broadcast,
            "collective-permute":
                lambda b: b / self.link_bw + self._lat(self.permute_hops),
        }[kind](bytes_per_node)

    # ---- empirical validation against an executed schedule ----------------
    def validate(self, sim) -> Dict[str, Any]:
        """Measured/predicted ratios for an executed schedule — the first
        empirical check that the spectral (alpha, beta) figures this model
        certifies are actually attained by a schedule that ran.

        Args:
            sim: a :class:`repro.core.simulate.SimulationResult` (duck-typed:
                ``collective``/``algorithm`` names, ``payload_bytes`` and
                ``time_seconds`` arrays).  The simulation must have run with
                this model's ``link_bw``/``hop_latency`` for the comparison
                to be apples-to-apples.

        Returns:
            dict with ``collective``, ``algorithm``, per-payload ``rows``
            (``payload_bytes``, ``measured_s``, ``predicted_s``, ``ratio`` =
            measured/predicted) and ``all_measured_geq_predicted`` — the
            analytic model is a *lower* bound, so a ratio below 1 - 1e-6
            means the certificate over-promised (or constants diverged).
        """
        kind = str(sim.collective).replace("_", "-")
        if kind not in COLLECTIVE_FACTORS:
            raise ValueError(
                f"cannot validate {sim.collective!r}: the analytic model "
                f"only predicts {sorted(COLLECTIVE_FACTORS)}")
        rows = []
        ok = True
        for p, t in zip(sim.payload_bytes, sim.time_seconds):
            pred = self.collective_time(kind, float(p))
            ratio = float(t) / pred if pred > 0 else float("inf")
            ok &= float(t) >= pred * (1.0 - 1e-6)
            rows.append(dict(payload_bytes=float(p), measured_s=float(t),
                             predicted_s=pred, ratio=ratio))
        return dict(collective=kind, algorithm=sim.algorithm, rows=rows,
                    all_measured_geq_predicted=bool(ok))


def network_from_topology(topo: Topology, diameter: Optional[int] = None,
                          rho2: Optional[float] = None,
                          exact_bisection: Optional[float] = None,
                          vertex_transitive: bool = True,
                          routing: Optional[object] = None) -> NetworkModel:
    """Build the model from a constructed Topology.

    Args:
        topo: the physical interconnect graph (must be regular).
        diameter: known diameter in hops; measured by BFS when omitted.
        rho2: known algebraic connectivity; solved when omitted.
        exact_bisection: exact bisection link count, if known.
        vertex_transitive: lets the BFS diameter use one eccentricity.
        routing: a :class:`repro.core.routing.RoutingResult` from a path-level
            analysis; when given, its *measured* exact diameter and average
            hop count replace the BFS/Theorem-1 figures (``avg_hops`` then
            drives ``collective-permute`` latency).

    Returns:
        A :class:`NetworkModel` whose bisection uses the *guaranteed*
        (Fiedler) figure unless an exact value is supplied — this is the
        paper's point: the spectral gap is what a scheduler can certify
        without solving min-bisection.
    """
    from .properties import diameter as diam_fn
    from .spectral import algebraic_connectivity

    if rho2 is None:
        rho2 = algebraic_connectivity(topo)
    avg_hops = None
    if routing is not None:
        if diameter is None and routing.exact:
            diameter = int(routing.diameter)
        avg_hops = float(routing.avg_path_length)
    if diameter is None:
        diameter = diam_fn(topo, vertex_transitive=vertex_transitive)
    bisection = exact_bisection if exact_bisection is not None \
        else fiedler_bw_lb(topo.n, rho2)
    return NetworkModel(name=topo.name, n=topo.n, radix=topo.radix,
                        bisection_links=max(bisection, 1e-9), diameter=diameter,
                        rho2=rho2, avg_hops=avg_hops)


def tpu_v5e_ici(x: int = 16, y: int = 16) -> NetworkModel:
    """The *faithful* model of a v5e pod: Torus(x) x Torus(y) ICI.

    Args: ``x``, ``y`` — torus extents (chips per ring).
    Returns a :class:`NetworkModel` with the closed-form figures:
    rho2 = 2(1 - cos(2 pi / max(x,y))) (paper §4.1); bisection of a 2D torus
    is 2*min(x,y) links; diameter x/2 + y/2 hops.
    """
    n = x * y
    rho2 = 2.0 * (1 - math.cos(2 * math.pi / max(x, y)))
    return NetworkModel(name=f"torus({x}x{y})", n=n, radix=4,
                        bisection_links=2.0 * min(x, y),
                        diameter=x // 2 + y // 2, rho2=rho2)


# traffic factors used by the roofline report (documents the model above)
COLLECTIVE_FACTORS = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "broadcast": 1.0, "collective-permute": 1.0,
}
