"""Link-level collective & workload simulator: execute what the model predicts.

Every earlier layer *predicts*: :class:`~repro.core.collectives.NetworkModel`
is a closed-form (alpha, beta) model, :mod:`repro.core.traffic` computes
static ECMP loads, and the spectral layer bounds both.  This module closes the
loop by **executing** collective algorithms and traffic workloads round by
round on the physical links of any topology, so the Theorem-2 figures the
scheduler relies on are checked against a schedule that actually ran.

Two stages, one operand contract (the padded ``(n, k)`` gather table shared
with :mod:`spectral` / :mod:`faults` / :mod:`routing`):

1. **Schedule compiler** — :func:`compile_schedule` lowers a named algorithm
   (``ring``, ``halving_doubling``, ``binomial``, ``bruck``, ``bfs_tree``)
   into a :class:`Schedule`: per-round *slot-aligned* ``(n, k)`` transfer
   tensors.  Logical transfers between non-adjacent nodes are routed over all
   minimal paths with equal splitting (the ECMP lowering reuses
   :func:`repro.core.routing.bfs_distances` / ``shortest_path_counts`` /
   :func:`repro.core.traffic.ecmp_link_loads`); the topology-aware
   ``bfs_tree`` broadcast maps straight onto physical parent→child links.
   Identical rounds are stored once with a repetition count (a ring
   all-reduce is ONE unique round × ``2(n-1)``), so schedules stay small at
   ``lps(13,5)`` scale.
2. **Round engine** — :func:`run_schedule` advances a jitted
   ``lax.while_loop`` over the unique rounds: every directed link drains its
   round bytes at ``link_bw``, the round completes when the most contended
   link finishes (synchronous round semantics), and a store-and-forward
   latency term charges ``hop_latency`` per hop of the round's longest
   transfer.  The engine is vmapped over B payload sizes in one call, and
   :func:`stacked_ring_allreduce` vmaps compile + engine over the
   ``(B, n, k)`` fault stacks of :func:`repro.core.faults.stacked_operands`
   (one device call for all B degraded samples).

Units: payloads and transfer tensors are **bytes** (``round_bytes`` is stored
per unit payload, i.e. a fraction of B); ``link_bw`` bytes/second per
directed link; ``hop_latency`` seconds/hop; all returned times are seconds;
link utilization is the dimensionless busy fraction busy_seconds / total.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .collectives import LINK_BW, PER_HOP_LATENCY
from .graphs import Topology
from .routing import (DEFAULT_SOURCE_CHUNK, RoutingResult, _bfs_dist_chunk,
                      _sigma_chunk, analyze_routing)
from .traffic import (ROUTING_SCHEMES, _ecmp_loads_chunk, demand_matrix,
                      scheme_link_loads)

__all__ = [
    "Schedule", "SimulationResult", "RoundTelemetry", "SIM_ALGORITHMS",
    "compile_schedule", "run_schedule", "simulate_collective",
    "simulate_traffic", "stacked_ring_allreduce",
]

#: collective -> known schedule algorithms (first entry is the default).
#: ``bruck`` / ``binomial`` / ``halving_doubling`` are the classic
#: topology-oblivious log-round schedules; ``ring`` is the bandwidth-optimal
#: chain; ``bfs_tree`` is the topology-AWARE broadcast (a BFS spanning tree
#: of physical links — no multi-hop routing at all).
SIM_ALGORITHMS: Dict[str, Tuple[str, ...]] = {
    "all_reduce": ("ring", "halving_doubling"),
    "reduce_scatter": ("ring", "halving_doubling"),
    "all_gather": ("ring", "bruck", "halving_doubling"),
    "broadcast": ("bfs_tree", "binomial"),
}


# --------------------------------------------------------------------------
# schedule representation
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Schedule:
    """A compiled collective: unique per-round link-transfer tensors.

    ``round_bytes[u]`` holds the bytes each directed gather-table slot
    ``(v, j)`` (link v → table[v, j]) carries in round u **per unit payload**
    (multiply by B to get bytes); ``counts[u]`` repeats identical rounds
    without storing them twice; ``hops[u]`` is the longest shortest-path any
    transfer of round u travels (the round's store-and-forward latency, in
    hops).  ``rounds`` = ``counts.sum()`` is the executed round count.
    """
    name: str
    collective: str
    algorithm: str
    n: int
    k: int                       # gather-table width (directed slots per node)
    round_bytes: np.ndarray      # (U, n, k) float32, bytes per unit payload
    counts: np.ndarray           # (U,) int32 repetitions of each unique round
    hops: np.ndarray             # (U,) int32 max hops travelled in the round
    dropped_demand: float = 0.0  # unit-payload bytes to unreachable targets

    @property
    def unique_rounds(self) -> int:
        return int(self.round_bytes.shape[0])

    @property
    def rounds(self) -> int:
        return int(self.counts.sum())

    def total_link_bytes(self) -> np.ndarray:
        """(n, k) bytes per unit payload each directed slot carries in total."""
        return (self.round_bytes
                * self.counts[:, None, None].astype(np.float64)).sum(axis=0)


def _logical_rounds_ring(n: int, phases: int) -> List[Tuple[np.ndarray, int, float]]:
    """Ring chain s -> s+1 (mod n): one unique demand, ``phases*(n-1)`` rounds,
    1/n of the payload per node per round."""
    D = np.zeros((n, n))
    s = np.arange(n)
    D[s, (s + 1) % n] = 1.0 / n
    np.fill_diagonal(D, 0.0)        # n == 1 degenerates to self-traffic
    return [(D, phases * (n - 1), 1.0)]


def _require_pow2(n: int, algorithm: str) -> int:
    t = n.bit_length() - 1
    if n <= 0 or (1 << t) != n:
        raise ValueError(f"{algorithm} needs a power-of-two node count, "
                         f"got n={n}; use algorithm='ring' instead")
    return t


def _logical_rounds_halving_doubling(n: int, phases: int
                                     ) -> List[Tuple[np.ndarray, int, float]]:
    """Recursive halving (reduce-scatter) / doubling (all-gather): round i
    pairs s with s XOR 2^i and exchanges 1/2^(i+1) of the payload.  An
    all-reduce (phases=2) runs each exchange twice — once per direction of
    the butterfly — so each unique round gets count 2."""
    t = _require_pow2(n, "halving_doubling")
    s = np.arange(n)
    out = []
    for i in range(t):
        D = np.zeros((n, n))
        D[s, s ^ (1 << i)] = 1.0 / float(1 << (i + 1))
        out.append((D, phases, 1.0))
    return out


def _logical_rounds_bruck(n: int) -> List[Tuple[np.ndarray, int, float]]:
    """Bruck all-gather: ceil(log2 n) rounds; in round i node s sends its
    accumulated min(2^i, n - 2^i) blocks (of 1/n payload each) to
    (s - 2^i) mod n."""
    s = np.arange(n)
    out = []
    i = 0
    while (1 << i) < n:
        blocks = min(1 << i, n - (1 << i))
        D = np.zeros((n, n))
        D[s, (s - (1 << i)) % n] = blocks / float(n)
        out.append((D, 1, 1.0))
        i += 1
    return out


def _logical_rounds_binomial(n: int, root: int
                             ) -> List[Tuple[np.ndarray, int, float]]:
    """Binomial-tree broadcast from ``root``: in round i every node that
    already holds the payload (rank-distance < 2^i from the root) forwards the
    full payload to rank-distance +2^i."""
    out = []
    i = 0
    while (1 << i) < max(n, 2):
        D = np.zeros((n, n))
        senders = np.arange(min(1 << i, n))
        receivers = senders + (1 << i)
        keep = receivers < n
        D[(senders[keep] + root) % n, (receivers[keep] + root) % n] = 1.0
        if keep.any():
            out.append((D, 1, 1.0))
        i += 1
    return out


def _unpack_topo(topo: Union[Topology, Tuple[np.ndarray, int]]
                 ) -> Tuple[str, int, np.ndarray]:
    """(name, n, padded table) from a Topology or a ``(table, n)`` pair; the
    schedules below all need at least two nodes (and hence k >= 1 slots)."""
    if isinstance(topo, Topology):
        name, n = topo.name, topo.n
        table = topo.gather_operands()[0]
    else:
        table, n = np.asarray(topo[0]), int(topo[1])
        name = f"table(n={n})"
    if n < 2:
        raise ValueError(f"simulation needs at least 2 nodes, got n={n}")
    return name, n, table


def _lower_demand_rounds(table: np.ndarray, routing: RoutingResult,
                         logical: List[Tuple[np.ndarray, int, float]],
                         chunk: int, scheme: str = "minimal",
                         slack: int = 1) -> Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray, float]:
    """Lower logical (demand, count) rounds onto the gather-table slots under
    one of the traffic layer's routing schemes (minimal ECMP by default —
    Valiant/UGAL/ksp let executed collectives ride non-minimal paths)."""
    dist = routing.dist
    reachable = dist >= 0
    rounds, counts, hops = [], [], []
    dropped = 0.0
    for D, count, _scale in logical:
        served = np.where(reachable, D, 0.0)
        np.fill_diagonal(served, 0.0)
        dropped += count * float(D.sum() - np.trace(D) - served.sum())
        loads, _, max_hops = scheme_link_loads(
            table, routing, served, scheme, slack=slack, chunk=chunk)
        rounds.append(loads.astype(np.float32))
        counts.append(count)
        hops.append(int(max_hops))
    return (np.stack(rounds), np.asarray(counts, dtype=np.int32),
            np.asarray(hops, dtype=np.int32), dropped)


def _bfs_tree_rounds(table: np.ndarray, dist_root: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Broadcast over a BFS spanning tree: round d loads exactly the physical
    parent→child links between depths d-1 and d (no ECMP — every transfer is
    one hop).  Each child's parent is its lowest-id neighbor one layer up."""
    n, k = table.shape
    depth = int(dist_root.max())
    nbr_dist = dist_root[table]                        # (n, k)
    is_parent = nbr_dist == (dist_root[:, None] - 1)
    # lowest-id parent per reached non-root vertex (stable, deterministic)
    parent_ids = np.where(is_parent, table, n)
    parent = parent_ids.min(axis=1)
    rounds, counts, hops = [], [], []
    for d in range(1, depth + 1):
        children = np.nonzero((dist_root == d) & (parent < n))[0]
        loads = np.zeros((n, k), dtype=np.float32)
        for c in children:                             # host-side; <= n total
            row = table[parent[c]]
            j = int(np.nonzero(row == c)[0][0])
            loads[parent[c], j] += 1.0
        rounds.append(loads)
        counts.append(1)
        hops.append(1)
    if not rounds:                                     # n == 1 or shattered root
        rounds = [np.zeros((n, k), dtype=np.float32)]
        counts, hops = [1], [0]
    return (np.stack(rounds), np.asarray(counts, dtype=np.int32),
            np.asarray(hops, dtype=np.int32))


@obs.traced("simulate/compile_schedule", phase="compile")
def compile_schedule(topo: Union[Topology, Tuple[np.ndarray, int]],
                     collective: str = "all_reduce",
                     algorithm: Optional[str] = None, *,
                     routing: Optional[RoutingResult] = None,
                     root: int = 0,
                     scheme: str = "minimal",
                     slack: int = 1,
                     chunk: int = DEFAULT_SOURCE_CHUNK) -> Schedule:
    """Lower one collective algorithm onto a topology's physical links.

    Args:
        topo: a :class:`Topology` or ``(table, n)`` padded gather-table pair
            (the degraded-operation entry point).
        collective: key of :data:`SIM_ALGORITHMS` (``all_reduce``,
            ``reduce_scatter``, ``all_gather``, ``broadcast``).
        algorithm: schedule algorithm; default is the collective's first
            entry in :data:`SIM_ALGORITHMS`.  ``halving_doubling`` requires a
            power-of-two node count.
        routing: reuse an all-sources :class:`RoutingResult` (e.g. from a
            lazy Analysis session); computed here when absent.
        root: broadcast root vertex.
        scheme: routing scheme used to lower each logical round onto links
            (one of :data:`repro.core.traffic.ROUTING_SCHEMES`).  Non-minimal
            schemes let executed collectives ride Valiant/UGAL/ksp paths;
            ``bfs_tree`` schedules are physical trees and ignore it.
        slack: extra hops beyond shortest for ``scheme="ksp"``.
        chunk: sources per jitted ECMP call (memory knob).

    Returns:
        A :class:`Schedule` of unique ``(n, k)`` per-round transfer tensors
        (bytes per unit payload), repetition counts, and per-round hop counts.
        Demand between disconnected pairs is dropped and accounted in
        ``dropped_demand``.
    """
    name, n, table = _unpack_topo(topo)
    if collective not in SIM_ALGORITHMS:
        raise ValueError(f"unknown collective {collective!r} "
                         f"(known: {sorted(SIM_ALGORITHMS)})")
    algorithm = algorithm or SIM_ALGORITHMS[collective][0]
    if algorithm not in SIM_ALGORITHMS[collective]:
        raise ValueError(f"unknown algorithm {algorithm!r} for {collective} "
                         f"(known: {SIM_ALGORITHMS[collective]})")
    if scheme not in ROUTING_SCHEMES:
        raise ValueError(f"unknown routing scheme {scheme!r} "
                         f"(known: {ROUTING_SCHEMES})")
    if routing is None:
        routing = analyze_routing((table, n), chunk=chunk)
    if not routing.exact:
        raise ValueError("schedule compilation needs an all-sources routing "
                         f"result (got {routing.sources.size}/{n} sources)")
    dropped = 0.0
    if algorithm == "bfs_tree":
        rounds, counts, hops = _bfs_tree_rounds(table, routing.dist[root])
        dropped = float((routing.dist[root] < 0).sum())
    else:
        if algorithm == "ring":
            logical = _logical_rounds_ring(
                n, phases=2 if collective == "all_reduce" else 1)
        elif algorithm == "halving_doubling":
            logical = _logical_rounds_halving_doubling(
                n, phases=2 if collective == "all_reduce" else 1)
        elif algorithm == "bruck":
            logical = _logical_rounds_bruck(n)
        else:                                          # binomial broadcast
            logical = _logical_rounds_binomial(n, root)
        rounds, counts, hops, dropped = _lower_demand_rounds(
            table, routing, logical, chunk, scheme=scheme, slack=slack)
    return Schedule(name=name, collective=collective, algorithm=algorithm,
                    n=n, k=int(table.shape[1]), round_bytes=rounds,
                    counts=counts, hops=hops, dropped_demand=dropped)


# --------------------------------------------------------------------------
# the round engine
# --------------------------------------------------------------------------

@jax.jit
def _engine(round_bytes: jnp.ndarray, counts: jnp.ndarray, hops: jnp.ndarray,
            payload: jnp.ndarray, link_bw: jnp.ndarray,
            hop_latency: jnp.ndarray):
    """Advance rounds until the schedule is drained.

    Each unique round u: every directed slot drains ``round_bytes[u] *
    payload`` at ``link_bw``; the round takes ``max_link_bytes / link_bw +
    hops[u] * hop_latency`` seconds (synchronous rounds: the most contended
    link gates everyone) and repeats ``counts[u]`` times.  Returns
    (total seconds, (n, k) per-slot busy seconds).
    """
    obs.count("jit_trace/round_engine")          # trace-time increment
    U = round_bytes.shape[0]

    def cond(state):
        u, _, _ = state
        return u < U

    def body(state):
        u, total, busy = state
        b = round_bytes[u] * payload
        t_round = b.max() / link_bw + hops[u].astype(b.dtype) * hop_latency
        c = counts[u].astype(b.dtype)
        return u + 1, total + c * t_round, busy + c * (b / link_bw)

    _, total, busy = jax.lax.while_loop(
        cond, body,
        (jnp.int32(0), jnp.float32(0.0),
         jnp.zeros(round_bytes.shape[1:], dtype=jnp.float32)))
    return total, busy


#: the payload sweep: one engine call for B payload sizes
_engine_payloads = jax.jit(jax.vmap(_engine,
                                    in_axes=(None, None, None, 0, None, None)))

#: the fault-stack sweep: one engine call for B stacked degraded schedules
_engine_stacked = jax.jit(jax.vmap(_engine,
                                   in_axes=(0, None, 0, None, None, None)))


@dataclasses.dataclass
class RoundTelemetry:
    """Per-round engine telemetry, indexed by **unique** round ``u``.

    Computed host-side from the compiled schedule at one payload size (the
    largest of the sweep), so it costs no extra device work.  Link loads are
    per **unit payload** (the :class:`Schedule` convention): for a one-round
    traffic schedule ``round_max_link_load.max()`` equals the static routing
    layer's ``max_link_load`` on the same demand — the executed counterpart
    of the quantity Theorem 2's spectral bound controls.  Utilizations are
    busy fractions of the round: ``round_util_max`` is the straggler link's
    drain share ``bw_seconds / round_seconds`` and ``round_util_mean``
    averages over loaded slots.  ``hot_node[u], hot_slot[u]`` name the argmax
    contended directed link as gather-table coordinates (the physical link is
    ``hot_node -> table[hot_node, hot_slot]``).
    """
    round_seconds: np.ndarray          # (U,) seconds per execution of round u
    round_bw_seconds: np.ndarray       # (U,) straggler-link drain term
    round_latency_seconds: np.ndarray  # (U,) hops[u] * hop_latency term
    round_max_link_load: np.ndarray    # (U,) peak slot bytes per unit payload
    round_mean_link_load: np.ndarray   # (U,) mean over loaded slots
    round_util_max: np.ndarray         # (U,) straggler busy fraction
    round_util_mean: np.ndarray        # (U,) mean loaded-slot busy fraction
    hot_node: np.ndarray               # (U,) argmax link source node
    hot_slot: np.ndarray               # (U,) argmax link gather-table slot
    counts: np.ndarray                 # (U,) repetitions of each unique round
    hops: np.ndarray                   # (U,) store-and-forward hops
    payload_bytes: float               # payload the seconds are computed at

    @property
    def unique_rounds(self) -> int:
        return int(self.round_seconds.shape[0])

    def argmax_link(self) -> Tuple[int, int]:
        """(node, slot) of the most contended link over ALL rounds."""
        u = int(self.round_max_link_load.argmax())
        return int(self.hot_node[u]), int(self.hot_slot[u])

    def total_seconds(self) -> float:
        """Engine-identity check: ``sum(counts * round_seconds)`` equals the
        measured completion time at ``payload_bytes`` (up to f32 rounding)."""
        return float((self.counts.astype(np.float64)
                      * self.round_seconds).sum())

    def to_dict(self) -> Dict:
        """JSON-ready per-round arrays (lists; U is small by construction)."""
        node, slot = self.argmax_link()
        return dict(
            unique_rounds=self.unique_rounds,
            payload_bytes=float(self.payload_bytes),
            round_seconds=[round(float(t), 9) for t in self.round_seconds],
            round_bw_seconds=[round(float(t), 9)
                              for t in self.round_bw_seconds],
            round_latency_seconds=[round(float(t), 9)
                                   for t in self.round_latency_seconds],
            round_max_link_load=[round(float(x), 9)
                                 for x in self.round_max_link_load],
            round_mean_link_load=[round(float(x), 9)
                                  for x in self.round_mean_link_load],
            round_util_max=[round(float(x), 6) for x in self.round_util_max],
            round_util_mean=[round(float(x), 6)
                             for x in self.round_util_mean],
            hot_link=[node, slot],
            counts=[int(c) for c in self.counts],
            hops=[int(h) for h in self.hops])


def _round_telemetry(schedule: Schedule, payload: float, link_bw: float,
                     hop_latency: float) -> RoundTelemetry:
    """Host-side per-round accounting mirroring the engine's round formula."""
    rb = np.asarray(schedule.round_bytes, dtype=np.float64)
    U = rb.shape[0]
    flat = rb.reshape(U, -1)
    idx = flat.argmax(axis=1)
    max_load = flat[np.arange(U), idx]
    node, slot = np.unravel_index(idx, rb.shape[1:])
    loaded = (flat > 0).sum(axis=1)
    mean_load = np.where(loaded > 0,
                         flat.sum(axis=1) / np.maximum(loaded, 1), 0.0)
    bw_s = max_load * payload / link_bw
    lat_s = schedule.hops.astype(np.float64) * hop_latency
    round_s = bw_s + lat_s
    safe = np.where(round_s > 0, round_s, 1.0)
    util_max = np.where(round_s > 0, bw_s / safe, 0.0)
    util_mean = np.where(round_s > 0,
                         mean_load * payload / link_bw / safe, 0.0)
    return RoundTelemetry(
        round_seconds=round_s, round_bw_seconds=bw_s,
        round_latency_seconds=lat_s, round_max_link_load=max_load,
        round_mean_link_load=mean_load, round_util_max=util_max,
        round_util_mean=util_mean,
        hot_node=node.astype(np.int64), hot_slot=slot.astype(np.int64),
        counts=np.asarray(schedule.counts),
        hops=np.asarray(schedule.hops), payload_bytes=float(payload))


@dataclasses.dataclass
class SimulationResult:
    """Measured execution of one schedule at one or more payload sizes.

    ``time_seconds[i]`` is the completion time at ``payload_bytes[i]``;
    ``link_busy_seconds`` (per directed slot, at the LARGEST payload) divided
    by that payload's completion time gives per-link utilization.  Padding
    slots never carry bytes and stay 0.
    """
    name: str
    collective: str
    algorithm: str
    n: int
    rounds: int
    unique_rounds: int
    payload_bytes: np.ndarray      # (B,) bytes per node
    time_seconds: np.ndarray       # (B,) measured completion seconds
    link_busy_seconds: np.ndarray  # (n, k) busy seconds at the largest payload
    max_link_bytes: float          # peak per-round slot bytes per unit payload
    total_bytes: float             # link bytes moved per unit payload (all rounds)
    utilization_max: float         # busiest slot's busy fraction (largest payload)
    utilization_mean: float        # mean busy fraction over loaded slots
    dropped_demand: float          # unit-payload bytes to unreachable targets
    saturation_throughput: Optional[float]  # traffic workloads only (1/max load)
    seconds: float                 # wall time (compile + engine)
    telemetry: Optional[RoundTelemetry] = None  # run_schedule(telemetry=True)

    def utilization(self, index: int = -1) -> np.ndarray:
        """(n, k) busy fraction of each directed slot at payload ``index``."""
        t = float(self.time_seconds[index])
        if t <= 0:
            return np.zeros_like(self.link_busy_seconds)
        scale = float(self.payload_bytes[index] / self.payload_bytes.max())
        return self.link_busy_seconds * scale / t

    def hot_links(self, table: np.ndarray, top: int = 5
                  ) -> List[Tuple[int, int, float]]:
        """The ``top`` most-utilized directed links as (u, v, busy fraction)."""
        util = self.utilization()
        flat = np.argsort(-util, axis=None)[:top]
        out = []
        for f in flat:
            u, j = np.unravel_index(f, util.shape)
            out.append((int(u), int(table[u, j]), float(util[u, j])))
        return out

    def utilization_histogram(self, bins: int = 10) -> Dict[str, List[float]]:
        """Histogram of per-slot busy fractions over LOADED slots (the
        congestion picture: a tight histogram means balanced links)."""
        util = self.utilization()
        loaded = util[self.link_busy_seconds > 0]
        counts, edges = np.histogram(loaded, bins=bins,
                                     range=(0.0, max(1.0, float(util.max()))))
        return dict(counts=counts.tolist(), edges=np.round(edges, 6).tolist())

    def to_dict(self) -> Dict:
        """JSON-ready summary (drops the (n, k) busy matrix)."""
        return dict(
            name=self.name, collective=self.collective,
            algorithm=self.algorithm, n=self.n, rounds=self.rounds,
            unique_rounds=self.unique_rounds,
            payload_bytes=[float(p) for p in self.payload_bytes],
            time_seconds=[float(t) for t in self.time_seconds],
            max_link_bytes=round(self.max_link_bytes, 9),
            total_bytes=round(self.total_bytes, 6),
            utilization_max=round(self.utilization_max, 6),
            utilization_mean=round(self.utilization_mean, 6),
            dropped_demand=round(self.dropped_demand, 6),
            saturation_throughput=None if self.saturation_throughput is None
                else round(self.saturation_throughput, 6),
            seconds=round(self.seconds, 3),
            telemetry=None if self.telemetry is None
                else self.telemetry.to_dict())

    def report(self) -> str:
        """Compact text block for CLI reports."""
        times = ", ".join(f"{p / 1e6:.1f}MB: {t * 1e3:.3f}ms"
                          for p, t in zip(self.payload_bytes,
                                          self.time_seconds))
        return "\n".join([
            f"simulated       : {self.collective}/{self.algorithm} "
            f"({self.rounds} rounds, {self.unique_rounds} unique)",
            f"measured time   : {times}",
            f"link utilization: max {self.utilization_max:.3f} / "
            f"mean {self.utilization_mean:.3f} busy fraction",
        ])


@obs.traced("simulate/run_schedule", phase="execute")
def run_schedule(schedule: Schedule,
                 payloads: Union[float, Sequence[float]] = float(1 << 26), *,
                 link_bw: float = LINK_BW,
                 hop_latency: float = PER_HOP_LATENCY,
                 saturation_throughput: Optional[float] = None,
                 t0: Optional[float] = None,
                 telemetry: bool = False) -> SimulationResult:
    """Execute a compiled schedule at B payload sizes in one vmapped call.

    Args:
        schedule: output of :func:`compile_schedule`.
        payloads: payload bytes per node — a scalar or a sequence (the engine
            vmaps over all of them at once).
        link_bw: bytes/second each directed link drains.
        hop_latency: seconds charged per hop of a round's longest transfer.
        saturation_throughput: passed through to the result (set by
            :func:`simulate_traffic`).
        t0: wall-clock start to attribute compile time to the result.
        telemetry: attach a :class:`RoundTelemetry` (per-round times, link
            loads, utilizations, argmax contended link) computed at the
            largest payload of the sweep.

    Returns:
        :class:`SimulationResult` with measured times (seconds) and per-link
        utilization accounting.
    """
    t0 = time.time() if t0 is None else t0
    pay = np.atleast_1d(np.asarray(payloads, dtype=np.float32))
    order = np.argsort(pay, kind="stable")
    times, busy = _engine_payloads(
        jnp.asarray(schedule.round_bytes), jnp.asarray(schedule.counts),
        jnp.asarray(schedule.hops), jnp.asarray(pay),
        jnp.float32(link_bw), jnp.float32(hop_latency))
    times = np.asarray(times, dtype=np.float64)
    busy_last = np.asarray(busy, dtype=np.float64)[order[-1]]
    t_last = float(times[order[-1]])
    util = busy_last / t_last if t_last > 0 else np.zeros_like(busy_last)
    loaded = util[busy_last > 0]
    tel = None
    if telemetry:
        tel = _round_telemetry(schedule, float(pay[order[-1]]),
                               link_bw, hop_latency)
    return SimulationResult(
        name=schedule.name, collective=schedule.collective,
        algorithm=schedule.algorithm, n=schedule.n, rounds=schedule.rounds,
        unique_rounds=schedule.unique_rounds,
        payload_bytes=pay.astype(np.float64), time_seconds=times,
        link_busy_seconds=busy_last,
        max_link_bytes=float(schedule.round_bytes.max()),
        total_bytes=float(schedule.total_link_bytes().sum()),
        utilization_max=float(util.max()) if util.size else 0.0,
        utilization_mean=float(loaded.mean()) if loaded.size else 0.0,
        dropped_demand=schedule.dropped_demand,
        saturation_throughput=saturation_throughput,
        seconds=time.time() - t0, telemetry=tel)


# --------------------------------------------------------------------------
# one-call drivers
# --------------------------------------------------------------------------

def simulate_collective(topo: Union[Topology, Tuple[np.ndarray, int]],
                        collective: str = "all_reduce",
                        algorithm: Optional[str] = None, *,
                        payloads: Union[float, Sequence[float]] = float(1 << 26),
                        link_bw: float = LINK_BW,
                        hop_latency: float = PER_HOP_LATENCY,
                        routing: Optional[RoutingResult] = None,
                        root: int = 0,
                        scheme: str = "minimal",
                        slack: int = 1,
                        chunk: int = DEFAULT_SOURCE_CHUNK,
                        telemetry: bool = False) -> SimulationResult:
    """Compile + execute one collective on one topology (see
    :func:`compile_schedule` / :func:`run_schedule` for the arguments).

    Returns a :class:`SimulationResult`; ``time_seconds`` is directly
    comparable to the :class:`~repro.core.collectives.NetworkModel`
    prediction at the same payload (same ``link_bw`` / ``hop_latency``
    constants), which is what ``NetworkModel.validate`` ratios.
    """
    t0 = time.time()
    sched = compile_schedule(topo, collective, algorithm, routing=routing,
                             root=root, scheme=scheme, slack=slack,
                             chunk=chunk)
    return run_schedule(sched, payloads, link_bw=link_bw,
                        hop_latency=hop_latency, t0=t0, telemetry=telemetry)


def simulate_traffic(topo: Union[Topology, Tuple[np.ndarray, int]],
                     pattern: str = "uniform", *,
                     payloads: Union[float, Sequence[float]] = float(1 << 26),
                     link_bw: float = LINK_BW,
                     hop_latency: float = PER_HOP_LATENCY,
                     routing: Optional[RoutingResult] = None,
                     fiedler: Optional[np.ndarray] = None,
                     demands: Optional[np.ndarray] = None,
                     scheme: str = "minimal",
                     slack: int = 1,
                     chunk: int = DEFAULT_SOURCE_CHUNK,
                     telemetry: bool = False) -> SimulationResult:
    """Execute one traffic workload: every node injects ``payload`` bytes
    spread per the demand matrix, in one contention round on the links.

    The measured ``saturation_throughput`` (1 / peak per-unit-payload link
    bytes × per-node demand) is the executed counterpart of
    :attr:`repro.core.traffic.TrafficResult.saturation_throughput` — same
    injection-units convention, so the two figures are directly comparable
    (and the spectral prediction
    :func:`~repro.core.traffic.spectral_throughput_estimate` ratios both).

    Args: as :func:`simulate_collective`, plus ``pattern`` /
    ``fiedler`` / ``demands`` / ``scheme`` / ``slack`` as in
    :func:`repro.core.traffic.evaluate_traffic`.
    """
    t0 = time.time()
    name, n, table = _unpack_topo(topo)
    if routing is None:
        routing = analyze_routing((table, n), chunk=chunk)
    if demands is None:
        D = demand_matrix(pattern, n, fiedler=fiedler)
    else:
        D = np.asarray(demands, dtype=np.float64)
        pattern = "custom"
    rounds, counts, hops, dropped = _lower_demand_rounds(
        table, routing, [(D, 1, 1.0)], chunk, scheme=scheme, slack=slack)
    sched = Schedule(name=name, collective=f"traffic:{pattern}",
                     algorithm="ecmp" if scheme == "minimal" else scheme,
                     n=n, k=int(table.shape[1]),
                     round_bytes=rounds, counts=counts, hops=hops,
                     dropped_demand=dropped)
    max_load = float(rounds.max())
    thpt = 1.0 / max_load if max_load > 0 else float("inf")
    return run_schedule(sched, payloads, link_bw=link_bw,
                        hop_latency=hop_latency,
                        saturation_throughput=thpt, t0=t0,
                        telemetry=telemetry)


# --------------------------------------------------------------------------
# fault stacks: B degraded samples -> one vmapped compile + one engine call
# --------------------------------------------------------------------------

@jax.jit
def _stacked_ring_round(tables: jnp.ndarray, dist0: jnp.ndarray,
                        demands: jnp.ndarray):
    """Per-sample ring-round lowering for a source chunk: BFS + sigma + ECMP
    in one vmapped call over the (B, n, k) stack.  Returns per-sample
    (loads (n, k), max served hops, dropped demand)."""
    obs.count("jit_trace/stacked_ring_round")    # trace-time increment

    def one(tab):
        dist = _bfs_dist_chunk(tab, dist0)
        sigma = _sigma_chunk(tab, dist)
        served = jnp.where(dist >= 0, demands, 0.0)
        loads = _ecmp_loads_chunk(tab, dist, sigma.astype(jnp.float32),
                                  served.astype(jnp.float32))
        hop = jnp.where(served > 0, dist, 0).max()
        dropped = jnp.where(dist < 0, demands, 0.0).sum()
        return loads, hop, dropped

    return jax.vmap(one)(tables)


def stacked_ring_allreduce(tables: np.ndarray,
                           payload: float = float(1 << 26), *,
                           link_bw: float = LINK_BW,
                           hop_latency: float = PER_HOP_LATENCY,
                           chunk: int = DEFAULT_SOURCE_CHUNK) -> Dict:
    """Ring all-reduce times for B stacked padded tables in one engine call.

    This is the fault-subsystem hook: ``tables`` is the (B, n, k) block
    :func:`repro.core.faults.stacked_operands` builds for a batch of degraded
    samples.  Each sample's ring round is compiled with vmapped BFS + ECMP
    (chunked over sources to bound the (B, S, n) intermediates) and all B
    schedules execute in ONE vmapped engine call.  Demand between
    disconnected pairs is dropped (and reported), exactly like the healthy
    compiler.

    Args:
        tables: (B, n, k) int padded neighbor tables.
        payload: all-reduce bytes per node.
        link_bw / hop_latency: engine constants (see :func:`run_schedule`).
        chunk: BFS/ECMP sources per jitted call.

    Returns:
        dict with ``time_seconds`` (B,), ``dropped_frac`` (B,) — fraction of
        the ring demand dropped per sample — plus ``rounds`` and ``payload``.
    """
    tables = np.asarray(tables)
    B, n, k = tables.shape
    tabs = jnp.asarray(tables, dtype=jnp.int32)
    D = _logical_rounds_ring(n, phases=1)[0][0]   # the healthy ring demand
    loads = np.zeros((B, n, k), dtype=np.float64)
    hops = np.zeros(B, dtype=np.int32)
    dropped = np.zeros(B, dtype=np.float64)
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        dist0 = jnp.full((hi - lo, n), -1, dtype=jnp.int32)
        dist0 = dist0.at[jnp.arange(hi - lo), jnp.arange(lo, hi)].set(0)
        ld, hp, dr = _stacked_ring_round(tabs, dist0,
                                         jnp.asarray(D[lo:hi],
                                                     dtype=jnp.float32))
        loads += np.asarray(ld, dtype=np.float64)
        hops = np.maximum(hops, np.asarray(hp, dtype=np.int32))
        dropped += np.asarray(dr, dtype=np.float64)
    counts = np.array([2 * (n - 1)], dtype=np.int32)
    times, _ = _engine_stacked(
        jnp.asarray(loads[:, None], dtype=jnp.float32), jnp.asarray(counts),
        jnp.asarray(hops[:, None]), jnp.float32(payload),
        jnp.float32(link_bw), jnp.float32(hop_latency))
    total = float(D.sum())
    return dict(
        time_seconds=np.asarray(times, dtype=np.float64),
        dropped_frac=dropped / total if total > 0 else dropped,
        rounds=int(counts[0]), payload=float(payload))
