"""Fault models + batched degraded-operation spectral sweeps.

The paper names fault tolerance as one of the three spectrally-controlled
properties of an interconnect (kappa >= rho_2, Fiedler), and §3's discrepancy
bounds are what guarantee bandwidth on a *degraded* machine.  This module asks
the operational question directly: what happens to rho_2, the guaranteed
bisection, and connectivity when links or routers die?

Four fault models produce :class:`FaultScenario` records (which links/nodes
fail), ``apply_faults`` materializes the degraded :class:`Topology`, and
:func:`fault_sweep` drives the whole pipeline: for each fault rate it draws B
Monte-Carlo samples, stacks their padded gather operands, and solves all B
degraded graphs in ONE vmapped Laplacian Lanczos call
(:func:`repro.core.spectral.rho2_laplacian_batched` — the same padded-table
operand contract as the ``cayley_spmv`` kernel).  Degraded graphs are
irregular, so the sweep runs on L = D - A rather than the regular-only
adjacency batch.

Models
------
* ``link``            — iid random link failure (Monte-Carlo, seeded)
* ``node``            — iid random router failure; survivors are relabelled
* ``attack_degree``   — adversarial: kill the highest-degree routers first
* ``attack_spectral`` — adversarial: cut the links carrying the Fiedler
  Rayleigh quotient (largest (f_u - f_v)^2), the spectrally most damaging set
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

from . import bounds as B
from . import spectral as S
from .graphs import Topology

__all__ = [
    "FaultScenario", "FaultSweepResult", "FAULT_MODELS",
    "random_link_faults", "random_node_faults",
    "adversarial_degree_attack", "adversarial_spectral_attack",
    "apply_faults", "stacked_operands", "connected_component_count",
    "fault_sweep",
]

FAULT_MODELS = ("link", "node", "attack_degree", "attack_spectral")

#: adversarial models are deterministic — one sample tells the whole story
DETERMINISTIC_MODELS = ("attack_degree", "attack_spectral")


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """One concrete fault pattern: which links/nodes of a topology fail."""
    kind: str                   # one of FAULT_MODELS
    rate: float                 # requested fault fraction
    seed: int                   # RNG seed (0 for deterministic attacks)
    failed_links: np.ndarray    # (t,) int64 row indices into topo.edges
    failed_nodes: np.ndarray    # (f,) int64 vertex ids (empty for link models)

    @property
    def n_failed_links(self) -> int:
        return int(self.failed_links.size)

    @property
    def n_failed_nodes(self) -> int:
        return int(self.failed_nodes.size)


def _check_rate(rate: float) -> None:
    if not 0.0 <= rate < 1.0:
        raise ValueError(f"fault rate must be in [0, 1), got {rate}")


def random_link_faults(topo: Topology, rate: float, seed: int = 0
                       ) -> FaultScenario:
    """iid link failure: a uniform random ``round(rate * m)``-subset of edges."""
    _check_rate(rate)
    t = int(round(rate * topo.m))
    rng = np.random.default_rng(seed)
    failed = rng.choice(topo.m, size=t, replace=False) if t else \
        np.empty(0, dtype=np.int64)
    return FaultScenario(kind="link", rate=rate, seed=seed,
                         failed_links=np.sort(failed.astype(np.int64)),
                         failed_nodes=np.empty(0, dtype=np.int64))


def _incident_links(topo: Topology, nodes: np.ndarray) -> np.ndarray:
    dead = np.zeros(topo.n, dtype=bool)
    dead[nodes] = True
    hit = dead[topo.edges[:, 0]] | dead[topo.edges[:, 1]]
    return np.nonzero(hit)[0].astype(np.int64)


def random_node_faults(topo: Topology, rate: float, seed: int = 0
                       ) -> FaultScenario:
    """iid router failure: ``round(rate * n)`` random vertices (and every
    incident link) die; the degraded graph is the induced survivor subgraph."""
    _check_rate(rate)
    f = int(round(rate * topo.n))
    rng = np.random.default_rng(seed)
    nodes = rng.choice(topo.n, size=f, replace=False) if f else \
        np.empty(0, dtype=np.int64)
    nodes = np.sort(nodes.astype(np.int64))
    return FaultScenario(kind="node", rate=rate, seed=seed,
                         failed_links=_incident_links(topo, nodes),
                         failed_nodes=nodes)


def adversarial_degree_attack(topo: Topology, rate: float) -> FaultScenario:
    """Targeted router attack: the ``round(rate * n)`` highest-degree vertices
    (ties broken by vertex id) — the classic hub-removal adversary."""
    _check_rate(rate)
    f = int(round(rate * topo.n))
    deg = topo.degrees(include_loops=False)
    # stable sort on (-degree, id): highest degree first, lowest id on ties
    order = np.argsort(-deg, kind="stable")
    nodes = np.sort(order[:f].astype(np.int64))
    return FaultScenario(kind="attack_degree", rate=rate, seed=0,
                         failed_links=_incident_links(topo, nodes),
                         failed_nodes=nodes)


def adversarial_spectral_attack(topo: Topology, rate: float,
                                fiedler: Optional[np.ndarray] = None
                                ) -> FaultScenario:
    """Spectrally-targeted link attack: cut the ``round(rate * m)`` edges with
    the largest Fiedler energy (f_u - f_v)^2.  Those edges carry the Rayleigh
    quotient of rho_2, so removing them is the greedy gap-minimizing cut."""
    _check_rate(rate)
    t = int(round(rate * topo.m))
    if fiedler is None:
        fiedler = S.fiedler_vector(topo) if topo.n <= S.DENSE_THRESHOLD \
            else S.fiedler_lanczos(topo)
    f = np.asarray(fiedler, dtype=np.float64)
    energy = (f[topo.edges[:, 0]] - f[topo.edges[:, 1]]) ** 2
    order = np.argsort(-energy, kind="stable")
    return FaultScenario(kind="attack_spectral", rate=rate, seed=0,
                         failed_links=np.sort(order[:t].astype(np.int64)),
                         failed_nodes=np.empty(0, dtype=np.int64))


def make_scenario(topo: Topology, model: str, rate: float, seed: int = 0,
                  fiedler: Optional[np.ndarray] = None) -> FaultScenario:
    if model == "link":
        return random_link_faults(topo, rate, seed)
    if model == "node":
        return random_node_faults(topo, rate, seed)
    if model == "attack_degree":
        return adversarial_degree_attack(topo, rate)
    if model == "attack_spectral":
        return adversarial_spectral_attack(topo, rate, fiedler)
    raise ValueError(f"unknown fault model {model!r} (known: {FAULT_MODELS})")


def apply_faults(topo: Topology, sc: FaultScenario) -> Topology:
    """Materialize the degraded topology: failed links dropped, failed nodes
    removed with survivors relabelled 0..n_s-1 (``meta['survivors']`` keeps the
    original ids).  Healthy-only meta (vertex transitivity, spec/closed forms)
    is stripped — a degraded graph earns none of those certificates."""
    keep = np.ones(topo.m, dtype=bool)
    keep[sc.failed_links] = False
    edges = topo.edges[keep]
    loops = topo.loops
    n = topo.n
    meta = {k: v for k, v in topo.meta.items()
            if k not in ("vertex_transitive", "spec")}
    meta["fault"] = dict(kind=sc.kind, rate=sc.rate, seed=sc.seed,
                         failed_links=sc.n_failed_links,
                         failed_nodes=sc.n_failed_nodes)
    if sc.failed_nodes.size:
        alive = np.ones(topo.n, dtype=bool)
        alive[sc.failed_nodes] = False
        relabel = np.cumsum(alive) - 1
        edges = relabel[edges]
        loops = loops[alive] if loops is not None else None
        n = int(alive.sum())
        meta["survivors"] = np.nonzero(alive)[0]
    name = f"{topo.name}%{sc.kind}@{sc.rate:g}" + \
        (f"#{sc.seed}" if sc.kind not in DETERMINISTIC_MODELS else "")
    return Topology(name, n, edges, loops=loops, meta=meta)


# --------------------------------------------------------------------------
# stacked operands: B degraded graphs -> one (B, n, k) batched solve
# --------------------------------------------------------------------------

def _padded_operands(n: int, edges: np.ndarray, loops: Optional[np.ndarray],
                     width: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized :meth:`Topology.gather_operands` with an imposed table width
    (so samples of different max degree still stack).  Returns
    (table (n, width) int32, w (n,) float64, deg (n,) float64 incl. loops)."""
    src = np.concatenate([edges[:, 0], edges[:, 1]])
    dst = np.concatenate([edges[:, 1], edges[:, 0]])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    deg = np.bincount(src, minlength=n)
    if deg.size and deg.max() > width:
        raise ValueError(f"table width {width} < max degree {deg.max()}")
    starts = np.concatenate([[0], np.cumsum(deg)])
    slot = np.arange(src.size) - starts[src]
    table = np.repeat(np.arange(n, dtype=np.int32)[:, None], width, axis=1)
    table[src, slot] = dst.astype(np.int32)
    lo = loops if loops is not None else np.zeros(n)
    w = lo - (width - deg).astype(np.float64)
    # deg carries the SIGNED loop weight so deg*x - (gather + w*x) = L x
    # exactly (loops cancel in the combinatorial Laplacian)
    return table, w, deg.astype(np.float64) + lo


def stacked_operands(topos: Sequence[Topology], width: Optional[int] = None
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack B same-order graphs into (tables (B,n,k), weights (B,n),
    degs (B,n)) — the operand block of one batched Laplacian solve."""
    ns = {t.n for t in topos}
    if len(ns) != 1:
        raise ValueError(f"stacked graphs must share n, got {sorted(ns)}")
    n = ns.pop()
    if width is None:
        width = max(int(np.bincount(t.edges.reshape(-1), minlength=n).max())
                    for t in topos)
        width = max(width, 1)
    tabs, ws, degs = zip(*(_padded_operands(t.n, t.edges, t.loops, width)
                           for t in topos))
    return np.stack(tabs), np.stack(ws), np.stack(degs)


def connected_component_count(n: int, edges: np.ndarray) -> int:
    """Exact component count via vectorized min-label propagation with
    pointer jumping — O((m + n) log n), no Python per-edge loop."""
    labels = np.arange(n, dtype=np.int64)
    if edges.size == 0:
        return n
    u, v = edges[:, 0], edges[:, 1]
    while True:
        nxt = labels.copy()
        np.minimum.at(nxt, u, labels[v])
        np.minimum.at(nxt, v, labels[u])
        nxt = nxt[nxt]                       # pointer jumping
        if np.array_equal(nxt, labels):
            break
        labels = nxt
    return int(np.unique(labels).size)


# --------------------------------------------------------------------------
# the sweep driver
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FaultSweepResult:
    """Survival curves of one topology under one fault model."""
    name: str
    model: str
    n: int
    m: int
    samples: int
    seed: int
    rho2_healthy: float
    rows: List[Dict]            # one dict per fault rate (see fault_sweep)
    batched_solves: int         # number of vmapped Lanczos calls issued
    seconds: float

    def curve(self, field: str) -> List:
        """[(rate, value), ...] — e.g. curve('rho2_mean')."""
        return [(r["rate"], r[field]) for r in self.rows]

    def to_dict(self) -> Dict:
        return dict(name=self.name, model=self.model, n=self.n, m=self.m,
                    samples=self.samples, seed=self.seed,
                    rho2_healthy=self.rho2_healthy, rows=self.rows,
                    batched_solves=self.batched_solves,
                    seconds=round(self.seconds, 3))

    def report(self) -> str:
        """Compact text block for CLI reports."""
        lines = [f"fault model     : {self.model} "
                 f"({self.samples} sample{'s' if self.samples > 1 else ''}/rate, "
                 f"{self.batched_solves} batched solve"
                 f"{'s' if self.batched_solves > 1 else ''})",
                 f"healthy rho2    : {self.rho2_healthy:.5f}"]
        for r in self.rows:
            kept = "n/a kept" if r["rho2_retention"] is None \
                else f"{r['rho2_retention']:.0%} kept"
            lines.append(
                f"  rate {r['rate']:>5.1%} : rho2 {r['rho2_mean']:.4f} "
                f"({kept}), "
                f"P(connected) {r['connectivity_prob']:.2f}, "
                f"bisection LB {r['bw_fiedler_lb_mean']:.1f}")
        return "\n".join(lines)


@obs.traced("faults/sweep", phase="execute")
def fault_sweep(topo: Topology, rates: Sequence[float] = (0.02, 0.05, 0.1, 0.2),
                model: str = "link", samples: int = 32, seed: int = 0,
                iters: int = 160, rho2_healthy: Optional[float] = None,
                fiedler: Optional[np.ndarray] = None,
                routing: bool = False,
                routing_sources: Optional[int] = None,
                simulate: bool = False,
                sim_payload: float = float(1 << 26),
                workload=None,
                workload_samples: int = 2) -> FaultSweepResult:
    """Survival curves under fault injection, batched per rate.

    For each rate, ``samples`` Monte-Carlo scenarios (or one, for the
    deterministic adversarial models) are materialized, their padded gather
    operands stacked, and all degraded rho_2 values solved in a single
    vmapped Laplacian Lanczos call.  Connectivity is counted exactly on the
    host (rho_2 of a disconnected sample is ~0 and its zero crossing is the
    connectivity signal, but the component count is cheap and unambiguous).

    Per-rate row fields: rate, samples, failed_links_mean, failed_nodes,
    rho2_mean/min/max, rho2_retention (mean / healthy), connectivity_prob,
    bw_fiedler_lb_mean (Theorem 2 at each sample), diameter_ub (Theorem 1 at
    the worst connected sample; None if every sample disconnected), and the
    analytic caps interlacing_rho2_ub (link models only) / weyl_rho2_lb.

    ``routing=True`` feeds each rate's already-stacked padded tables through
    :func:`repro.core.routing.routing_stats_stacked` — one vmapped BFS for all
    B samples — appending *measured* degraded path structure per row:
    ``bfs_diameter_mean/max`` (hops; over fully-reachable samples only, None
    when every sample disconnected — a shattered sample's max-over-reachable
    figure would shrink, not grow; exact per sample when all sources run,
    else a lower bound), ``bfs_avg_hops_mean`` (over reachable pairs),
    ``reachable_frac_mean``.
    ``routing_sources`` caps the BFS sources per sample (default: all vertices
    up to n=512, then 64 sampled sources — the knob trades exactness for time
    on large instances).

    ``simulate=True`` *executes* a ring all-reduce of ``sim_payload`` bytes
    per node on each rate's stacked degraded tables
    (:func:`repro.core.simulate.stacked_ring_allreduce` — one vmapped
    schedule compile + engine call for all B samples), appending measured
    degraded collective times per row: ``sim_allreduce_mean/max`` (seconds;
    demand between disconnected pairs is dropped) and
    ``sim_dropped_frac_mean`` (fraction of the ring demand dropped — the
    disconnection signal).  Memory is O(B n^2 / chunks) for the per-sample
    BFS matrices, so prefer modest ``samples`` above n ~ 1024.

    ``workload=`` (a spec string, :class:`~repro.core.workloads.WorkloadSpec`
    or prebuilt :class:`~repro.core.workloads.CommPlan`) *executes* the full
    per-step training communication plan on the first ``workload_samples``
    degraded samples of each rate (:func:`repro.core.workloads.
    simulate_workload`; each sample needs its own all-sources BFS, hence the
    small default), appending measured degraded step times per row:
    ``workload_step_mean/max`` (seconds), ``workload_dropped_frac_mean``
    (fraction of the plan's demand between disconnected node pairs).
    """
    if model not in FAULT_MODELS:
        raise ValueError(f"unknown fault model {model!r} (known: {FAULT_MODELS})")
    t0 = time.time()
    if rho2_healthy is None:
        rho2_healthy = S.algebraic_connectivity(topo)
    if model == "attack_spectral" and fiedler is None:
        fiedler = S.fiedler_vector(topo) if topo.n <= S.DENSE_THRESHOLD \
            else S.fiedler_lanczos(topo)
    B_samples = 1 if model in DETERMINISTIC_MODELS else samples
    plan = None
    if workload is not None:
        from .workloads import CommPlan, plan_workload
        plan = workload if isinstance(workload, CommPlan) else \
            plan_workload(workload)
    # impose the healthy table width so link-model rates batch identically
    # (one XLA compilation for the whole sweep; node models still retrace per
    # rate because the surviving n differs)
    healthy_width = max(int(np.bincount(topo.edges.reshape(-1),
                                        minlength=topo.n).max()), 1)
    rows: List[Dict] = []
    solves = 0
    for rate in rates:
        scen = [make_scenario(topo, model, rate, seed=seed + 7919 * i,
                              fiedler=fiedler) for i in range(B_samples)]
        degraded = [apply_faults(topo, sc) for sc in scen]
        tabs, ws, degs = stacked_operands(degraded, width=healthy_width)
        rho2s = S.rho2_laplacian_batched(tabs, ws, degs, iters=iters, seed=seed)
        solves += 1
        obs.count("faults/batched_solves")
        comps = np.array([connected_component_count(d.n, d.edges)
                          for d in degraded])
        connected = comps == 1
        n_s = degraded[0].n
        kmax = max(float(d.degrees().max()) for d in degraded)
        row = dict(
            rate=float(rate),
            samples=B_samples,
            nodes_surviving=n_s,
            failed_links_mean=float(np.mean([s.n_failed_links for s in scen])),
            failed_nodes=int(scen[0].n_failed_nodes),
            rho2_mean=float(np.mean(rho2s)),
            rho2_min=float(np.min(rho2s)),
            rho2_max=float(np.max(rho2s)),
            rho2_retention=float(np.mean(rho2s) / rho2_healthy)
                if rho2_healthy > 0 else None,
            connectivity_prob=float(np.mean(connected)),
            bw_fiedler_lb_mean=float(np.mean(
                [B.fiedler_bw_lb(n_s, r) for r in rho2s])),
            weyl_rho2_lb=B.weyl_degraded_rho2_lb(
                rho2_healthy, int(round(np.mean(
                    [s.n_failed_links for s in scen])))),
        )
        # link removal can only lower rho2 (Loewner monotonicity); node
        # removal changes the vertex set and carries no such cap
        row["interlacing_rho2_ub"] = B.interlacing_degraded_rho2_ub(
            rho2_healthy) if not scen[0].n_failed_nodes else None
        conn_rho2 = rho2s[connected]
        row["diameter_ub"] = float(B.alon_milman_diameter_ub(
            n_s, kmax, float(conn_rho2.min()))) \
            if conn_rho2.size and conn_rho2.min() > 1e-9 else None
        if routing:
            from .routing import routing_stats_stacked, sample_sources
            if routing_sources is None:
                srcs = None if n_s <= 512 else sample_sources(n_s, 64, seed)
            else:
                srcs = None if routing_sources >= n_s else \
                    sample_sources(n_s, routing_sources, seed)
            stats = routing_stats_stacked(tabs, sources=srcs)
            # diameter stats only over samples whose sampled pairs all
            # connect — a shattered sample's max-over-reachable "diameter"
            # shrinks as components do, which would read as paths improving
            # under faults (same restriction diameter_ub applies via
            # conn_rho2); reachable_frac_mean carries the disconnection signal
            conn_stats = [s for s in stats if s["reachable_frac"] == 1.0]
            row["bfs_diameter_mean"] = float(np.mean(
                [s["diameter"] for s in conn_stats])) if conn_stats else None
            row["bfs_diameter_max"] = int(max(
                s["diameter"] for s in conn_stats)) if conn_stats else None
            row["bfs_avg_hops_mean"] = float(
                np.mean([s["avg_path_length"] for s in stats]))
            row["reachable_frac_mean"] = float(
                np.mean([s["reachable_frac"] for s in stats]))
        if simulate:
            from .simulate import stacked_ring_allreduce
            sim = stacked_ring_allreduce(tabs, payload=sim_payload)
            row["sim_allreduce_mean"] = float(sim["time_seconds"].mean())
            row["sim_allreduce_max"] = float(sim["time_seconds"].max())
            row["sim_dropped_frac_mean"] = float(sim["dropped_frac"].mean())
        if plan is not None:
            from .workloads import simulate_workload
            wl = [simulate_workload(d, plan)
                  for d in degraded[:max(1, workload_samples)]]
            row["workload_step_mean"] = float(
                np.mean([w.step_seconds for w in wl]))
            row["workload_step_max"] = float(
                np.max([w.step_seconds for w in wl]))
            row["workload_dropped_frac_mean"] = float(
                np.mean([w.dropped_frac for w in wl]))
        rows.append(row)
    return FaultSweepResult(
        name=topo.name, model=model, n=topo.n, m=topo.m, samples=B_samples,
        seed=seed, rho2_healthy=float(rho2_healthy), rows=rows,
        batched_solves=solves, seconds=time.time() - t0)
