"""repro.core — the paper's contribution: interconnect topologies, their
spectra, the Reduction Lemma, Ramanujan (LPS) constructions, and the
topology-aware collective cost model."""
from . import bounds, collectives, faults, graphs, lifts, placement, \
    properties, ramanujan, reduction, spectral, topologies
from .graphs import Topology

__all__ = ["Topology", "bounds", "collectives", "faults", "graphs", "lifts",
           "placement", "properties", "ramanujan", "reduction", "spectral",
           "topologies"]
