"""repro.core — the paper's contribution: interconnect topologies, their
spectra, the Reduction Lemma, Ramanujan (LPS) constructions, path-level
routing/traffic evaluation, and the topology-aware collective cost model."""
from . import bounds, collectives, faults, graphs, lifts, placement, \
    properties, ramanujan, reduction, routing, spectral, topologies, traffic
from .graphs import Topology

__all__ = ["Topology", "bounds", "collectives", "faults", "graphs", "lifts",
           "placement", "properties", "ramanujan", "reduction", "routing",
           "spectral", "topologies", "traffic"]
