"""Analytic spectral bounds: Theorems 1-3, §3 expansion bounds, and the full
Table 1 of per-topology rho_2 / bisection-bandwidth bounds.

Everything here is a closed-form function of topology parameters — the
numerical validation (tests/benchmarks) checks the *constructed* graphs against
these expressions.
"""
from __future__ import annotations

import math
from typing import Callable, Dict

import numpy as np

__all__ = [
    "alon_milman_diameter_ub", "mohar_diameter_lb", "fiedler_bw_lb",
    "cheeger_bw_ub", "first_moment_bw_ub", "fiedler_vertex_connectivity_lb",
    "tanner_isoperimetric_lb", "alon_milman_gap_lb", "discrepancy_edge_bound",
    "active_subset_bw_lb", "ramanujan_rho2", "ramanujan_bw_lb",
    "interlacing_degraded_rho2_ub", "weyl_degraded_rho2_lb",
    "expected_degraded_rho2", "TABLE1",
]


# --------------------------------------------------------------------------
# §2.1 general eigenvalue bounds
# --------------------------------------------------------------------------

def alon_milman_diameter_ub(n: int, max_deg: float, rho2: float) -> float:
    """Theorem 1: diam(G) <= 2 ceil( sqrt(2*Delta/rho2) * log2(n) )."""
    return 2 * math.ceil(math.sqrt(2.0 * max_deg / rho2) * math.log2(n))


def mohar_diameter_lb(n: int, rho2: float) -> float:
    """McKay/Mohar: diam(G) >= 4 / (n * rho2)."""
    return 4.0 / (n * rho2)


def fiedler_bw_lb(n: int, rho2: float) -> float:
    """Theorem 2 (Fiedler): BW(G) >= rho2 * n / 4."""
    return rho2 * n / 4.0


def cheeger_bw_ub(n: int, k: float, rho2: float) -> float:
    """Theorem 3: BW(G) <= sqrt(2 k rho2) * k * n / 2 (loose for large rho2)."""
    return math.sqrt(2.0 * k * rho2) * k * n / 2.0


def first_moment_bw_ub(m: int) -> float:
    """BW(G) <= m/2 for any graph with m edges (first-moment method)."""
    return m / 2.0


def fiedler_vertex_connectivity_lb(rho2: float) -> float:
    """kappa(G) >= rho2 — the fault-tolerance guarantee."""
    return rho2


def tanner_isoperimetric_lb(k: float, lambda2: float) -> float:
    """Tanner: h(G) >= 1 - k / (2k - 2*lambda2)."""
    return 1.0 - k / (2.0 * k - 2.0 * lambda2)


def alon_milman_gap_lb(h: float) -> float:
    """Alon–Milman: k - lambda2 >= h^2 / (4 + 2 h^2)."""
    return h * h / (4.0 + 2.0 * h * h)


# --------------------------------------------------------------------------
# §3 Ramanujan reference values + discrepancy
# --------------------------------------------------------------------------

def ramanujan_rho2(k: float) -> float:
    """rho2 of a Ramanujan graph is >= k - 2 sqrt(k-1)."""
    return k - 2.0 * math.sqrt(k - 1.0)


def ramanujan_bw_lb(n: int, k: float) -> float:
    """Fiedler lower bound at the Ramanujan rho2: (k - 2 sqrt(k-1)) n / 4."""
    return ramanujan_rho2(k) * n / 4.0


def discrepancy_edge_bound(n: int, k: float, sx: int, sy: int) -> float:
    """|e(X,Y) - k|X||Y|/n| <= (2 sqrt(k-1)/n) sqrt(|X|(n-|X|)|Y|(n-|Y|))."""
    return (2.0 * math.sqrt(k - 1.0) / n) * math.sqrt(sx * (n - sx) * sy * (n - sy))


def active_subset_bw_lb(alpha: float, n: int, k: float) -> float:
    """Guaranteed bisection bandwidth on ANY alpha*n active nodes of a
    Ramanujan topology (§3):  (alpha k n / 2) (alpha/2 - (2 sqrt(k-1)/k)(1 - alpha/2)).
    """
    return (alpha * k * n / 2.0) * (alpha / 2.0 - (2.0 * math.sqrt(k - 1.0) / k) * (1.0 - alpha / 2.0))


# --------------------------------------------------------------------------
# degraded operation: analytic bounds on rho_2 after link faults
# --------------------------------------------------------------------------

def interlacing_degraded_rho2_ub(rho2_healthy: float) -> float:
    """Removing links never raises rho_2: L(G - F) ⪯ L(G) in the Loewner
    order (each removed edge subtracts a PSD rank-1 term), so by eigenvalue
    monotonicity every sampled degraded gap sits at or below the healthy one.
    Valid for link faults; node faults change the vertex set and can RAISE
    rho_2 (e.g. pruning a pendant path), so no such cap applies there."""
    return rho2_healthy


def weyl_degraded_rho2_lb(rho2_healthy: float, links_removed: int) -> float:
    """Weyl: each removed edge's Laplacian has spectral norm 2, so
    rho_2(G - F) >= rho_2(G) - 2 |F| (clipped at 0).  Loose but certified."""
    return max(0.0, rho2_healthy - 2.0 * links_removed)


def expected_degraded_rho2(rho2_healthy: float, fault_rate: float) -> float:
    """E[L_degraded] = (1 - r) L under iid link failure at rate r, so the
    first-order expected gap is (1 - r) rho_2 — the scaling the collective
    cost model's ``degrade`` view uses for its guaranteed-bisection figure."""
    return rho2_healthy * (1.0 - fault_rate)


# --------------------------------------------------------------------------
# Table 1: per-topology closed forms.  Each entry maps parameters to
# dict(nodes, radix, rho2_ub, bw_ub) exactly as printed in the paper.
#
# NOTE: the registry (repro.api.registry) is now the canonical home of these
# expressions — each Family record carries its closed_forms callable, wired up
# at registration time in core/topologies.py.  TABLE1 remains as the shared
# implementation + a backwards-compatible name-keyed view.
# --------------------------------------------------------------------------

def _butterfly(k: int, s: int) -> Dict:
    n = s * k ** s
    return dict(nodes=n, radix=2 * k,
                # Proposition 1: rho2 <= 2k - 2k cos(2 pi / s)
                rho2_ub=2 * k - 2 * k * math.cos(2 * math.pi / s),
                bw_ub=(k + 1) * k ** s / 2.0)


def _ccc(d: int) -> Dict:
    # Proposition 3 is an *order* bound ("at most on the order of"); the
    # paper's closed-form Rayleigh evaluation has a small algebra slip (its
    # printed lower bound on lambda_1(A') exceeds the true lambda_1 by ~4e-4
    # at d=4; we verified Lemma 2 itself holds EXACTLY — see
    # tests/test_topologies.py::test_ccc_lemma2_exact).  We encode the
    # asymptotic statement with its measured constant envelope (ratio <= 1.15
    # for d >= 3, decreasing to 1).
    return dict(nodes=d * 2 ** d, radix=3,
                rho2_ub=1.15 * 2.0 * (1 - math.cos(math.pi / (d + 2))),
                bw_ub=2.0 ** (d - 1))


def _clex(k: int, ell: int) -> Dict:
    return dict(nodes=k ** ell, radix=2 * ell * k - k - 1,
                # Proposition 5: gap <= t + 3k + 1 with t = k-1 -> 4k - 2... the
                # paper's table prints 4k - 2 (t + 3k + 1 at t = k - 1 = 4k).
                # We use the table value.
                rho2_ub=4.0 * k - 2.0,
                bw_ub=float(k) ** (ell + 1))


def _data_vortex(A: int, C: int) -> Dict:
    return dict(nodes=A * C * 2 ** (C - 1), radix=4,
                # Proposition 2
                rho2_ub=min(2 - 2 * math.cos(math.pi / C),
                            2 - 2 * math.cos(2 * math.pi / A)),
                bw_ub=A * 2.0 ** (C - 2))


def _dragonfly(h_nodes: int, h_edges: int, h_bw: float) -> Dict:
    r = 2.0 * h_edges / h_nodes
    return dict(nodes=h_nodes * h_nodes + h_nodes, radix=r + 1,
                # Corollary 2
                rho2_ub=1.0 + h_nodes / (2.0 * h_edges),
                bw_ub=((h_nodes + 1) / 2.0) ** 2 + h_bw)


def _hypercube(d: int) -> Dict:
    return dict(nodes=2 ** d, radix=d, rho2_ub=2.0, bw_ub=2.0 ** (d - 1),
                diameter=d)


def _petersen_torus(a: int, b: int) -> Dict:
    return dict(nodes=10 * a * b, radix=4,
                # Corollary 1
                rho2_ub=(4 - 3 * math.cos(4 * math.pi / a) - math.cos(2 * math.pi / a)) / 5.0,
                bw_ub=6.0 * b + a * b + 5.0)


def _slimfly(q: int) -> Dict:
    return dict(nodes=2 * q * q, radix=(3 * q - 1) / 2.0,
                rho2_ub=float(q),                 # Proposition 9 (exact)
                bw_ub=(q ** 3 + q) / 2.0,         # Proposition 10
                diameter=2)                       # MMS graphs have diameter 2


def _torus(k: int, d: int) -> Dict:
    return dict(nodes=k ** d, radix=2 * d,
                rho2_ub=2.0 * (1 - math.cos(2 * math.pi / k)),
                bw_ub=2.0 * k ** (d - 1),
                diameter=d * (k // 2))


class _Table1(Dict[str, Callable[..., Dict]]):
    """Table-1 record lookup that names removed/renamed keys in its KeyError
    (a plain dict would just echo the missing key)."""

    #: removed key -> its replacement (kept so the error can say *why*)
    _REMOVED = {"peterson_torus": "petersen_torus"}

    def __missing__(self, key):
        if key in self._REMOVED:
            raise KeyError(
                f"TABLE1 key {key!r} was removed after its deprecation "
                f"cycle; use {self._REMOVED[key]!r}")
        raise KeyError(f"unknown TABLE1 topology {key!r} "
                       f"(known: {', '.join(sorted(self))})")


TABLE1: Dict[str, Callable[..., Dict]] = _Table1({
    "butterfly": _butterfly,
    "ccc": _ccc,
    "clex": _clex,
    "data_vortex": _data_vortex,
    "dragonfly": _dragonfly,
    "hypercube": _hypercube,
    "petersen_torus": _petersen_torus,
    "slimfly": _slimfly,
    "torus": _torus,
})
