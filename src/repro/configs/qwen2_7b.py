"""Qwen2-7B [arXiv:2407.10671; hf] — GQA kv=4, QKV bias."""
from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="qwen2-7b", family="dense",
    d_model=3584, n_layers=28, pattern=(LayerSpec("attn"),),
    n_heads=28, n_kv_heads=4, head_dim=128, qkv_bias=True,
    rope_theta=1_000_000.0,
    d_ff=18944, mlp_act="silu", vocab_size=152064,
))
