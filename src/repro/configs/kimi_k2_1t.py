"""Kimi K2 1T-A32B [arXiv:2501.kimi2] — 384-expert top-8 trillion-param MoE."""
from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    d_model=7168, n_layers=61, pattern=(LayerSpec("attn", moe=True),),
    n_heads=64, n_kv_heads=8, head_dim=112,
    vocab_size=163840,
    n_experts=384, experts_per_token=8, moe_d_ff=2048,
    capacity_factor=1.25,
    opt_state_dtype="bfloat16",   # 1T params: bf16 m/v (int8-Adam class tradeoff)
))
