"""H2O-Danube-3-4B [arXiv:2401.16818] — llama+mistral mix with SWA."""
from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    d_model=3840, n_layers=24, pattern=(LayerSpec("attn", window=4096),),
    n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, mlp_act="silu", vocab_size=32000,
))
