"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf] — M-RoPE, vision frontend stubbed."""
from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    d_model=3584, n_layers=28, pattern=(LayerSpec("attn"),),
    n_heads=28, n_kv_heads=4, head_dim=128, qkv_bias=True,
    rope_theta=1_000_000.0, mrope_sections=(16, 24, 24),
    d_ff=18944, mlp_act="silu", vocab_size=152064,
    frontend="vision_stub",
))
