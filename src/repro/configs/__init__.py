from .base import (SHAPES, ArchConfig, LayerSpec, ShapeSpec, cells_for,
                   get_config, list_configs, register)

__all__ = ["SHAPES", "ArchConfig", "LayerSpec", "ShapeSpec", "cells_for",
           "get_config", "list_configs", "register"]
