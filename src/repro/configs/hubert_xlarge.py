"""HuBERT-XLarge [arXiv:2106.07447] — encoder-only audio (w2v2 arch), frontend stubbed."""
from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge", family="audio",
    d_model=1280, n_layers=48, pattern=(LayerSpec("attn"),),
    n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, mlp_act="gelu", vocab_size=504,
    causal=False, frontend="audio_stub",
))
