"""~100M-param LM for the end-to-end CPU training example (not an assigned arch)."""
from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="lm100m", family="dense",
    d_model=640, n_layers=10, pattern=(LayerSpec("attn"),),
    n_heads=10, n_kv_heads=5, head_dim=64,
    d_ff=2560, mlp_act="silu", vocab_size=50257,
    param_dtype="float32", compute_dtype="float32",
))
