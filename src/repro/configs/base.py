"""Architecture config system: every assigned arch is a frozen ArchConfig.

A model is a repeated ``pattern`` of LayerSpecs (scan-over-repeats keeps the
HLO compact at 48-64 layers); heterogeneous schedules (jamba 1:7, gemma3 5:1)
are expressed as longer patterns.

Contract consumed by the workload-lowering pass (``repro.core.workloads``):
``get_config``/``list_configs`` resolve registry names; ``SHAPES`` supplies
``(seq_len, global_batch, kind)`` per training shape; ``param_count()`` /
``active_param_count()`` are exact analytic counts (units: parameters, not
bytes — multiply by the ``param_dtype`` width for bytes); the MoE fields
(``n_experts``, ``experts_per_token``, ``moe_d_ff``, ``capacity_factor``,
``moe_dispatch_dtype``) size the expert-parallel all-to-all; ``pattern`` x
``n_repeats`` determines per-layer collective op counts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

__all__ = ["LayerSpec", "ArchConfig", "register", "get_config", "list_configs",
           "SHAPES", "ShapeSpec", "cells_for"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"             # "attn" | "mamba"
    moe: bool = False
    window: Optional[int] = None   # sliding-window size; None = full attention


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    n_layers: int
    pattern: Tuple[LayerSpec, ...]
    vocab_size: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, ...]] = None   # M-RoPE (qwen2-vl)
    # dense mlp
    d_ff: int = 0
    mlp_act: str = "silu"          # silu -> SwiGLU | gelu -> GeGLU
    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # EP all-to-all payload dtype; "float8_e4m3fn" halves dispatch traffic
    # (per-slot-scaled, DeepSeek-V3 style). "bfloat16" = paper-faithful baseline.
    moe_dispatch_dtype: str = "bfloat16"
    # ssm (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    # dtype of the in-chunk associative scan elements; bf16 halves the
    # dominant HBM term of SSM training (decay factors are <= 1 so the
    # product chain is benign; the carry h stays f32 across chunks)
    ssm_scan_dtype: str = "float32"
    # "assoc": parallel associative scan in-chunk (~log(c) full passes);
    # "seq": sequential in-chunk scan emitting y directly (~2-3 passes of
    # HBM traffic; the time recurrence serializes on the VPU — the Pallas
    # mamba_scan kernel gives the best of both on real TPU)
    ssm_impl: str = "assoc"
    # embedding / head / misc
    tie_embeddings: bool = False
    causal: bool = True            # False = encoder-only (hubert)
    frontend: str = "none"         # none | vision_stub | audio_stub
    norm_eps: float = 1e-6
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    # training-step internals
    loss_chunk: int = 512          # sequence-chunked xent
    attn_chunk: int = 512          # flash-style block size (pure-JAX path)
    mamba_chunk: int = 256
    remat: bool = True

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.name}: pattern {len(self.pattern)} !| layers {self.n_layers}"

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    @property
    def has_attention(self) -> bool:
        return any(s.kind == "attn" for s in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer needs an unbounded full-attention KV cache at
        decode... i.e. all attention layers are windowed or the model is
        SSM/hybrid-with-few-global (gemma3/jamba run long_500k; see DESIGN.md)."""
        full_attn = [s for s in self.pattern if s.kind == "attn" and s.window is None]
        return len(full_attn) == 0 or (len(full_attn) / len(self.pattern)) <= 0.2

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head).

        Returns the exact number of scalar parameters (dimensionless count;
        multiply by the ``param_dtype`` byte width for memory / gradient
        traffic).  Matches the materialized ``model.param_shapes`` tree leaf
        by leaf — the workload DP all-reduce sizing depends on this identity.
        """
        D, V = self.d_model, self.vocab_size
        total = V * D                      # embedding
        if not self.tie_embeddings:
            total += D * V                 # head
        total += D                         # final norm
        for s in self.pattern:
            n = self.n_repeats
            if s.kind == "attn":
                qkv = D * self.n_heads * self.head_dim + 2 * D * self.n_kv_heads * self.head_dim
                o = self.n_heads * self.head_dim * D
                if self.qkv_bias:
                    qkv += (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
                total += n * (qkv + o + D)             # + norm
            else:  # mamba
                Di, N, R = self.d_inner, self.ssm_state, self.dt_rank
                total += n * (D * 2 * Di + Di * self.ssm_conv + Di * (R + 2 * N)
                              + R * Di + Di * N + Di + Di * D + D)
            if s.moe:
                total += n * (D * self.n_experts
                              + self.n_experts * 3 * D * self.moe_d_ff + D)
            elif s.kind == "attn" and self.d_ff:
                total += n * (3 * D * self.d_ff + D)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only).

        Returns ``param_count()`` with the expert MLPs rescaled from
        ``n_experts`` to ``experts_per_token`` — the count that enters the
        ``6 * active_params * tokens`` training-FLOP estimate used by
        ``repro.core.workloads`` and the roofline model.
        """
        if self.n_experts == 0:
            return self.param_count()
        dense = self.param_count()
        moe_layers = sum(1 for s in self.pattern if s.moe) * self.n_repeats
        all_experts = moe_layers * self.n_experts * 3 * self.d_model * self.moe_d_ff
        active = moe_layers * self.experts_per_token * 3 * self.d_model * self.moe_d_ff
        return dense - all_experts + active


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    """Add ``cfg`` to the registry under ``cfg.name``; returns it unchanged."""
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    """The registered ``ArchConfig`` for an exact registry ``name``.

    Raises ``KeyError`` for unknown names; see ``list_configs()`` for the
    valid set (workload specs additionally accept unique prefixes, resolved
    in ``repro.core.workloads`` before calling this).
    """
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs():
    """Sorted list of every registered architecture name (loads on demand)."""
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import (falcon_mamba_7b, gemma3_12b, gemma_2b, grok1_314b,  # noqa: F401
                   h2o_danube3_4b, hubert_xlarge, jamba_v01_52b, kimi_k2_1t,
                   lm100m, qwen2_7b, qwen2_vl_7b)


# --------------------------------------------------------------------------
# assigned input shapes (LM family: seq_len x global_batch)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape: ``global_batch`` sequences of ``seq_len``
    tokens each; ``kind`` gates which passes run it (workload lowering
    accepts only ``kind == "train"``)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ArchConfig, repeats: int = 2) -> ArchConfig:
    """Smoke-test shrink of the same family: tiny widths/experts/vocab, scaled
    windows, one-or-two pattern repeats.  Structure (pattern, GQA ratio,
    activation, frontend, biases, M-RoPE) is preserved."""
    kv = 1 if cfg.n_kv_heads == 1 else 2
    heads = 4 if cfg.n_heads else 0
    head_dim = 16
    pattern = tuple(dataclasses.replace(s, window=(8 if s.window else None))
                    for s in cfg.pattern)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        d_model=64, n_layers=len(cfg.pattern) * repeats, pattern=pattern,
        n_heads=heads, n_kv_heads=kv if heads else 0,
        head_dim=head_dim if heads else 0,
        mrope_sections=(2, 3, 3) if cfg.mrope_sections else None,
        d_ff=128 if cfg.d_ff else 0,
        n_experts=4 if cfg.n_experts else 0,
        experts_per_token=2 if cfg.n_experts else 0,
        moe_d_ff=32 if cfg.n_experts else 0,
        # capacity >= group size so forward/prefill/decode route identically
        # (capacity drops are group-size dependent by design; tests need exact
        # teacher-forcing equivalence)
        capacity_factor=4.0,
        ssm_state=8 if cfg.ssm_state else 0,
        vocab_size=211,
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=16, attn_chunk=8, mamba_chunk=8,
    )


def cells_for(cfg: ArchConfig):
    """The (arch x shape) cells this arch runs (skip rules per DESIGN.md)."""
    out = []
    for s in SHAPES.values():
        if not cfg.causal and s.kind == "decode":
            continue                       # encoder-only: no decode step
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue                       # pure full-attention: skip 500k
        out.append(s)
    return out
