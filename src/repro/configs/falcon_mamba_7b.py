"""Falcon-Mamba-7B [arXiv:2410.05355] — pure Mamba-1, attention-free."""
from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    d_model=4096, n_layers=64, pattern=(LayerSpec("mamba"),),
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
))
