"""Grok-1 314B [hf:xai-org/grok-1] — MoE 8e top-2, GQA kv=8."""
from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="grok-1-314b", family="moe",
    d_model=6144, n_layers=64, pattern=(LayerSpec("attn", moe=True),),
    n_heads=48, n_kv_heads=8, head_dim=128,
    vocab_size=131072,
    n_experts=8, experts_per_token=2, moe_d_ff=32768,
    opt_state_dtype="bfloat16",   # 314B: quantized optimizer states at 512 chips
))
