"""Jamba-v0.1 52B [arXiv:2403.19887; hf] — Mamba+attn 1:7 interleave, MoE 16e top-2.

Pattern = one Jamba period of 8 layers (attn at offset 4), MoE on every other
layer (odd offsets), repeated 4x for 32 layers.
"""
from .base import ArchConfig, LayerSpec, register

_period = tuple(
    LayerSpec(kind=("attn" if i == 4 else "mamba"), moe=(i % 2 == 1))
    for i in range(8)
)

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    d_model=4096, n_layers=32, pattern=_period,
    n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, mlp_act="silu", vocab_size=65536,
    n_experts=16, experts_per_token=2, moe_d_ff=14336,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
))
