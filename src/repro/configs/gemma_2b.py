"""Gemma-2B [arXiv:2403.08295; hf] — GeGLU, head_dim=256, MQA (kv=1)."""
from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="gemma-2b", family="dense",
    d_model=2048, n_layers=18, pattern=(LayerSpec("attn"),),
    n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, mlp_act="gelu", vocab_size=256000,
    tie_embeddings=True,
))
