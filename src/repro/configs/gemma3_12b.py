"""Gemma-3-12B class [hf:google/gemma-3] — 5:1 local:global attention, GeGLU."""
from .base import ArchConfig, LayerSpec, register

_period = tuple(LayerSpec("attn", window=1024) for _ in range(5)) + (LayerSpec("attn"),)

CONFIG = register(ArchConfig(
    name="gemma3-12b", family="dense",
    d_model=3840, n_layers=48, pattern=_period,
    n_heads=16, n_kv_heads=8, head_dim=256,
    rope_theta=1_000_000.0,
    d_ff=15360, mlp_act="gelu", vocab_size=262144,
    tie_embeddings=True,
))
