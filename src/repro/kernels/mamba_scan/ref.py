"""Pure-jnp oracle: the sequential selective scan (repro.models.mamba)."""
from repro.models.mamba import selective_scan_ref


def mamba_scan_ref(x, delta, A, B_t, C_t, D):
    return selective_scan_ref(x, delta, A, B_t, C_t, D)
