"""Chunked selective-scan (Mamba-1) TPU kernel.

Grid (batch, d_inner blocks, time chunks), time innermost so the SSM state
h (block_d, N) persists in VMEM scratch across chunks — the kernel never
materializes the (B, L, d_inner, N) tensor that makes the naive lowering
memory-bound (this is the core insight of the original Mamba kernel, re-blocked
for VMEM/VPU instead of SRAM/warps; DESIGN.md §3).

Within a chunk the recurrence h_t = a_t*h + b_t runs as a fori_loop over time
steps (VPU elementwise; N=16 lanes).  y_t = C_t . h_t + D*x_t is written per
chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, o_ref, h_scr, *,
                 chunk: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)             # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)           # (chunk, bd)
    A = A_ref[...].astype(jnp.float32)           # (bd, N)
    Bt = B_ref[0].astype(jnp.float32)            # (chunk, N)
    Ct = C_ref[0].astype(jnp.float32)            # (chunk, N)
    Dw = D_ref[...].astype(jnp.float32)          # (bd,)

    def step(t, carry):
        h, ys = carry
        a = jnp.exp(dt[t][:, None] * A)                       # (bd, N)
        b = (dt[t] * x[t])[:, None] * Bt[t][None, :]          # (bd, N)
        h = a * h + b
        y = jnp.sum(h * Ct[t][None, :], axis=1) + Dw * x[t]   # (bd,)
        ys = jax.lax.dynamic_update_slice(ys, y[None], (t, 0))
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h_f, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_scr[...] = h_f
    o_ref[0] = ys.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan(x, delta, A, B_t, C_t, D, *, chunk: int = 64,
               block_d: int = 512, interpret: bool = True):
    """x/delta: (B, L, Di); A: (Di, N); B_t/C_t: (B, L, N); D: (Di,).
    Returns y: (B, L, Di).  L must pad to a chunk multiple (handled here)."""
    Bb, L, Di = x.shape
    N = A.shape[1]
    chunk = min(chunk, L)
    nt = -(-L // chunk)
    Lp = nt * chunk
    block_d = min(block_d, Di)
    nd = -(-Di // block_d)
    if Lp != L:
        pad = ((0, 0), (0, Lp - L), (0, 0))
        x, delta = jnp.pad(x, pad), jnp.pad(delta, pad)
        B_t, C_t = jnp.pad(B_t, pad), jnp.pad(C_t, pad)
    grid = (Bb, nd, nt)
    out = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((block_d, N), lambda b, d, t: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((block_d,), lambda b, d, t: (d,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, d, t: (b, t, d)),
        out_shape=jax.ShapeDtypeStruct((Bb, Lp, Di), x.dtype),
        scratch_shapes=[_vmem((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, delta, A, B_t, C_t, D)
    return out[:, :L]


def _vmem(shape, dtype):
    import jax.experimental.pallas.tpu as pltpu
    return pltpu.VMEM(shape, dtype)
