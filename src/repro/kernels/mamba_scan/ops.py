"""jit'd wrapper selecting kernel vs oracle."""
import functools

import jax

from .kernel import mamba_scan
from .ref import mamba_scan_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "chunk", "interpret"))
def selective_scan(x, delta, A, B_t, C_t, D, use_kernel: bool = True,
                   chunk: int = 64, interpret: bool = True):
    if use_kernel:
        return mamba_scan(x, delta, A, B_t, C_t, D, chunk=chunk,
                          interpret=interpret)
    return mamba_scan_ref(x, delta, A, B_t, C_t, D)
