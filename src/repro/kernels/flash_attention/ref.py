"""Pure-jnp oracle for the flash attention kernel."""
import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, D); k, v: (B, H, Sk, D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
