"""Flash attention TPU kernel (pl.pallas_call + explicit BlockSpec VMEM tiling).

Canonical TPU formulation: grid (B, H, num_q_blocks, num_k_blocks) executed
minor-to-major, so the k-block axis is innermost and the online-softmax state
(m, l, acc) persists in VMEM scratch across k blocks of one q block.  Causal
masking prunes fully-masked k blocks with @pl.when (no MXU work issued).

Block shapes are MXU-aligned (multiples of 128 on the q/k dims; head_dim is
the lane dim).  q/k/v stream HBM->VMEM one block at a time: VMEM footprint =
(bq + 2*bk) * D + bq * D accumulator.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               causal: bool, scale: float, block_q: int, block_k: int,
               num_k_blocks: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block pruning: k block strictly in the future contributes nothing
    run = (not causal) or True
    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when((not causal) or (k_start <= q_start + block_q - 1))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_k
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, D); k, v: (B, H, Sk, D) (GQA expansion handled in ops.py)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    Sq_p, Sk_p = nq * block_q, nk * block_k
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
    grid = (B, H, nq, nk)
    kern = functools.partial(
        _fa_kernel, causal=causal, scale=1.0 / math.sqrt(D),
        block_q=block_q, block_k=block_k, num_k_blocks=nk, seq_k=Sk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, D), q.dtype),
        scratch_shapes=[
            _vmem((block_q,), jnp.float32),       # m: running max
            _vmem((block_q,), jnp.float32),       # l: running denom
            _vmem((block_q, D), jnp.float32),     # acc: running numerator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]


def _vmem(shape, dtype):
    import jax.experimental.pallas.tpu as pltpu
    return pltpu.VMEM(shape, dtype)
