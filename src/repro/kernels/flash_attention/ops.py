"""jit'd public wrapper: GQA layout adaptation + kernel/ref dispatch.

The model's layout is (B, S, H, hd) with Kv <= H kv heads; the kernel works on
(B, H, S, hd) with matched heads.  On CPU (this container) the kernel runs in
interpret mode; on TPU set interpret=False.
"""
import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "use_kernel", "interpret"))
def gqa_flash_attention(q, k, v, *, causal: bool = True,
                        use_kernel: bool = True, interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, S, Kv, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qt = q.transpose(0, 2, 1, 3)                       # (B, H, S, hd)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
    fn = flash_attention if use_kernel else (
        lambda a, b, c, causal, interpret=None: attention_ref(a, b, c, causal=causal))
    if use_kernel:
        o = flash_attention(qt, kt, vt, causal=causal, interpret=interpret)
    else:
        o = attention_ref(qt, kt, vt, causal=causal)
    return o.transpose(0, 2, 1, 3)
