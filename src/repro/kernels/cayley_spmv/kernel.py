"""Blocked Cayley-graph adjacency matvec — the paper's eigensolver hot spot.

The adjacency operator of a k-regular (multi)graph in neighbor-table form is
``y[i] = sum_j x[table[i, j]] (+ loop_w[i] * x[i])``.  For Cayley graphs
(LPS X^{p,q}) each table column is a permutation, so the operator is k
permutation-gathers + accumulate — a *memory-bound* kernel: no MXU, all
HBM->VMEM streaming + VPU adds.

TPU adaptation (DESIGN.md §3): the source vector x lives fully in VMEM
(n <= ~4M f32; LPS p=101 -> n=515k = 2 MB), the (n, k) table streams in
row blocks; each instance performs k in-VMEM gathers for its row block.
The gather lowers to Mosaic's dynamic-gather on v4+; on this CPU container
the kernel is validated with interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(x_ref, tab_ref, loops_ref, o_ref):
    x = x_ref[...]                               # (n,) full vector in VMEM
    idx = tab_ref[...]                           # (block_rows, k)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    k = idx.shape[1]
    for j in range(k):                           # k unrolled permutation gathers
        acc = acc + jnp.take(x, idx[:, j], axis=0).astype(jnp.float32)
    i0 = pl.program_id(0) * o_ref.shape[0]
    rows = i0 + jax.lax.broadcasted_iota(jnp.int32, o_ref.shape, 0)
    acc = acc + loops_ref[...].astype(jnp.float32) * jnp.take(x, rows, axis=0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cayley_spmv(x: jnp.ndarray, table: jnp.ndarray,
                loops: jnp.ndarray | None = None,
                block_rows: int = 1024, interpret: bool = True) -> jnp.ndarray:
    """x: (n,); table: (n, k) int32; loops: optional (n,) self-loop weights."""
    n, k = table.shape
    if loops is None:
        loops = jnp.zeros((n,), x.dtype)
    block_rows = min(block_rows, n)
    nb = -(-n // block_rows)
    pad = nb * block_rows - n
    tab = table
    lps = loops
    if pad:
        tab = jnp.pad(table, ((0, pad), (0, 0)))        # pads gather index 0
        lps = jnp.pad(loops, (0, pad))
    out = pl.pallas_call(
        _spmv_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),                  # x: whole vector
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),     # table rows
            pl.BlockSpec((block_rows,), lambda i: (i,)),         # loop weights
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows,), x.dtype),
        interpret=interpret,
    )(x, tab.astype(jnp.int32), lps)
    return out[:n]
