"""jit'd wrapper + Lanczos matvec factory backed by the kernel."""
import functools

import jax
import jax.numpy as jnp

from .kernel import cayley_spmv
from .ref import spmv_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def adjacency_matvec(x, table, loops=None, use_kernel: bool = True,
                     interpret: bool = True):
    if use_kernel:
        return cayley_spmv(x, table, loops, interpret=interpret)
    return spmv_ref(x, table, loops)


def kernel_matvec(table, loops=None, interpret: bool = True):
    """Drop-in replacement for repro.core.spectral.table_matvec."""
    tab = jnp.asarray(table, dtype=jnp.int32)
    lw = None if loops is None else jnp.asarray(loops, dtype=jnp.float32)

    def mv(x):
        return cayley_spmv(x, tab, lw, interpret=interpret)

    return mv
