"""Pure-jnp oracle: the table matvec used by repro.core.spectral."""
import jax.numpy as jnp


def spmv_ref(x, table, loops=None):
    y = jnp.sum(x[table], axis=1)
    if loops is not None:
        y = y + loops * x
    return y
