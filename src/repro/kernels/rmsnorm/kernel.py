"""Fused RMSNorm TPU kernel: one pass, row-blocked, f32 accumulation in VMEM.

Grid over row blocks; each instance loads a (block_rows, D) tile + the (D,)
weight, computes rsqrt(mean(x^2)+eps) on the VPU and writes the normalized
tile.  Fusing the square/mean/scale avoids the 3 HBM round-trips of the
unfused lowering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = True) -> jnp.ndarray:
    """x: (..., D); w: (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    block_rows = min(block_rows, rows)
    nb = -(-rows // block_rows)
    pad = nb * block_rows - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows, D), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
