"""Pure-jnp oracle for the fused RMSNorm kernel (same as models.layers.rms_norm)."""
import jax
import jax.numpy as jnp


def rmsnorm_ref(x, w, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dt)
