"""jit'd wrapper selecting kernel vs oracle."""
import functools

import jax

from .kernel import rmsnorm
from .ref import rmsnorm_ref


@functools.partial(jax.jit, static_argnames=("eps", "use_kernel", "interpret"))
def fused_rmsnorm(x, w, eps: float = 1e-6, use_kernel: bool = True,
                  interpret: bool = True):
    if use_kernel:
        return rmsnorm(x, w, eps=eps, interpret=interpret)
    return rmsnorm_ref(x, w, eps)
