"""Universal padded gather-table spmv: one matvec, every engine, two backends.

Every hot path in the repo applies the same operator family through the
padded gather-table contract (``graphs.Topology.gather_operands``):

    (A x)[i] = sum_j  signs[i, j] * x[table[i, j]]  +  loops[i] * x[i]

with ``signs`` defaulting to all-ones (plain adjacency; the signed form is
the Bilu–Linial operator of the synthesis subsystem) and ``loops`` to zero.
This module is the single dispatch point for that operator:

* :func:`spmv_ref`    — pure-jnp reference (gather + sum), any backend;
* :func:`spmv_padded` — the Pallas kernel, generalized from
  ``kernels/cayley_spmv``: x fully in VMEM, (n, k) table (and optional
  per-slot signs) streamed in row blocks, k unrolled gathers per block;
* :func:`spmv`        — backend dispatcher.  The *kernel* is the default
  wherever Pallas can compile (TPU/GPU); on CPU — where Mosaic refuses
  compiled mode — the dispatcher falls back to :func:`spmv_ref`, and
  interpret-mode Pallas stays available for parity tests.

Backend resolution order: explicit ``backend=`` argument >
:func:`use_backend` context override > ``REPRO_SPMV_BACKEND`` env var >
auto (``"pallas"`` off-CPU, ``"ref"`` on CPU).  The engines thread the
resolved backend through their jitted solvers as a static argument, so a
:func:`use_backend` override retraces them (the context manager clears the
jit caches on entry and exit for exactly this reason).

Dispatch is observable through :mod:`repro.obs` counters (the call-counting
tests read these instead of monkey-patching): ``spmv/pallas_trace`` counts
Pallas-kernel *traces* (clear the jit caches first; a cache hit never
re-traces), ``spmv/dispatch/<backend>`` counts dispatcher decisions, and
``spmv/matvec/<backend>`` counts matvec closures per resolved backend.
"""
from __future__ import annotations

import contextlib
import functools
import os
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import obs

__all__ = [
    "BACKENDS", "spmv", "spmv_ref", "spmv_padded", "spmv_matvec",
    "default_backend", "resolve_backend", "use_backend", "pallas_supported",
    "kernel_backend",
]

#: "ref" = pure jnp gather+sum; "pallas" = compiled kernel (TPU/GPU);
#: "pallas_interpret" = the kernel under the Pallas interpreter (any backend,
#: slow — parity tests and CPU smoke only).
BACKENDS = ("ref", "pallas", "pallas_interpret")

_OVERRIDE: Optional[str] = None


def pallas_supported() -> bool:
    """True where Mosaic can *compile* the kernel (CPU only interprets)."""
    return jax.default_backend() != "cpu"


def kernel_backend() -> str:
    """The strongest kernel-exercising backend available here: compiled
    Pallas off-CPU, interpret mode on CPU (slow but faithful)."""
    return "pallas" if pallas_supported() else "pallas_interpret"


def _validate(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"unknown spmv backend {backend!r} "
                         f"(known: {BACKENDS})")
    return backend


def default_backend() -> str:
    """Ambient default: env ``REPRO_SPMV_BACKEND`` if set, else the kernel
    where it compiles (TPU/GPU) and the reference path on CPU."""
    env = os.environ.get("REPRO_SPMV_BACKEND")
    if env:
        return _validate(env)
    return "pallas" if pallas_supported() else "ref"


def resolve_backend(backend: Optional[str] = None) -> str:
    """Explicit argument > :func:`use_backend` override > ambient default."""
    if backend is not None:
        return _validate(backend)
    if _OVERRIDE is not None:
        return _OVERRIDE
    return default_backend()


@contextlib.contextmanager
def use_backend(backend: str):
    """Force every default-resolved spmv onto ``backend`` inside the block.

    Clears the jit caches on entry AND exit: the engines bake the resolved
    backend into their traces as a static argument, so cached traces from
    another backend must not be replayed under this one.
    """
    global _OVERRIDE
    _validate(backend)
    prev = _OVERRIDE
    _OVERRIDE = backend
    jax.clear_caches()
    try:
        yield
    finally:
        _OVERRIDE = prev
        jax.clear_caches()


# --------------------------------------------------------------------------
# reference path
# --------------------------------------------------------------------------

def spmv_ref(x: jnp.ndarray, table: jnp.ndarray,
             loops: Optional[jnp.ndarray] = None,
             signs: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Pure-jnp reference: ``sum_j signs[i,j] * x[table[i,j]] + loops[i]*x[i]``."""
    g = x[table]
    if signs is not None:
        g = g * signs
    y = jnp.sum(g, axis=1)
    if loops is not None:
        y = y + loops * x
    return y


# --------------------------------------------------------------------------
# Pallas kernel (generalized cayley_spmv: optional per-slot signs, f32/f64
# accumulation chosen by the input dtype, bf16 in/out supported)
# --------------------------------------------------------------------------

def _acc_dtype(dtype) -> jnp.dtype:
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def _plain_kernel(x_ref, tab_ref, loops_ref, o_ref):
    x = x_ref[...]                               # (n,) full vector in VMEM
    idx = tab_ref[...]                           # (block_rows, k)
    acc_dt = _acc_dtype(x.dtype)
    acc = jnp.zeros(o_ref.shape, acc_dt)
    for j in range(idx.shape[1]):                # k unrolled gathers
        acc = acc + jnp.take(x, idx[:, j], axis=0).astype(acc_dt)
    i0 = pl.program_id(0) * o_ref.shape[0]
    rows = i0 + jax.lax.broadcasted_iota(jnp.int32, o_ref.shape, 0)
    acc = acc + loops_ref[...].astype(acc_dt) * jnp.take(x, rows, axis=0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _signed_kernel(x_ref, tab_ref, sg_ref, loops_ref, o_ref):
    x = x_ref[...]
    idx = tab_ref[...]
    sg = sg_ref[...]                             # (block_rows, k) per-slot signs
    acc_dt = _acc_dtype(x.dtype)
    acc = jnp.zeros(o_ref.shape, acc_dt)
    for j in range(idx.shape[1]):
        acc = acc + sg[:, j].astype(acc_dt) * \
            jnp.take(x, idx[:, j], axis=0).astype(acc_dt)
    i0 = pl.program_id(0) * o_ref.shape[0]
    rows = i0 + jax.lax.broadcasted_iota(jnp.int32, o_ref.shape, 0)
    acc = acc + loops_ref[...].astype(acc_dt) * jnp.take(x, rows, axis=0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def spmv_padded(x: jnp.ndarray, table: jnp.ndarray,
                loops: Optional[jnp.ndarray] = None,
                signs: Optional[jnp.ndarray] = None, *,
                block_rows: int = 1024,
                interpret: bool = True) -> jnp.ndarray:
    """The Pallas padded gather-table spmv.

    ``x``: (n,); ``table``: (n, k) int32 self-padded neighbor table;
    ``loops``: optional (n,) self-loop weights (padding compensation);
    ``signs``: optional (n, k) per-slot ±1 signs (signed adjacency).
    Ragged ``n % block_rows`` is handled by padding the streamed operands
    (padded rows gather into live x entries but are sliced off the output).
    """
    obs.count("spmv/pallas_trace")               # trace-time: counts kernel traces
    n, k = table.shape
    if loops is None:
        loops = jnp.zeros((n,), x.dtype)
    block_rows = min(block_rows, n)
    nb = -(-n // block_rows)
    pad = nb * block_rows - n
    tab, lps, sg = table, loops, signs
    if pad:
        tab = jnp.pad(table, ((0, pad), (0, 0)))        # pads gather index 0
        lps = jnp.pad(loops, (0, pad))
        if sg is not None:
            sg = jnp.pad(signs, ((0, pad), (0, 0)))
    row_spec = pl.BlockSpec((block_rows, k), lambda i: (i, 0))
    in_specs = [pl.BlockSpec((n,), lambda i: (0,)), row_spec]
    ops = [x, tab.astype(jnp.int32)]
    kernel = _plain_kernel
    if sg is not None:
        kernel = _signed_kernel
        in_specs.append(row_spec)
        ops.append(sg)
    in_specs.append(pl.BlockSpec((block_rows,), lambda i: (i,)))
    ops.append(lps)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows,), x.dtype),
        interpret=interpret,
    )(*ops)
    return out[:n]


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------

def spmv(x: jnp.ndarray, table: jnp.ndarray,
         loops: Optional[jnp.ndarray] = None,
         signs: Optional[jnp.ndarray] = None, *,
         backend: Optional[str] = None,
         block_rows: int = 1024) -> jnp.ndarray:
    """Apply the padded gather-table operator through the resolved backend."""
    b = resolve_backend(backend)
    obs.count("spmv/dispatch/" + b)
    if b == "ref":
        return spmv_ref(x, table, loops, signs)
    return spmv_padded(x, table, loops, signs, block_rows=block_rows,
                       interpret=(b == "pallas_interpret"))


def spmv_matvec(table, loops=None, *, backend: Optional[str] = None
                ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Adjacency-operator closure over one (n, k) table — the drop-in matvec
    for :func:`repro.core.spectral.lanczos_tridiag` and friends.  The backend
    is resolved once, at closure creation."""
    b = resolve_backend(backend)
    obs.count("spmv/matvec/" + b)
    tab = jnp.asarray(table, dtype=jnp.int32)
    lw = None if loops is None else jnp.asarray(loops, dtype=jnp.float32)

    def mv(x: jnp.ndarray) -> jnp.ndarray:
        return spmv(x, tab, lw, backend=b)

    return mv
