"""Deterministic synthetic LM data pipeline, shardable per host.

Batches are a pure function of (step, config) — no host synchronization, no
state: every host can materialize exactly its shard (fault-tolerant restart
reproduces the identical stream).  Token streams are Zipf-ish so the loss
curve is non-trivial (structure to learn: next token depends on previous).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "synthetic_batch", "host_shard_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0


def synthetic_batch(cfg: DataConfig, step: int,
                    frontend: str = "none", d_model: int = 0) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic stream: t_{i+1} = (a * t_i + noise) mod V."""
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    a = 31
    t0 = rng.integers(0, V, size=(B, 1))
    noise = rng.integers(0, 17, size=(B, S + 1))
    toks = np.zeros((B, S + 1), dtype=np.int64)
    toks[:, 0] = t0[:, 0]
    for i in range(S):
        toks[:, i + 1] = (a * toks[:, i] + noise[:, i]) % V
    batch: Dict[str, np.ndarray] = dict(
        tokens=toks[:, :S].astype(np.int32),
        labels=toks[:, 1:].astype(np.int32))
    if frontend != "none":
        emb = rng.standard_normal(size=(B, S, d_model)).astype(np.float32)
        batch = dict(embeds=emb, labels=batch["labels"])
    return batch


def host_shard_batch(batch: Dict[str, np.ndarray], host_id: int,
                     n_hosts: int) -> Dict[str, np.ndarray]:
    """Slice a global batch to this host's rows (data-parallel input feeding)."""
    def shard(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return {k: shard(v) for k, v in batch.items()}
