"""Token-choice top-k MoE with sort-based capacity dispatch (EP-shardable).

Design (see DESIGN.md): routing is *local by construction* — tokens are
grouped (group = one sequence / one data shard), each group routes its own
tokens with per-group capacity C = ceil(S*k/E * cf).  The dispatched tensor
(G, E, C, D) is sharded G->data, E->model, so GSPMD lowers the group->expert
exchange to the EP all-to-all.  Dispatch/combine are *gathers/scatters*
(O(tokens * k * D) memory traffic), NOT the dense one-hot einsum (which would
cost O(tokens * E * C * D) FLOPs — untenable at E=384).

``moe_ref`` is the capacity-unbounded dense oracle used by tests.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..parallel.act import BATCH, TP, constrain

__all__ = ["moe_params_shapes", "moe_forward", "moe_ref", "capacity"]


def capacity(tokens_per_group: int, n_experts: int, k: int, cf: float) -> int:
    """Per-expert slot count C for one routing group.

    ``ceil(tokens * k / n_experts * cf)``, floored at 1 — the padded slot
    tensor is ``(n_experts, C, d_model)`` regardless of actual routing, which
    is why capacity (not routed-token counts) sizes the EP all-to-all in both
    ``parallel.ep_moe`` and the ``repro.core.workloads`` plan.
    """
    return max(1, math.ceil(tokens_per_group * k / n_experts * cf))


def moe_params_shapes(cfg) -> Dict[str, tuple]:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    return dict(router=(D, E), wg=(E, D, F), wu=(E, D, F), wd=(E, F, D),
                norm=(D,))


def _route_group(x: jnp.ndarray, router_logits: jnp.ndarray, k: int, C: int,
                 E: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One group's routing.  x: (S, D); router_logits: (S, E).

    Returns (dispatch_idx (E, C) into the S*k assignment list with sentinel
    S*k, gate (S, k), token_of_assignment (S*k,), valid mask (E, C)).
    """
    S = x.shape[0]
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    _, expert_idx = jax.lax.top_k(probs, k)                       # (S, k)
    # gate values via gather (not top_k's value output): the gather VJP keeps
    # the router gradient group-local, while top_k's VJP lowers to a scatter
    # that GSPMD replicates across groups
    gate = jnp.take_along_axis(probs, expert_idx, axis=-1)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)  # renormalize
    flat_expert = expert_idx.reshape(-1)                          # (S*k,)
    # stable sort by expert id (ties keep token order)
    order = jnp.argsort(flat_expert * (S * k) + jnp.arange(S * k))
    sorted_expert = flat_expert[order]
    counts = jnp.zeros(E, dtype=jnp.int32).at[flat_expert].add(1)
    starts = jnp.cumsum(counts) - counts                          # exclusive
    rank = jnp.arange(S * k) - starts[sorted_expert]              # within-expert slot
    ok = rank < C
    slot = jnp.where(ok, sorted_expert * C + rank, E * C)         # overflow -> dropped
    dispatch = jnp.full(E * C + 1, S * k, dtype=jnp.int32)        # sentinel
    dispatch = dispatch.at[slot].set(order)[: E * C].reshape(E, C)
    valid = dispatch < S * k
    return dispatch, gate, flat_expert, valid


def moe_forward(params: Dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (G, S, D) grouped tokens -> (y, aux_loss).

    The vmapped routing is per-group; the expert matmul runs over the
    dispatched (G, E, C, D) tensor.
    """
    G, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = capacity(S, E, k, cfg.capacity_factor)
    logits = x @ params["router"].astype(x.dtype)                 # (G, S, E)

    dispatch, gate, flat_expert, valid = jax.vmap(
        lambda xs, ls: _route_group(xs, ls, k, C, E))(x, logits)

    # gather tokens into expert slots: token of assignment a is a // k
    xpad = jnp.concatenate([x, jnp.zeros((G, 1, D), x.dtype)], axis=1)  # sentinel row
    token_idx = jnp.where(valid, dispatch // k, S)                # (G, E, C)
    xe = jnp.take_along_axis(xpad, token_idx.reshape(G, E * C)[..., None],
                             axis=1).reshape(G, E, C, D)
    # EP boundary: groups live on the batch axis, experts on the model axis —
    # GSPMD lowers this resharding to the all-to-all.  Optional fp8 payload
    # (per-slot max scale, DeepSeek-V3 style) halves the dispatch traffic.
    fp8 = getattr(cfg, "moe_dispatch_dtype", "bfloat16").startswith("float8")
    if fp8:
        scale = jnp.max(jnp.abs(xe.astype(jnp.float32)), axis=-1,
                        keepdims=True) / 448.0 + 1e-12        # e4m3 max
        xq = (xe.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
        xq = constrain(xq, BATCH, TP, None, None)
        scale = constrain(scale, BATCH, TP, None, None)
        xe = (xq.astype(jnp.float32) * scale).astype(x.dtype)
    else:
        xe = constrain(xe, BATCH, TP, None, None)

    # expert FFN (E sharded over 'model'): (G,E,C,D) x (E,D,F)
    act = jax.nn.silu if cfg.mlp_act == "silu" else (lambda a: jax.nn.gelu(a, approximate=True))
    g = jnp.einsum("gecd,edf->gecf", xe, params["wg"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, params["wu"].astype(x.dtype))
    ye = jnp.einsum("gecf,efd->gecd", act(g) * u, params["wd"].astype(x.dtype))
    # NOTE (§Perf, kimi hillclimb): constraining ye back to group-major here
    # (the "textbook" EP return all-to-all) was MEASURED WORSE (53.7s vs 36.4s
    # collective) — the padded (G,E,C,D) tensor is ~25% larger than the scatter
    # payload GSPMD replicates instead.  Keep the expert-major constraint.
    ye = constrain(ye, BATCH, TP, None, None)

    # combine: scatter expert outputs back to tokens with gate weights
    gate_flat = gate.reshape(G, S * k)                            # (G, S*k)
    assign_gate = jnp.take_along_axis(
        jnp.concatenate([gate_flat, jnp.zeros((G, 1), gate_flat.dtype)], axis=1),
        jnp.where(valid, dispatch, S * k).reshape(G, E * C), axis=1
    ).reshape(G, E, C)
    # bf16 accumulation: each token sums <= k gate-weighted expert outputs, so
    # bf16 is safe and HALVES the scatter's replicated-AR payload (§Perf)
    y = jnp.zeros((G, S + 1, D), dtype=x.dtype)
    y = y.at[jnp.arange(G)[:, None, None], token_idx, :].add(
        ye * assign_gate[..., None].astype(ye.dtype))
    y = y[:, :S]

    # switch-style load-balance aux loss
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.mean(axis=(0, 1))                                  # (E,)
    one_hot = jax.nn.one_hot(flat_expert.reshape(G, S, k)[..., 0], E)
    ce = one_hot.reshape(-1, E).mean(axis=0)
    aux = E * jnp.sum(me * ce)
    return y, aux


def moe_ref(params: Dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Capacity-unbounded dense oracle: every token goes to its top-k experts."""
    G, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    logits = x @ params["router"].astype(x.dtype)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    act = jax.nn.silu if cfg.mlp_act == "silu" else (lambda a: jax.nn.gelu(a, approximate=True))
    # run every expert on every token (test sizes only)
    g = jnp.einsum("gsd,edf->gsef", x, params["wg"].astype(x.dtype))
    u = jnp.einsum("gsd,edf->gsef", x, params["wu"].astype(x.dtype))
    ye = jnp.einsum("gsef,efd->gsed", act(g) * u, params["wd"].astype(x.dtype))
    mask = jax.nn.one_hot(idx, E, dtype=jnp.float32) * gate[..., None]  # (G,S,k,E)
    w = mask.sum(axis=2)                                          # (G,S,E)
    return jnp.einsum("gsed,gse->gsd", ye.astype(jnp.float32),
                      w).astype(x.dtype)
