"""Block assembly: (attn | mamba) mixer + (dense | MoE) FFN, scan over repeats.

A model is ``pattern`` applied ``n_repeats`` times.  Parameters for pattern
position p are stacked with a leading (R,) axis and consumed by lax.scan, so
the HLO stays compact for 48-64 layer models.  Each layer is wrapped in
jax.checkpoint (full remat) when cfg.remat.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.act import BATCH, TP, constrain
from .attention import chunked_attention, decode_attention
from .layers import apply_rope, gated_mlp, rms_norm
from .mamba import (mamba_decode_step, mamba_forward, mamba_params_shapes,
                    mamba_prefill)
from .moe import moe_forward, moe_params_shapes

__all__ = ["block_param_shapes", "blocks_forward", "blocks_decode",
           "init_block_cache", "attn_cache_len"]


# --------------------------------------------------------------------------
# parameter shape declarations (one dict per pattern position; stacked by R)
# --------------------------------------------------------------------------

def _attn_shapes(cfg) -> Dict[str, tuple]:
    D, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = dict(wq=(D, H * hd), wk=(D, Kv * hd), wv=(D, Kv * hd), wo=(H * hd, D))
    if cfg.qkv_bias:
        s.update(bq=(H * hd,), bk=(Kv * hd,), bv=(Kv * hd,))
    return s


def block_param_shapes(cfg, spec) -> Dict[str, Any]:
    """Shapes for one pattern position (without the leading repeat axis)."""
    D = cfg.d_model
    p: Dict[str, Any] = dict(norm1=(D,))
    if spec.kind == "attn":
        p["attn"] = _attn_shapes(cfg)
    else:
        p["mamba"] = mamba_params_shapes(cfg)
    if spec.moe:
        p["norm2"] = (D,)
        p["moe"] = moe_params_shapes(cfg)
        del p["moe"]["norm"]
    elif cfg.d_ff:
        p["norm2"] = (D,)
        p["mlp"] = dict(wg=(D, cfg.d_ff), wu=(D, cfg.d_ff), wd=(cfg.d_ff, D))
    if spec.kind == "mamba":
        del p["mamba"]["norm"]
    return p


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _attn_sublayer(p, x, cfg, spec, rope, q_offset=0,
                   return_kv: bool = False):
    B, S, D = x.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"] + (p["bq"] if cfg.qkv_bias else 0)).reshape(B, S, H, hd)
    k = (x @ p["wk"] + (p["bk"] if cfg.qkv_bias else 0)).reshape(B, S, Kv, hd)
    v = (x @ p["wv"] + (p["bv"] if cfg.qkv_bias else 0)).reshape(B, S, Kv, hd)
    q = constrain(q, BATCH, None, TP, None)
    if Kv == 1:   # MQA: the single kv head cannot carry TP — shard head_dim
        k = constrain(k, BATCH, None, None, TP)
        v = constrain(v, BATCH, None, None, TP)
    else:
        k = constrain(k, BATCH, None, TP, None)
        v = constrain(v, BATCH, None, TP, None)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    o = chunked_attention(q, k, v, causal=cfg.causal, window=spec.window,
                          q_chunk=cfg.attn_chunk, k_chunk=cfg.attn_chunk,
                          q_offset=q_offset)
    out = o.reshape(B, S, H * hd) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def _ffn_sublayer(p, x, cfg, spec) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if spec.moe:
        B, S, D = x.shape
        y, aux = moe_forward(p["moe"], x.reshape(B, S, D), cfg)  # groups = batch
        return y.reshape(B, S, D), aux
    return gated_mlp(x, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"],
                     cfg.mlp_act), jnp.float32(0.0)


def _one_block(spec, p, x, cfg, rope, cache_slice=None, cur_pos=None):
    """Apply mixer + ffn.  If cache_slice is given we are decoding (S == 1)."""
    aux = jnp.float32(0.0)
    new_cache = None
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        if cache_slice is None:
            h = _attn_sublayer(p["attn"], h, cfg, spec, rope)
        else:
            h, new_cache = _attn_decode(p["attn"], h, cfg, spec, rope,
                                        cache_slice, cur_pos)
    else:
        if cache_slice is None:
            h = mamba_forward(p["mamba"], h, cfg)
        else:
            h, new_cache = mamba_decode_step(p["mamba"], h, cache_slice, cfg)
    x = x + h
    if "norm2" in p:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        h, aux = _ffn_sublayer(p, h, cfg, spec)
        x = x + h
    return x, aux, new_cache


def blocks_forward(block_params: List[Dict], x: jnp.ndarray, cfg, rope
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scan over repeats; returns (hidden, total_aux_loss)."""
    pattern = cfg.pattern

    def body(carry, stacked):
        h, aux = carry
        for spec, p in zip(pattern, stacked):
            h = constrain(h, BATCH, None, None)
            h, a, _ = _one_block(spec, p, h, cfg, rope)
            aux = aux + a
        h = constrain(h, BATCH, None, None)
        return (h, aux), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (h, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), tuple(block_params))
    return h, aux


# --------------------------------------------------------------------------
# decode (+ cache plumbing)
# --------------------------------------------------------------------------

def attn_cache_len(cfg, spec, max_len: int) -> int:
    if spec.window is not None:
        return min(spec.window, max_len)
    return max_len


def init_block_cache(cfg, spec, B: int, max_len: int, dtype) -> Optional[Dict]:
    """Cache pytree for ONE pattern position (without the repeat axis)."""
    if spec.kind == "attn":
        L = attn_cache_len(cfg, spec, max_len)
        Kv, hd = cfg.n_kv_heads, cfg.head_dim
        return dict(k=jnp.zeros((B, L, Kv, hd), dtype),
                    v=jnp.zeros((B, L, Kv, hd), dtype),
                    pos=jnp.full((L,), -1, jnp.int32))
    return dict(conv=jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner), dtype),
                ssm=jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32))


def _attn_decode(p, x, cfg, spec, rope, cache, cur_pos):
    B, S, D = x.shape            # S == 1
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"] + (p["bq"] if cfg.qkv_bias else 0)).reshape(B, 1, H, hd)
    k = (x @ p["wk"] + (p["bk"] if cfg.qkv_bias else 0)).reshape(B, 1, Kv, hd)
    v = (x @ p["wv"] + (p["bv"] if cfg.qkv_bias else 0)).reshape(B, 1, Kv, hd)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    L = cache["k"].shape[1]
    slot = jnp.mod(cur_pos, L)
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    posc = cache["pos"].at[slot].set(cur_pos)
    valid_window = spec.window if spec.window is not None else None
    o = _decode_attn_with_slots(q, kc, vc, posc, cur_pos, valid_window)
    out = o.reshape(B, 1, H * hd) @ p["wo"]
    return out, dict(k=kc, v=vc, pos=posc)


def _decode_attn_with_slots(q, k_cache, v_cache, slot_pos, cur_pos, window):
    import math as _m
    B, _, H, hd = q.shape
    L, Kv = k_cache.shape[1], k_cache.shape[2]
    G = H // Kv
    qg = q.reshape(B, Kv, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) / _m.sqrt(hd)
    valid = (slot_pos >= 0) & (slot_pos <= cur_pos)
    if window is not None:
        valid &= slot_pos > cur_pos - window
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def blocks_prefill(block_params: List[Dict], x: jnp.ndarray, cfg, rope,
                   max_len: int) -> Tuple[jnp.ndarray, List[Dict]]:
    """Forward over the prompt AND build the decode caches (leading (R,) axis)."""
    pattern = cfg.pattern
    B, S, _ = x.shape

    def body(h, params_r):
        caches_r = []
        for spec, p in zip(pattern, params_r):
            h = constrain(h, BATCH, None, None)
            hn = rms_norm(h, p["norm1"], cfg.norm_eps)
            if spec.kind == "attn":
                out, (k, v) = _attn_sublayer(p["attn"], hn, cfg, spec, rope,
                                             return_kv=True)
                L = attn_cache_len(cfg, spec, max_len)
                kc = jnp.zeros((B, L, cfg.n_kv_heads, cfg.head_dim), k.dtype)
                vc = jnp.zeros_like(kc)
                keep = min(S, L)
                # windowed layers keep the tail (window | S for our shapes)
                kc = jax.lax.dynamic_update_slice(kc, k[:, S - keep:], (0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(vc, v[:, S - keep:], (0, 0, 0, 0))
                pos = jnp.where(jnp.arange(L) < keep,
                                jnp.arange(L) + (S - keep), -1)
                cache = dict(k=kc, v=vc, pos=pos.astype(jnp.int32))
            else:
                out, cache = mamba_prefill(p["mamba"], hn, cfg)
            h = h + out
            if "norm2" in p:
                hn = rms_norm(h, p["norm2"], cfg.norm_eps)
                out, _ = _ffn_sublayer(p, hn, cfg, spec)
                h = h + out
            caches_r.append(cache)
        return h, tuple(caches_r)

    if cfg.remat:
        body = jax.checkpoint(body)
    h, caches = jax.lax.scan(body, x, tuple(block_params))
    return h, list(caches)


def blocks_decode(block_params: List[Dict], caches: List[Dict], x: jnp.ndarray,
                  cfg, rope, cur_pos) -> Tuple[jnp.ndarray, List[Dict]]:
    """One decode step through all layers.  caches[p] has leading (R,) axis."""
    pattern = cfg.pattern

    def body(h, stacked):
        params_r, caches_r = stacked
        new_caches_r = []
        for spec, p, c in zip(pattern, params_r, caches_r):
            h, _, nc = _one_block(spec, p, h, cfg, rope, cache_slice=c,
                                  cur_pos=cur_pos)
            new_caches_r.append(nc)
        return h, tuple(new_caches_r)

    h, new_caches = jax.lax.scan(body, x, (tuple(block_params), tuple(caches)))
    return h, list(new_caches)
