"""Elemental layers: RMSNorm, RoPE (incl. M-RoPE), gated MLPs.

Pure functions over explicit param pytrees.  The pure-jnp implementations here
are also the reference oracles for the Pallas kernels in repro.kernels.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rms_norm", "rope_angles", "apply_rope", "mrope_positions",
           "gated_mlp", "init_linear", "init_norm"]


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * weight.astype(jnp.float32)).astype(dt)


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                sections: Optional[Tuple[int, ...]] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables.

    positions: (B, S) for standard RoPE, or (3, B, S) for M-RoPE where the
    three planes are (temporal, height, width) and ``sections`` splits the
    head_dim/2 frequency bands across planes (qwen2-vl §2.1).
    Returns cos/sin of shape (B, S, head_dim/2).
    """
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 2:      # standard
        ang = positions[..., None].astype(jnp.float32) * freqs
    else:                        # M-RoPE: pick the plane per frequency band
        assert sections is not None and sum(sections) == half
        plane = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
        pos_per_band = positions[plane]                     # (half, B, S)
        ang = jnp.moveaxis(pos_per_band, 0, -1).astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, head_dim); cos/sin: (B, S, head_dim/2). Rotate-half form."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def mrope_positions(B: int, S: int, offset: int = 0) -> jnp.ndarray:
    """Text-stream M-RoPE positions: all three planes share 1D positions."""
    p = jnp.arange(offset, offset + S)[None, :].repeat(B, axis=0)
    return jnp.stack([p, p, p], axis=0)


def gated_mlp(x: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray, wd: jnp.ndarray,
              act: str = "silu") -> jnp.ndarray:
    """SwiGLU / GeGLU: down( act(x@wg) * (x@wu) )."""
    g = x @ wg
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    return (g * (x @ wu)) @ wd


def init_linear(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


def init_norm(shape, dtype):
    return jnp.ones(shape, dtype=dtype)
