"""LM model wrapper: params init, forward, chunked loss, prefill/decode."""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..parallel.act import BATCH, TP, constrain
from .layers import init_linear, init_norm, mrope_positions, rope_angles
from .transformer import (block_param_shapes, blocks_decode, blocks_forward,
                          blocks_prefill, init_block_cache)

__all__ = ["param_shapes", "init_params", "forward_hidden", "loss_fn",
           "prefill", "decode_step", "init_cache", "make_rope"]


def _dt(name: str):
    return dict(float32=jnp.float32, bfloat16=jnp.bfloat16)[name]


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

class Shape(tuple):
    """Shape leaf marker (so pytree flattening stops at shape tuples)."""


def _is_shape(x):
    return isinstance(x, Shape)


def param_shapes(cfg: ArchConfig) -> Dict[str, Any]:
    """Nested dict of Shape leaves (leading repeat axis on block params)."""
    R = cfg.n_repeats

    def mark(tree):
        if isinstance(tree, dict):
            return {k: mark(v) for k, v in tree.items()}
        return Shape((R, *tree))

    blocks = [mark(block_param_shapes(cfg, spec)) for spec in cfg.pattern]
    out = dict(embed=Shape((cfg.vocab_size, cfg.d_model)), blocks=blocks,
               final_norm=Shape((cfg.d_model,)))
    if not cfg.tie_embeddings:
        out["head"] = Shape((cfg.d_model, cfg.vocab_size))
    # strip the repeat axis from top-level (non-block) entries
    out["embed"] = Shape((cfg.vocab_size, cfg.d_model))
    out["final_norm"] = Shape((cfg.d_model,))
    return out


_BIAS_NAMES = {"bq", "bk", "bv", "conv_b", "dt_bias"}


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    dtype = _dt(cfg.param_dtype)
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=_is_shape)
    keys = jax.random.split(key, len(leaves))
    params = jax.tree.unflatten(
        treedef, [init_linear(k, tuple(s), dtype) for k, s in zip(keys, leaves)])
    return _fix_special_init(params, cfg)


def _fix_special_init(params, cfg):
    def walk(d, name=""):
        if isinstance(d, dict):
            return {k: walk(v, k) for k, v in d.items()}
        if isinstance(d, (list, tuple)):
            return type(d)(walk(v, name) for v in d)
        if name.startswith("norm") or name == "final_norm":
            return jnp.ones_like(d)
        if name in _BIAS_NAMES:
            return jnp.zeros_like(d)
        if name == "A_log":   # mamba: A = -exp(A_log); A_log = log(1..N)
            N = d.shape[-1]
            base = jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))
            return jnp.broadcast_to(base, d.shape).astype(jnp.float32)
        if name == "D":
            return jnp.ones(d.shape, dtype=jnp.float32)
        if name == "embed":
            return (d / jnp.maximum(jnp.std(d), 1e-6) * 0.02).astype(d.dtype)
        return d
    return walk(params)


# --------------------------------------------------------------------------
# rope helper
# --------------------------------------------------------------------------

def make_rope(cfg: ArchConfig, B: int, S: int, offset=0):
    if not cfg.causal:
        return None                      # encoder-only: frontend supplies pos info
    if cfg.mrope_sections is not None:
        pos = mrope_positions(B, S, 0) + offset
        return rope_angles(pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
    if np.isscalar(offset) or getattr(offset, "ndim", 0) == 0:
        pos = jnp.arange(S)[None, :].repeat(B, axis=0) + offset
    else:
        pos = offset[:, None] + jnp.arange(S)[None, :]
    return rope_angles(pos, cfg.head_dim, cfg.rope_theta)


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------

def _embed_in(params, batch, cfg):
    dtype = _dt(cfg.compute_dtype)
    if "embeds" in batch:                     # stub frontends (vlm/audio)
        return constrain(batch["embeds"].astype(dtype), BATCH, None, None)
    tok = batch["tokens"]
    return constrain(params["embed"].astype(dtype)[tok], BATCH, None, None)


def forward_hidden(params, batch, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = _embed_in(params, batch, cfg)
    B, S, _ = x.shape
    rope = make_rope(cfg, B, S)
    h, aux = blocks_forward(list(params["blocks"]), x, cfg, rope)
    return h, aux


def _head_weight(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def loss_fn(params, batch, cfg) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Sequence-chunked softmax xent (never materializes (B, S, V))."""
    from .layers import rms_norm
    h, aux = forward_hidden(params, batch, cfg)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    labels = batch["labels"]
    B, S, D = h.shape
    V = cfg.vocab_size
    c = min(cfg.loss_chunk, S)
    pad = (-S) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (S + pad) // c
    hw = _head_weight(params, cfg)

    def chunk(carry, inp):
        hs, ls = inp                                  # (B, c, D), (B, c)
        logits = (hs @ hw.astype(hs.dtype)).astype(jnp.float32)
        logits = constrain(logits, BATCH, None, TP)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, jnp.maximum(ls, 0)[..., None],
                                  axis=-1)[..., 0]
        valid = ls >= 0
        nll = jnp.where(valid, lse - tgt, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    body = chunk
    if cfg.remat:
        body = jax.checkpoint(chunk)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.int32(0)),
        (h.reshape(B, nc, c, D).swapaxes(0, 1), labels.reshape(B, nc, c).swapaxes(0, 1)))
    loss = tot / jnp.maximum(cnt, 1)
    total = loss + cfg.router_aux_coef * aux
    return total, dict(loss=loss, aux=aux, tokens=cnt)


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, B: int, max_len: int) -> List[Dict]:
    dtype = _dt(cfg.compute_dtype)
    R = cfg.n_repeats
    caches = []
    for spec in cfg.pattern:
        c = init_block_cache(cfg, spec, B, max_len, dtype)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (R, *a.shape)).copy() if a.ndim else a, c))
    return caches


def prefill(params, batch, cfg, max_len: int):
    """Returns (last-position logits, caches).  Encoder-only: (all logits, None)."""
    from .layers import rms_norm
    x = _embed_in(params, batch, cfg)
    B, S, _ = x.shape
    rope = make_rope(cfg, B, S)
    if not cfg.causal:
        h, _ = blocks_forward(list(params["blocks"]), x, cfg, rope)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = (h @ _head_weight(params, cfg).astype(h.dtype)).astype(jnp.float32)
        return logits, None
    h, caches = blocks_prefill(list(params["blocks"]), x, cfg, rope, max_len)
    h = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (h @ _head_weight(params, cfg).astype(h.dtype)).astype(jnp.float32)
    return logits[:, 0], caches


def decode_step(params, token, caches, cur_pos, cfg):
    """token: (B,) int32 (or (B, D) embeds for stub frontends);
    cur_pos: scalar int32.  Returns (logits (B, V), new caches)."""
    from .layers import rms_norm
    dtype = _dt(cfg.compute_dtype)
    if token.ndim == 2:                    # stub frontend embeds
        x = token.astype(dtype)[:, None, :]
    else:
        x = params["embed"].astype(dtype)[token][:, None, :]
    B = x.shape[0]
    rope = make_rope(cfg, B, 1, offset=cur_pos)
    h, new_caches = blocks_decode(list(params["blocks"]), caches, x, cfg, rope,
                                  cur_pos)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (h @ _head_weight(params, cfg).astype(h.dtype)).astype(jnp.float32)
    return logits[:, 0], new_caches
