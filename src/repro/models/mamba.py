"""Mamba-1 selective SSM block (falcon-mamba / jamba substrate).

Train/prefill path: lax.scan over time *chunks* with an associative scan
inside each chunk — the (B, chunk, d_inner, N) working set is transient (this
is exactly the blocking a TPU kernel wants; see kernels/mamba_scan).
Decode path: single-step recurrence over (conv_state, ssm_state).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["mamba_params_shapes", "mamba_forward", "mamba_prefill",
           "mamba_decode_step", "selective_scan_chunked", "selective_scan_ref"]


# --------------------------------------------------------------------------
# selective scan
# --------------------------------------------------------------------------

def _ssm_inputs(x, delta, A, B_t, C_t):
    """a_t = exp(delta_t A) (B,L,Di,N); b_t = delta_t * B_t * x_t."""
    a = jnp.exp(delta[..., None] * A[None, None])                 # (B,L,Di,N)
    b = (delta * x)[..., None] * B_t[:, :, None, :]               # (B,L,Di,N)
    return a, b


def selective_scan_ref(x, delta, A, B_t, C_t, D) -> jnp.ndarray:
    """Naive sequential oracle: h_t = a_t h_{t-1} + b_t; y_t = C_t.h_t + D x_t.

    x/delta: (B, L, Di); A: (Di, N); B_t/C_t: (B, L, N); D: (Di,).
    """
    a, b = _ssm_inputs(x, delta, A, B_t, C_t)

    def step(h, inp):
        a_t, b_t, c_t = inp
        h = a_t * h + b_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    B, L, Di = x.shape
    h0 = jnp.zeros((B, Di, A.shape[1]), dtype=jnp.float32)
    _, ys = jax.lax.scan(step, h0, (a.swapaxes(0, 1).astype(jnp.float32),
                                    b.swapaxes(0, 1).astype(jnp.float32),
                                    C_t.swapaxes(0, 1).astype(jnp.float32)))
    out = ys.swapaxes(0, 1) + x.astype(jnp.float32) * D[None, None]
    return out.astype(x.dtype)


def selective_scan_chunked(x, delta, A, B_t, C_t, D, chunk: int = 256,
                           h0: Optional[jnp.ndarray] = None,
                           scan_dtype=jnp.float32, impl: str = "assoc"
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked scan; returns (y, h_final).  Same math as selective_scan_ref."""
    B, L, Di = x.shape
    N = A.shape[1]
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
        B_t = jnp.pad(B_t, ((0, 0), (0, pad), (0, 0)))
        C_t = jnp.pad(C_t, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk
    xs = (x.reshape(B, nc, chunk, Di).swapaxes(0, 1),
          delta.reshape(B, nc, chunk, Di).swapaxes(0, 1),
          B_t.reshape(B, nc, chunk, N).swapaxes(0, 1),
          C_t.reshape(B, nc, chunk, N).swapaxes(0, 1))
    if h0 is None:
        h0 = jnp.zeros((B, Di, N), dtype=jnp.float32)

    def chunk_body(h, inp):
        from ..parallel.act import BATCH, TP, constrain
        xc, dc, bc, cc = inp
        if impl == "seq":
            # time-sequential: a_t/b_t built per step, y emitted directly;
            # HBM traffic ~2-3 passes of (B,c,Di,N) (bwd residuals) instead
            # of the associative scan's ~12
            def step(hh, s_inp):
                x_t, d_t, bt, ct = s_inp                     # (B,Di),(B,Di),(B,N),(B,N)
                a_t = jnp.exp(d_t[..., None].astype(jnp.float32) * A[None])
                b_t = (d_t * x_t)[..., None].astype(jnp.float32) \
                    * bt[:, None, :].astype(jnp.float32)
                hh = a_t * hh + b_t
                y_t = jnp.einsum("bdn,bn->bd", hh, ct.astype(jnp.float32))
                return hh, y_t
            h_f, ys = jax.lax.scan(
                step, h, (xc.swapaxes(0, 1), dc.swapaxes(0, 1),
                          bc.swapaxes(0, 1), cc.swapaxes(0, 1)))
            return h_f, constrain(ys.swapaxes(0, 1), BATCH, None, TP)
        a, b = _ssm_inputs(xc, dc, A, bc, cc)
        a = constrain(a.astype(scan_dtype), BATCH, None, TP, None)
        b = constrain(b.astype(scan_dtype), BATCH, None, TP, None)

        def combine(u, v):
            (a1, b1), (a2, b2) = u, v
            return a1 * a2, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_t = constrain(a_cum.astype(jnp.float32) * h[:, None]
                        + b_cum.astype(jnp.float32),              # (B,c,Di,N)
                        BATCH, None, TP, None)
        y = jnp.einsum("bcdn,bcn->bcd", h_t, cc.astype(jnp.float32))
        return h_t[:, -1], constrain(y, BATCH, None, TP)

    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, Lp, Di)[:, :L]
    out = y + x[:, :L].astype(jnp.float32) * D[None, None]
    return out.astype(x.dtype), h_final


# --------------------------------------------------------------------------
# full mamba block
# --------------------------------------------------------------------------

def mamba_params_shapes(cfg) -> Dict[str, tuple]:
    D, Di, N, R, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                      cfg.ssm_conv)
    return dict(in_proj=(D, 2 * Di), conv_w=(K, Di), conv_b=(Di,),
                x_proj=(Di, R + 2 * N), dt_proj=(R, Di), dt_bias=(Di,),
                A_log=(Di, N), D=(Di,), out_proj=(Di, D), norm=(D,))


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv along time via K shifted adds. x: (B, L, Di)."""
    K = w.shape[0]
    if state is not None:                       # prepend cached context
        x_ext = jnp.concatenate([state, x], axis=1)
    else:
        x_ext = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    L = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):
        y = y + x_ext[:, k:k + L].astype(jnp.float32) * w[k][None, None]
    return (y + b[None, None]).astype(x.dtype)


def _ssm_projections(params, u, cfg):
    N, R = cfg.ssm_state, cfg.dt_rank
    proj = u @ params["x_proj"]                                   # (B,L,R+2N)
    dt, B_t, C_t = jnp.split(proj, [R, R + N], axis=-1)
    delta = jax.nn.softplus(dt @ params["dt_proj"] + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    return delta, A, B_t, C_t


def mamba_forward(params: Dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """x: (B, L, D) -> (B, L, D)."""
    from ..parallel.act import BATCH, TP, constrain
    Di = cfg.d_inner
    xz = x @ params["in_proj"]
    u, z = jnp.split(xz, [Di], axis=-1)
    u = constrain(u, BATCH, None, TP)
    z = constrain(z, BATCH, None, TP)
    u = jax.nn.silu(_causal_conv(u, params["conv_w"], params["conv_b"]))
    delta, A, B_t, C_t = _ssm_projections(params, u, cfg)
    sdt = dict(float32=jnp.float32, bfloat16=jnp.bfloat16)[
        getattr(cfg, "ssm_scan_dtype", "float32")]
    y, _ = selective_scan_chunked(u, delta, A, B_t, C_t,
                                  params["D"].astype(jnp.float32),
                                  chunk=cfg.mamba_chunk, scan_dtype=sdt,
                                  impl=getattr(cfg, "ssm_impl", "assoc"))
    return (y * jax.nn.silu(z)) @ params["out_proj"]


def mamba_prefill(params: Dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, Dict]:
    """Forward over the prompt, returning the decode cache."""
    Di, K = cfg.d_inner, cfg.ssm_conv
    xz = x @ params["in_proj"]
    u, z = jnp.split(xz, [Di], axis=-1)
    conv_state = u[:, -(K - 1):, :]                               # raw inputs tail
    uc = jax.nn.silu(_causal_conv(u, params["conv_w"], params["conv_b"]))
    delta, A, B_t, C_t = _ssm_projections(params, uc, cfg)
    y, h_final = selective_scan_chunked(uc, delta, A, B_t, C_t,
                                        params["D"].astype(jnp.float32),
                                        chunk=cfg.mamba_chunk)
    out = (y * jax.nn.silu(z)) @ params["out_proj"]
    return out, dict(conv=conv_state, ssm=h_final)


def mamba_decode_step(params: Dict, x: jnp.ndarray, cache: Dict, cfg
                      ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, 1, D); cache: {conv: (B, K-1, Di), ssm: (B, Di, N)}."""
    Di = cfg.d_inner
    xz = x @ params["in_proj"]
    u, z = jnp.split(xz, [Di], axis=-1)
    conv_in = jnp.concatenate([cache["conv"], u], axis=1)        # (B, K, Di)
    w = params["conv_w"]
    uc = jnp.einsum("bkd,kd->bd", conv_in.astype(jnp.float32),
                    w.astype(jnp.float32)) + params["conv_b"]
    u1 = jax.nn.silu(uc)[:, None]                                 # (B,1,Di)
    delta, A, B_t, C_t = _ssm_projections(params, u1, cfg)
    a = jnp.exp(delta[..., None] * A[None, None])[:, 0]           # (B,Di,N)
    b = ((delta * u1)[..., None] * B_t[:, :, None, :])[:, 0]
    h = a.astype(jnp.float32) * cache["ssm"] + b.astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, C_t[:, 0].astype(jnp.float32))
    y = (y[:, None] + u1.astype(jnp.float32)
         * params["D"][None, None]).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ params["out_proj"]
    new_cache = dict(conv=conv_in[:, 1:], ssm=h)
    return out, new_cache
