"""GQA/MQA attention with causal masking, sliding windows, and a KV cache.

The train/prefill path is a *block-chunked online-softmax* (flash-style) in
pure JAX: it never materializes the (S, S) score matrix, skips fully-masked KV
blocks (causal/window block pruning happens at trace time, so the HLO contains
only the live blocks), and is numerically the oracle for the Pallas
``flash_attention`` kernel.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["chunked_attention", "decode_attention"]

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: Optional[int] = None,
                      q_chunk: int = 512, k_chunk: int = 512,
                      q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k, v: (B, Sk, Kv, hd) with H % Kv == 0.

    ``q_offset``: absolute position of q[0] (for prefill continuation).
    Block pruning: KV blocks entirely outside the causal/window band of a
    query block are skipped at trace time (no FLOPs in the HLO).
    """
    B, Sq, H, hd = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    scale = 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq, nk = -(-Sq // q_chunk), -(-Sk // k_chunk)
    # pad to multiples
    Sq_p, Sk_p = nq * q_chunk, nk * k_chunk
    qp = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    # (B, nk, kc, Kv, hd)
    kb = kp.reshape(B, nk, k_chunk, Kv, hd)
    vb = vp.reshape(B, nk, k_chunk, Kv, hd)

    out_chunks = []
    for qi in range(nq):
        qc = qp[:, qi * q_chunk:(qi + 1) * q_chunk]              # (B, qc, H, hd)
        qc = qc.reshape(B, q_chunk, Kv, G, hd)
        q_lo = q_offset + qi * q_chunk
        q_hi = q_lo + q_chunk - 1
        # live KV blocks for this query block
        live = []
        for ki in range(nk):
            k_lo, k_hi = ki * k_chunk, ki * k_chunk + k_chunk - 1
            if causal and k_lo > q_hi:
                continue                                          # future block
            if window is not None and k_hi < q_lo - window + 1:
                continue                                          # expired block
            live.append(ki)
        live_idx = jnp.array(live, dtype=jnp.int32)
        kl = kb[:, live_idx]                                      # (B, L, kc, Kv, hd)
        vl = vb[:, live_idx]

        m0 = jnp.full((B, q_chunk, Kv, G), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((B, q_chunk, Kv, G), dtype=jnp.float32)
        acc0 = jnp.zeros((B, q_chunk, Kv, G, hd), dtype=jnp.float32)

        q_pos = q_lo + jnp.arange(q_chunk)

        def body(carry, inp):
            m, l, acc = carry
            kc_, vc_, ki_ = inp
            s = jnp.einsum("bqkgd,bskd->bqkgs", qc, kc_,
                           preferred_element_type=jnp.float32) * scale
            k_pos = ki_ * k_chunk + jnp.arange(k_chunk)
            mask = _block_mask(q_pos, k_pos, causal, window)      # (qc, kc)
            mask &= (k_pos < Sk)[None, :]                         # padding
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgs,bskd->bqkgd", p.astype(vc_.dtype), vc_,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        # flash-attention-style backward: recompute block scores/probs instead
        # of saving the stacked (L, B, qc, ..., kc) intermediates
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(body), (m0, l0, acc0),
            (kl.swapaxes(0, 1), vl.swapaxes(0, 1), live_idx))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out_chunks.append(out.reshape(B, q_chunk, H, hd))
    o = jnp.concatenate(out_chunks, axis=1)[:, :Sq]
    return o.astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length: jnp.ndarray, *, window: Optional[int] = None
                     ) -> jnp.ndarray:
    """Single-position decode: q (B, 1, H, hd) against cache (B, S, Kv, hd).

    ``length``: number of valid cache positions (scalar int array).
    """
    B, _, H, hd = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    G = H // Kv
    qg = q.reshape(B, Kv, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    pos = jnp.arange(S)
    valid = pos < length
    if window is not None:
        valid &= pos >= length - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)
