"""Logical sharding rules -> PartitionSpecs for params, optimizer, batches, caches.

Mesh axes:
  single-pod : ('data', 'model')            = (16, 16)
  multi-pod  : ('pod', 'data', 'model')     = (2, 16, 16)

Policy (baseline, see EXPERIMENTS.md §Perf for the hillclimbed variants):
  * batch          -> ('pod', 'data')   (pure DP across pods, ICI-local FSDP)
  * TP ("model")   -> heads / d_ff / vocab / experts
  * FSDP ("data")  -> the d_model axis of every weight matrix (ZeRO-3 style;
                      GSPMD inserts the per-layer all-gathers)
  * long-context decode (batch < data axis) -> KV-cache sequence dim on 'data'
    (sequence parallelism for the cache)

``param_pspecs`` is the single source of truth for which parameter axes are
``'model'``-sharded: besides the GSPMD launch path, the workload-lowering
pass (``repro.core.workloads``) consults it to divide each parameter's
gradient bytes by its tensor-parallel shard factor and to count the
``'model'``-sharded matmul pairs that emit TP collectives.  That consumer
passes a duck-typed mesh — only ``mesh.axis_names`` and ``mesh.shape``
(a name -> size mapping) are read by the rule functions; no devices are
required to evaluate the rules.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..models import model as M

from .act import (BATCH, TP, _axis_size, _div, activation_mesh,  # noqa: F401
                  batch_axes, constrain, pick_tp_dim)

__all__ = ["batch_axes", "param_pspecs", "opt_pspecs", "batch_pspecs",
           "cache_pspecs", "to_shardings", "pick_tp_dim", "activation_mesh",
           "constrain", "BATCH", "TP"]

# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def _param_rule(name: str, shape: Tuple[int, ...], cfg: ArchConfig, mesh: Mesh,
                fsdp: str = "data") -> P:
    """Name+rank based PartitionSpec (leading dim may be the repeat axis)."""
    f = fsdp if _div(cfg.d_model, mesh, fsdp) else None

    def guard(spec: P, sh) -> P:
        # drop any axis assignment whose dim is not divisible
        out = []
        for dim, ax in zip(sh, tuple(spec) + (None,) * (len(sh) - len(spec))):
            if ax is None:
                out.append(None)
            else:
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = int(np.prod([_axis_size(mesh, a) for a in axes]))
                out.append(ax if dim % size == 0 else None)
        return P(*out)

    if name == "embed":
        return guard(P("model", f), shape)
    if name == "head":
        return guard(P(f, "model"), shape)
    if name in ("final_norm",):
        return P()
    # block params: leading repeat axis
    body = shape[1:]
    if name in ("wq", "wk", "wv", "in_proj"):          # (D, out)
        return guard(P(None, f, "model"), shape)
    if name in ("wo", "out_proj"):                     # (in, D)
        return guard(P(None, "model", f), shape)
    ep = _div(cfg.n_experts, mesh, "model") if cfg.n_experts else False
    if name in ("wg", "wu"):
        if len(body) == 2:                              # dense mlp (D, F)
            return guard(P(None, f, "model"), shape)
        if ep:                                          # moe (E, D, F): EP
            return guard(P(None, "model", f, None), shape)
        return guard(P(None, None, f, "model"), shape)  # few experts: TP on F
    if name == "wd":
        if len(body) == 2:                              # dense mlp (F, D)
            return guard(P(None, "model", f), shape)
        if ep:
            return guard(P(None, "model", None, f), shape)
        return guard(P(None, None, "model", f), shape)
    if name == "router":                                # (D, E)
        return guard(P(None, f, None), shape)
    if name in ("conv_w",):                             # (K, Di)
        return guard(P(None, None, "model"), shape)
    if name in ("conv_b", "dt_bias", "D"):              # (Di,)
        return guard(P(None, "model"), shape)
    if name in ("x_proj", "A_log"):                     # (Di, *)
        return guard(P(None, "model", None), shape)
    if name == "dt_proj":                               # (dt_rank, Di)
        return guard(P(None, None, "model"), shape)
    if name in ("bq", "bk", "bv"):                      # (H*hd,)
        return guard(P(None, "model"), shape)
    if name.startswith("norm"):
        return P()
    return P()                                          # safe default: replicate


def param_pspecs(cfg: ArchConfig, mesh: Mesh) -> Dict[str, Any]:
    """PartitionSpec tree matching ``models.model.param_shapes(cfg)``.

    Args:
      cfg: the architecture; expert/TP divisibility guards read its widths.
      mesh: a ``jax.sharding.Mesh`` — or any object with ``.axis_names`` and
        ``.shape`` (name -> size), which is all the rules consult.

    Returns a tree with the same structure as ``param_shapes(cfg)`` whose
    leaves are ``PartitionSpec``s; zip-walking the two trees pairs every
    parameter shape with its spec (how ``repro.core.workloads`` derives
    per-parameter shard factors).
    """
    shapes = M.param_shapes(cfg)

    def walk(tree, name=""):
        if isinstance(tree, M.Shape):
            return _param_rule(name, tuple(tree), cfg, mesh)
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return list(walk(v, name) for v in tree)
        return _param_rule(name, tuple(tree), cfg, mesh)

    return walk(shapes)


def opt_pspecs(cfg: ArchConfig, mesh: Mesh) -> Dict[str, Any]:
    ps = param_pspecs(cfg, mesh)
    return dict(m=ps, v=ps, step=P())


# --------------------------------------------------------------------------
# batches / caches
# --------------------------------------------------------------------------

def batch_pspecs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh) -> Dict[str, P]:
    ba = batch_axes(mesh)
    bsz = int(np.prod([_axis_size(mesh, a) for a in ba]))
    b = ba if shape.global_batch % bsz == 0 else None
    if b is None and shape.global_batch % _axis_size(mesh, "data") == 0:
        b = ("data",)
    spec: Dict[str, P] = {}
    if cfg.frontend != "none":
        spec["embeds"] = P(b, None, None)
    else:
        spec["tokens"] = P(b, None)
    if shape.kind == "train":
        spec["labels"] = P(b, None)
    return spec


def cache_pspecs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh) -> List[Dict]:
    """Per-pattern-position cache PartitionSpecs (leading repeat axis)."""
    ba = batch_axes(mesh)
    bsz = int(np.prod([_axis_size(mesh, a) for a in ba]))
    shard_batch = shape.global_batch % bsz == 0
    b = ba if shard_batch else None
    # long-context, tiny batch: sequence-parallel cache
    seq_ax = None if shard_batch else "data"
    out = []
    for spec in cfg.pattern:
        if spec.kind == "attn":
            from ..models.transformer import attn_cache_len
            L = attn_cache_len(cfg, spec, shape.seq_len)
            kv_ok = cfg.n_kv_heads % _axis_size(mesh, "model") == 0
            hd_ok = cfg.head_dim % _axis_size(mesh, "model") == 0
            heads = "model" if kv_ok else None
            hd = "model" if (not kv_ok and hd_ok) else None
            sax = seq_ax if (seq_ax and L % _axis_size(mesh, "data") == 0) else None
            out.append(dict(k=P(None, b, sax, heads, hd),
                            v=P(None, b, sax, heads, hd),
                            pos=P(None, sax)))
        else:
            di_ok = cfg.d_inner % _axis_size(mesh, "model") == 0
            di = "model" if di_ok else None
            out.append(dict(conv=P(None, b, None, di),
                            ssm=P(None, b, di, None)))
    return out


def to_shardings(pspecs, mesh: Mesh):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        pspecs, is_leaf=lambda x: isinstance(x, P))
