"""Explicit shard_map EP MoE — the fix for the kimi-k2 §Perf finding.

GSPMD replicates gather/scatter token routing (measured 7.3 TB/step of
collective traffic on kimi-k2 train_4k vs ~0.25 TB inherent).  This module
routes explicitly: inside a shard_map over the ('data','model') mesh, each
data shard sorts its own tokens, and the dispatch/return exchanges are
explicit ``jax.lax.all_to_all`` on the model axis — the exact EP volume,
nothing replicated.

Layout (per (data d, model m) shard):
  tokens   : local groups (G/d, S, D)
  experts  : wg/wu/wd shards (E/m, D, F)
  dispatch : (m, E/m, Cs, D) all_to_all on 'model' -> each model shard gets
             the slots destined for ITS experts from every data shard.

Forward-only building block (the full train-graph integration with custom
VJP is the roadmap item; this validates the exchange pattern and its cost).

Exchange-shape contract (what ``repro.core.workloads`` sizes its MoE
all-to-all phases from): each dispatch moves a padded ``(E, C, D/tp)`` slot
tensor per token group, ``C = capacity(S, E, k, capacity_factor)`` from
``repro.models.moe`` — capacity padding travels even when slots are empty.
Dispatch payload dtype is ``cfg.moe_dispatch_dtype``; the forward return and
both backward legs move the same shape in the compute dtype.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.moe import capacity

__all__ = ["ep_moe_forward"]


def _route_local(x, router_w, k: int, C: int, E: int):
    """Route one shard's tokens (S, D) into (E, C) slots (local sort)."""
    S = x.shape[0]
    logits = x @ router_w.astype(x.dtype)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, expert_idx = jax.lax.top_k(probs, k)
    gate = jnp.take_along_axis(probs, expert_idx, axis=-1)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    flat = expert_idx.reshape(-1)
    order = jnp.argsort(flat * (S * k) + jnp.arange(S * k))
    sorted_e = flat[order]
    counts = jnp.zeros(E, jnp.int32).at[flat].add(1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(S * k) - starts[sorted_e]
    ok = rank < C
    slot = jnp.where(ok, sorted_e * C + rank, E * C)
    dispatch = jnp.full(E * C + 1, S * k, jnp.int32).at[slot].set(order)[:E * C]
    token_of = jnp.where(dispatch < S * k, dispatch // k, S)
    xpad = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])
    xe = xpad[token_of].reshape(E, C, x.shape[1])
    return xe, dispatch.reshape(E, C), gate


def ep_moe_forward(mesh: Mesh, params: Dict, x: jnp.ndarray, cfg
                   ) -> jnp.ndarray:
    """Explicit-EP MoE forward over a ('data','model') mesh.

    Args:
      mesh: mesh whose 'model' axis hosts the experts (E % model_size == 0).
      params: dict with ``router (D,E)`` and ``wg/wu/wd (E,D,F)`` leaves,
        'model'-sharded on the expert axis.
      x: token groups ``(G, S, D)`` sharded on 'data'.
      cfg: ``ArchConfig`` — reads n_experts, experts_per_token, d_model,
        capacity_factor, mlp_act.

    Returns y ``(G, S, D)`` sharded on 'data'.  All cross-device traffic is
    two explicit all_to_all calls of exactly (E*C*D / model) payload per
    shard — the per-op volume the workload plan's moe phases reproduce.
    """
    E, k, D = cfg.n_experts, cfg.experts_per_token, cfg.d_model
    M = mesh.shape["model"]
    assert E % M == 0, "experts must divide the model axis"

    def local(x_l, router_w, wg_l, wu_l, wd_l):
        # x_l: (G_l, S, D); w*_l: (E/M, D, F)
        G_l, S, _ = x_l.shape
        C = capacity(S, E, k, cfg.capacity_factor)
        xe, dispatch, gate = jax.vmap(
            lambda xs: _route_local(xs, router_w, k, C, E))(x_l)
        # (G_l, E, C, D) -> regroup expert axis: (G_l, M, E/M, C, D)
        xe = xe.reshape(G_l, M, E // M, C, D)
        # dispatch exchange: split axis 1 across 'model', concat nothing —
        # each model shard receives every data-shard-local group's slots for
        # its experts: result (G_l * M?, ...) — all_to_all over model swaps
        # the M axis for a new leading shard axis.
        xe_r = jax.lax.all_to_all(xe, "model", split_axis=1, concat_axis=0,
                                  tiled=True)
        xe_r = xe_r.reshape(G_l * M, E // M, C, D)
        act = jax.nn.silu if cfg.mlp_act == "silu" else (
            lambda a: jax.nn.gelu(a, approximate=True))
        g = jnp.einsum("gecd,edf->gecf", xe_r, wg_l.astype(xe_r.dtype))
        u = jnp.einsum("gecd,edf->gecf", xe_r, wu_l.astype(xe_r.dtype))
        ye = jnp.einsum("gecf,efd->gecd", act(g) * u, wd_l.astype(xe_r.dtype))
        # return exchange: inverse all_to_all
        # inverse exchange: (G_l*M, E/M, C, D) -a2a-> (G_l, E, C, D)
        ye_b = jax.lax.all_to_all(ye, "model", split_axis=0, concat_axis=1,
                                  tiled=True)
        ye_b = ye_b.reshape(G_l, E, C, D)
        # local combine (gather + weighted sum), per group
        def combine(y_e, disp, xg, gates):
            S_l = xg.shape[0]
            flat_gate = jnp.concatenate(
                [gates.reshape(-1), jnp.zeros((1,), gates.dtype)])
            gsel = flat_gate[jnp.where(disp < S_l * k, disp, S_l * k)]
            tok = jnp.where(disp < S_l * k, disp // k, S_l)
            y = jnp.zeros((S_l + 1, D), y_e.dtype)
            y = y.at[tok.reshape(-1)].add(
                y_e.reshape(-1, D) * gsel.reshape(-1, 1).astype(y_e.dtype))
            return y[:S_l]
        return jax.vmap(combine)(ye_b, dispatch, x_l, gate)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P("data", None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=P("data", None, None),
        check_rep=False)
    return fn(x, params["router"], params["wg"], params["wu"], params["wd"])
