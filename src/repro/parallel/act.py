"""Activation sharding constraints (mesh-context based).

Model code calls ``constrain(x, *logical_axes)``; outside an
``activation_mesh`` context this is a no-op, so CPU unit tests and
single-device runs are unaffected.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["activation_mesh", "constrain", "BATCH", "TP",
           "batch_axes", "pick_tp_dim"]

# logical activation axes used by model code (resolved against the live mesh)
BATCH = ("pod", "data")
TP = "model"

_ACT_MESH: Optional[Mesh] = None


class activation_mesh:
    """Context: model-internal ``constrain`` calls target this mesh.
    No-op (constraints vanish) when not entered — CPU unit tests unaffected."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        global _ACT_MESH
        self._old = _ACT_MESH
        _ACT_MESH = self.mesh
        return self.mesh

    def __exit__(self, *exc):
        global _ACT_MESH
        _ACT_MESH = self._old
        return False


def constrain(x, *spec):
    """with_sharding_constraint against the context mesh; silently drops axes
    that are absent from the mesh or do not divide the dimension."""
    mesh = _ACT_MESH
    if mesh is None or x is None:
        return x
    clean = []
    for dim, entry in zip(x.shape, spec):
        if entry is None:
            clean.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if axes and dim % size == 0 and size > 1:
            clean.append(axes if len(axes) > 1 else axes[0])
        elif len(axes) == 1 or not axes:
            clean.append(None)
        else:
            # try prefixes (e.g. ('pod','data') -> 'pod' alone won't help batch
            # locality; just drop)
            clean.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*clean)))


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return n % _axis_size(mesh, axis) == 0


def pick_tp_dim(mesh: Mesh, *dims: int) -> int:
    """Index (into dims) of the first dim divisible by the model axis, else -1."""
    for i, d in enumerate(dims):
        if d and _div(d, mesh, "model"):
            return i
    return -1


