"""Zero-dependency tracing + metrics: the observability substrate of repro.

Three primitives, threaded through every engine (spectral, routing, traffic,
faults, synthesis, simulate, workloads, the spmv kernel dispatcher):

* **Spans** — :func:`span` / :func:`traced` record hierarchical wall-time
  intervals with tags and the peak-RSS high-water delta across the span.
  Recording is **off by default** (a disabled span is a shared no-op object);
  :func:`tracing` / :func:`enable` turn it on.  The buffer renders as
  Chrome-trace-event JSON (:func:`write_trace`, loadable in Perfetto /
  ``chrome://tracing``), a text tree (:func:`render_tree`), or an aggregated
  :class:`MetricsReport` (:func:`metrics_report`).
* **Counters** — :func:`count` / :func:`counters` are always on (a dict
  increment under a lock — nanoseconds, never gated on :func:`enabled`).
  The engines maintain the canonical counter namespace:

  - ``jit_trace/<engine>`` — incremented inside a jitted body, so it counts
    XLA (re)traces, not calls: a jit cache hit replays a compiled trace
    without re-entering Python.  The no-retrace regression gate asserts
    these stay flat across repeated identical runs.
  - ``spmv/pallas_trace`` — Pallas-kernel traces (the old
    ``kernel_trace_count`` probe, now a first-class counter).
  - ``spmv/dispatch/<backend>`` — :func:`repro.kernels.spmv.spmv` dispatch
    decisions (trace-time under jit, per-call eagerly).
  - ``spmv/matvec/<backend>`` — matvec closures created per resolved
    backend (the trace-time backend-resolution invariant of the survey).
  - ``lanczos/solves`` / ``lanczos/iters`` /
    ``lanczos/breakdown_truncations`` — host-side Lanczos accounting.
  - ``routing/bfs_sources`` / ``routing/bootstrap_reps`` — sampled-routing
    effort accounting.
  - ``survey/lanczos_groups`` / ``survey/lanczos_grouped_instances`` — the
    PR-1 same-shape batching decisions.

* **Telemetry** — the per-round simulator arrays live in
  :class:`repro.core.simulate.RoundTelemetry` (``run_schedule(telemetry=
  True)``); this module only carries the span/counter side.

Everything here is stdlib-only (``time``/``resource``/``json``/``threading``)
so ``tools/``-style consumers can import it with no numpy/jax installed.
RSS figures use ``getrusage(RUSAGE_SELF).ru_maxrss`` (KiB on Linux): a
*high-water* mark, so a span's ``rss_delta_kb`` reports how much the process
peak grew during the span (0 for work below the current peak), not live heap.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import pathlib
import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Union

try:                                    # Unix; absent on Windows — RSS -> 0
    import resource as _resource
except ImportError:                     # pragma: no cover
    _resource = None

__all__ = [
    "span", "traced", "tracing", "enable", "disable", "enabled",
    "count", "counters", "counter_delta", "reset_counters",
    "trace_events", "reset_spans", "reset", "write_trace", "render_tree",
    "metrics_report", "MetricsReport", "SpanStat", "peak_rss_kb",
]

_LOCK = threading.Lock()
_COUNTERS: Dict[str, int] = {}
_EVENTS: List[Dict[str, Any]] = []      # completed spans, Chrome "X" phase
_ENABLED = False
_T0 = time.perf_counter()               # trace-time origin (ts=0)
_TLS = threading.local()


def peak_rss_kb() -> int:
    """Process peak RSS high-water mark in KiB (0 where unsupported)."""
    if _resource is None:               # pragma: no cover
        return 0
    return int(_resource.getrusage(_resource.RUSAGE_SELF).ru_maxrss)


# --------------------------------------------------------------------------
# counters (always on)
# --------------------------------------------------------------------------

def count(name: str, inc: int = 1) -> None:
    """Increment counter ``name`` by ``inc`` (thread-safe, never gated)."""
    with _LOCK:
        _COUNTERS[name] = _COUNTERS.get(name, 0) + int(inc)


def counters(prefix: Optional[str] = None) -> Dict[str, int]:
    """Snapshot of all counters, optionally filtered to a name prefix."""
    with _LOCK:
        snap = dict(_COUNTERS)
    if prefix is None:
        return snap
    return {k: v for k, v in snap.items() if k.startswith(prefix)}


def counter_delta(before: Dict[str, int],
                  prefix: Optional[str] = None) -> Dict[str, int]:
    """Counters that changed since the ``before`` snapshot (non-zero deltas
    only) — the idiom behind every no-retrace assertion::

        before = obs.counters("jit_trace/")
        run_again()
        assert obs.counter_delta(before, "jit_trace/") == {}
    """
    after = counters(prefix)
    keys = set(before) | set(after)
    out = {}
    for k in keys:
        if prefix is not None and not k.startswith(prefix):
            continue
        d = after.get(k, 0) - before.get(k, 0)
        if d:
            out[k] = d
    return out


def reset_counters() -> None:
    with _LOCK:
        _COUNTERS.clear()


# --------------------------------------------------------------------------
# spans (off unless enabled)
# --------------------------------------------------------------------------

def enabled() -> bool:
    """Whether spans are currently being recorded."""
    return _ENABLED


def enable() -> None:
    """Start recording spans (counters are always on regardless)."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


class _NullSpan:
    """Shared no-op context — the full cost of a disabled span."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("name", "tags", "_t_start", "_rss0", "_depth")

    def __init__(self, name: str, tags: Dict[str, Any]):
        self.name = name
        self.tags = tags

    def __enter__(self):
        stack = getattr(_TLS, "stack", None)
        if stack is None:
            stack = _TLS.stack = []
        self._depth = len(stack)
        stack.append(self)
        self._rss0 = peak_rss_kb()
        self._t_start = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t_end = time.perf_counter()
        rss1 = peak_rss_kb()
        stack = _TLS.stack
        if stack and stack[-1] is self:
            stack.pop()
        args = dict(self.tags)
        args["rss_delta_kb"] = max(0, rss1 - self._rss0)
        args["depth"] = self._depth
        ev = dict(name=self.name, ph="X", cat=str(self.tags.get("phase", "span")),
                  ts=(self._t_start - _T0) * 1e6,
                  dur=(t_end - self._t_start) * 1e6,
                  pid=1, tid=threading.get_ident() & 0xFFFF, args=args)
        with _LOCK:
            _EVENTS.append(ev)
        return False


def span(name: str, **tags: Any):
    """Context manager recording one hierarchical span.

    ``tags`` are attached verbatim (Chrome-trace ``args``); the reserved tag
    ``phase=`` ("build" / "compile" / "execute") feeds the per-phase wall-time
    breakdown of :func:`metrics_report`.  When recording is disabled this
    returns a shared no-op object — safe on hot paths.
    """
    if not _ENABLED:
        return _NULL_SPAN
    return _Span(name, tags)


def traced(name: Optional[str] = None, phase: Optional[str] = None,
           **tags: Any) -> Callable:
    """Decorator form of :func:`span` — zero overhead while disabled::

        @obs.traced("routing/analyze", phase="execute")
        def analyze_routing(...): ...
    """
    def deco(fn: Callable) -> Callable:
        label = name or fn.__name__
        static = dict(tags)
        if phase is not None:
            static["phase"] = phase

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not _ENABLED:
                return fn(*a, **kw)
            with _Span(label, static):
                return fn(*a, **kw)

        return wrapper

    return deco


@contextlib.contextmanager
def tracing(path: Optional[Union[str, pathlib.Path]] = None):
    """Enable span recording inside the block; optionally write the Chrome
    trace JSON to ``path`` on exit.  Nests: an inner ``tracing()`` inside an
    already-enabled region neither clears the buffer nor disables recording
    on exit (the outermost activation owns both)."""
    global _ENABLED
    prev = _ENABLED
    if not prev:
        reset_spans()
    _ENABLED = True
    try:
        yield
    finally:
        _ENABLED = prev
        if path is not None:
            write_trace(path)


def trace_events() -> List[Dict[str, Any]]:
    """Copy of the recorded span buffer (Chrome trace-event dicts)."""
    with _LOCK:
        return [dict(e) for e in _EVENTS]


def reset_spans() -> None:
    """Clear the span buffer (counters untouched)."""
    with _LOCK:
        _EVENTS.clear()


def reset() -> None:
    """Clear spans AND counters (test isolation)."""
    reset_spans()
    reset_counters()


def write_trace(path: Union[str, pathlib.Path],
                events: Optional[Iterable[Dict[str, Any]]] = None) -> str:
    """Write the span buffer (or ``events``) as Chrome trace-event JSON
    (``{"traceEvents": [...]}``, ts/dur in microseconds — the format Perfetto
    and ``chrome://tracing`` load directly).  Returns the path written."""
    evs = trace_events() if events is None else list(events)
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(
        dict(traceEvents=evs, displayTimeUnit="ms"), indent=1))
    return str(p)


def render_tree(events: Optional[Iterable[Dict[str, Any]]] = None) -> str:
    """Text rendering of the span hierarchy (indent = nesting depth)::

        survey/row [instance=slimfly(13)]  41.2ms
          spectral/rho2_lanczos  38.9ms  (+12.0MB peak)
    """
    evs = trace_events() if events is None else list(events)
    evs.sort(key=lambda e: e["ts"])
    lines = []
    for e in evs:
        args = e.get("args", {})
        depth = int(args.get("depth", 0))
        tags = {k: v for k, v in args.items()
                if k not in ("depth", "rss_delta_kb")}
        tag_s = (" [" + ", ".join(f"{k}={v}" for k, v in sorted(tags.items()))
                 + "]") if tags else ""
        rss = int(args.get("rss_delta_kb", 0))
        rss_s = f"  (+{rss / 1024:.1f}MB peak)" if rss else ""
        lines.append(f"{'  ' * depth}{e['name']}{tag_s}  "
                     f"{e['dur'] / 1e3:.1f}ms{rss_s}")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# aggregation
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SpanStat:
    """Aggregate of every recorded span sharing one name."""
    name: str
    calls: int
    total_seconds: float
    max_seconds: float
    rss_delta_kb: int          # summed peak-RSS growth across the spans

    def to_dict(self) -> Dict[str, Any]:
        return dict(name=self.name, calls=self.calls,
                    total_seconds=round(self.total_seconds, 6),
                    max_seconds=round(self.max_seconds, 6),
                    rss_delta_kb=self.rss_delta_kb)


def _interval_union_seconds(intervals: List[tuple]) -> float:
    """Total length of the union of (start, end) intervals — phase seconds
    without double-counting nested same-phase spans."""
    if not intervals:
        return 0.0
    intervals.sort()
    total, cur_lo, cur_hi = 0.0, intervals[0][0], intervals[0][1]
    for lo, hi in intervals[1:]:
        if lo > cur_hi:
            total += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    return total + (cur_hi - cur_lo)


@dataclasses.dataclass
class MetricsReport:
    """Aggregated view of one recording window.

    ``spans`` aggregates by span name; ``phases`` maps each ``phase=`` tag to
    the union-length of its spans' wall intervals (seconds — nested or
    overlapping same-phase spans are not double-counted); ``counters`` is a
    snapshot; ``peak_rss_kb`` the process high-water mark at report time.
    """
    spans: Dict[str, SpanStat]
    phases: Dict[str, float]
    counters: Dict[str, int]
    peak_rss_kb: int

    def to_dict(self) -> Dict[str, Any]:
        return dict(
            spans={k: v.to_dict() for k, v in sorted(self.spans.items())},
            phases={k: round(v, 6) for k, v in sorted(self.phases.items())},
            counters=dict(sorted(self.counters.items())),
            peak_rss_kb=self.peak_rss_kb)

    def report(self) -> str:
        """Compact text block for CLI output."""
        lines = [f"peak RSS        : {self.peak_rss_kb / 2**20:.2f} GiB"]
        if self.phases:
            ph = ", ".join(f"{k} {v:.3f}s" for k, v in sorted(self.phases.items()))
            lines.append(f"phases          : {ph}")
        for st in sorted(self.spans.values(), key=lambda s: -s.total_seconds):
            lines.append(f"  {st.name:32s} x{st.calls:<4d} "
                         f"{st.total_seconds * 1e3:9.1f}ms total, "
                         f"{st.max_seconds * 1e3:8.1f}ms max")
        return "\n".join(lines)


def metrics_report(events: Optional[Iterable[Dict[str, Any]]] = None
                   ) -> MetricsReport:
    """Aggregate the span buffer (or ``events``) into a :class:`MetricsReport`."""
    evs = trace_events() if events is None else list(events)
    spans: Dict[str, SpanStat] = {}
    phase_ivals: Dict[str, List[tuple]] = {}
    for e in evs:
        dur_s = e["dur"] / 1e6
        st = spans.get(e["name"])
        if st is None:
            spans[e["name"]] = SpanStat(e["name"], 1, dur_s, dur_s,
                                        int(e["args"].get("rss_delta_kb", 0)))
        else:
            st.calls += 1
            st.total_seconds += dur_s
            st.max_seconds = max(st.max_seconds, dur_s)
            st.rss_delta_kb += int(e["args"].get("rss_delta_kb", 0))
        phase = e["args"].get("phase")
        if phase is not None:
            phase_ivals.setdefault(str(phase), []).append(
                (e["ts"], e["ts"] + e["dur"]))
    phases = {p: _interval_union_seconds(iv) / 1e6
              for p, iv in phase_ivals.items()}
    return MetricsReport(spans=spans, phases=phases, counters=counters(),
                        peak_rss_kb=peak_rss_kb())
