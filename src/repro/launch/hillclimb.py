"""§Perf hillclimb driver: re-lower one cell with a named variant and record
before/after next to the baseline artifact.

    PYTHONPATH=src python -m repro.launch.hillclimb --arch kimi-k2-1t-a32b \
        --shape train_4k --variant fp8_dispatch \
        --overrides '{"moe_dispatch_dtype": "float8_e4m3fn"}'
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402
from pathlib import Path  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--overrides", default="{}")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    from .dryrun import lower_cell
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    overrides = json.loads(args.overrides)
    tag = f"{args.arch}__{args.shape}__{'2x16x16' if args.multi_pod else '16x16'}"
    t0 = time.time()
    result, _ = lower_cell(args.arch, args.shape, args.multi_pod,
                           overrides=overrides or None)
    result["variant"] = args.variant
    result["overrides"] = overrides
    path = out / f"{tag}__{args.variant}.json"
    path.write_text(json.dumps(result, indent=1))
    base_path = Path("experiments/dryrun") / f"{tag}.json"
    line = (f"{args.variant}: compute={result['roofline']['compute_s']:.4f}s "
            f"memory={result['roofline']['memory_s']:.4f}s "
            f"collective={result['roofline']['collective_s']:.4f}s "
            f"dominant={result['roofline']['dominant']} "
            f"[{time.time() - t0:.0f}s]")
    if base_path.exists():
        b = json.loads(base_path.read_text())["roofline"]
        line += (f"   (baseline: {b['compute_s']:.4f}/{b['memory_s']:.4f}"
                 f"/{b['collective_s']:.4f} {b['dominant']})")
    print(line)


if __name__ == "__main__":
    main()
