"""Production meshes.  A function (not a module constant) so importing never
touches jax device state."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "shard_batch"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 v5e pod (256 chips), or 2 pods = 512 chips with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


def shard_batch(*arrays):
    """Shard the leading (batch) axis of each array across local devices.

    The batched Lanczos solvers call this on every (B, ...) operand tile so a
    multi-device host splits the B independent per-sample recurrences across
    its devices — jit partitions the vmapped solve along the input sharding
    with zero cross-device traffic (each sample's Lanczos is independent).

    Identity when only one device exists or B doesn't divide evenly (the
    tail tile of a chunked solve): sharding must never change results, only
    placement.  Returns the arrays in order (a single array unwrapped).
    """
    ndev = jax.local_device_count()
    B = arrays[0].shape[0]
    if ndev > 1 and B % ndev == 0:
        mesh = jax.make_mesh((ndev,), ("data",))
        spec = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("data"))
        arrays = tuple(jax.device_put(a, spec) for a in arrays)
    return arrays if len(arrays) > 1 else arrays[0]
