"""Production meshes.  A function (not a module constant) so importing never
touches jax device state."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 v5e pod (256 chips), or 2 pods = 512 chips with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    return jax.make_mesh((data, model), ("data", "model"))
