"""Roofline analysis of a compiled dry-run artifact (post-partitioning HLO).

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (no trip-count
scaling), which under-counts scan-over-layers models by ~n_layers.  This
module re-derives the terms from the HLO text directly:

  * builds the computation call graph (while bodies x known_trip_count,
    conditionals, fusions) and propagates execution multipliers from ENTRY;
  * FLOPs: every ``dot`` op contributes 2 * prod(output) * prod(contracting)
    (contracting dims parsed from the op attributes) x its multiplier;
  * collective bytes: per-device payload of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute x multiplier;
  * HBM bytes: per-instruction operand+output accounting at fusion
    granularity (fusion internals excluded) — the same convention as XLA's
    bytes-accessed, i.e. an upper bound that ignores on-chip reuse.

Hardware constants: TPU v5e-class (197 TFLOP/s bf16, 819 GB/s HBM,
4 ICI links x 50 GB/s per chip).

Besides compiled dry-run artifacts, ``analyze_hlo`` is the independent
auditor of the workload-lowering pass: ``repro.core.workloads`` re-emits its
closed-form communication plan as a synthetic HLO module and requires this
parser's per-kind collective byte totals to match (``hlo_crosscheck``).
``HW["peak_flops"]`` also sets that subsystem's compute-time denominator.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "analyze_hlo", "roofline_terms", "HloStats"]

HW = dict(peak_flops=197e12, hbm_bw=819e9, link_bw=50e9, n_links=4,
          hbm_bytes=16e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "s2": 1, "u2": 1,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_MEM_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
                 "bitcast", "after-all", "partition-id", "replica-id"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _ARRAY_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    line: str


# result type is either a tuple shape "(s32[], f32[...]{...}, ...)" (no nested
# parens, but may contain /*index=N*/ comments) or a plain array shape.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],\{\}]+)\s+"
    r"([\w\-]+)\(")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\.v\d+)?\s*\(")


@dataclasses.dataclass
class HloStats:
    flops: float
    hbm_bytes: float          # fusion-aware estimate (roofline memory term)
    hbm_bytes_unfused: float  # every op's operands+outputs (upper bound)
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, float]
    dot_count: float

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


# ops whose outputs are materialized to HBM on a TPU-style fused compile;
# bare elementwise/broadcast/reduce/convert ops are assumed fused into their
# producers/consumers (the CPU backend fuses far less than TPU would, so
# counting them would overstate HBM traffic ~20x).
_MATERIALIZE_OPS = {
    "dot", "convolution", "fusion", "copy", "dynamic-update-slice",
    "dynamic-slice", "gather", "scatter", "sort", "custom-call", "rng",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "transpose", "reshape", "concatenate", "pad",
    "slice", "iota",
}


def parse_module(text: str):
    comps: Dict[str, List[Instr]] = {}
    shapes: Dict[str, str] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if stripped.endswith("{") and "(" in stripped and "=" not in stripped.split("(")[0]:
            m = _HDR_RE.match(stripped)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, shape, op = im.group(1), im.group(2).strip(), im.group(3)
        # operand names: %tokens inside the first (...) group
        paren = line[line.index(op + "(") + len(op) + 1:]
        depth, args = 1, []
        buf = ""
        for ch in paren:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args.append(buf)
                    break
            if depth >= 1:
                buf += ch
        opnames = re.findall(r"%([\w\.\-]+)", args[0] if args else "")
        inst = Instr(name, shape, op, opnames, line)
        comps[cur].append(inst)
        shapes[name] = shape
    return comps, shapes, entry


def _trip_count(line: str) -> int:
    m = re.search(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)', line)
    if m:
        return int(m.group(1))
    return 1


def _callees(inst: Instr) -> List[Tuple[str, int, str]]:
    """(callee, multiplier, kind) edges of an instruction."""
    out = []
    if inst.op == "while":
        cm = re.search(r"condition=%?([\w\.\-]+)", inst.line)
        bm = re.search(r"body=%?([\w\.\-]+)", inst.line)
        trips = _trip_count(inst.line)
        if bm:
            out.append((bm.group(1), trips, "body"))
        if cm:
            out.append((cm.group(1), trips + 1, "cond"))
        return out
    if inst.op == "conditional":
        bm = re.search(r"branch_computations=\{([^}]*)\}", inst.line)
        if bm:
            for b in bm.group(1).split(","):
                out.append((b.strip().lstrip("%"), 1, "branch"))
        for k in ("true_computation", "false_computation"):
            m = re.search(rf"{k}=%?([\w\.\-]+)", inst.line)
            if m:
                out.append((m.group(1), 1, "branch"))
        return out
    if inst.op == "fusion":
        m = re.search(r"calls=%?([\w\.\-]+)", inst.line)
        if m:
            out.append((m.group(1), 1, "fusion"))
        return out
    if inst.op in ("call", "async-start", "custom-call"):
        m = re.search(r"(?:to_apply|calls|called_computation)=%?([\w\.\-]+)", inst.line)
        if m:
            out.append((m.group(1), 1, "call"))
    return out


def _dot_flops(inst: Instr, shapes: Dict[str, str]) -> float:
    out_dims = _shape_dims(inst.shape)
    lhs = shapes.get(inst.operands[0]) if inst.operands else None
    if lhs is None:
        return 0.0
    lhs_dims = _shape_dims(lhs)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
    if m and m.group(1):
        k = 1
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                k *= lhs_dims[di]
    else:
        k = 1
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * k


def analyze_hlo(text: str) -> HloStats:
    """Parse one post-partitioning HLO module and total its roofline terms.

    Args:
      text: HLO text (``module.to_string()`` of a compiled executable, or
        the synthetic module from ``CommPlan.to_hlo()``).

    Returns an ``HloStats`` with trip-count-scaled per-device totals: FLOPs,
    fusion-aware HBM bytes, and per-kind collective payload bytes/counts
    (all-gather counted by gathered OUTPUT bytes, every other collective by
    operand bytes — the convention the workload cross-check matches).
    """
    comps, shapes, entry = parse_module(text)
    if entry is None:
        entry = next(iter(comps), None)
    # propagate multipliers; kind 'fusion' bodies tracked separately for memory
    mult: Dict[str, float] = {}
    fusion_body: Dict[str, bool] = {}

    stack = [(entry, 1.0, False)]
    seen_guard = 0
    while stack:
        seen_guard += 1
        if seen_guard > 200000:
            break
        name, m, in_fusion = stack.pop()
        if name not in comps:
            continue
        mult[name] = mult.get(name, 0.0) + m
        fusion_body[name] = fusion_body.get(name, True) and in_fusion
        for inst in comps[name]:
            for callee, k, kind in _callees(inst):
                stack.append((callee, m * k, in_fusion or kind == "fusion"))

    flops = 0.0
    hbm_fused = 0.0
    hbm_unfused = 0.0
    coll_b = {k: 0.0 for k in _COLLECTIVES}
    coll_c = {k: 0.0 for k in _COLLECTIVES}
    dots = 0.0
    for cname, insts in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        is_fusion = fusion_body.get(cname, False)
        for inst in insts:
            op = inst.op
            if op in ("dot", "convolution"):
                flops += m * _dot_flops(inst, shapes)
                dots += m
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                if base == "all-gather":
                    payload = _shape_bytes(inst.shape)       # gathered bytes
                else:
                    payload = sum(_shape_bytes(shapes.get(o, ""))
                                  for o in inst.operands)
                coll_b[base] += m * payload
                coll_c[base] += m
            if not is_fusion and op not in _SKIP_MEM_OPS and not op.endswith("-done"):
                out_b = _shape_bytes(inst.shape)
                in_b = sum(_shape_bytes(shapes.get(o, "")) for o in inst.operands)
                hbm_unfused += m * (out_b + in_b)
                if base in _MATERIALIZE_OPS:
                    # in-place loop accumulators (scan stacking): each slice is
                    # written once over the loop, so the buffer counts ONCE,
                    # not once per iteration.
                    in_place = (op == "dynamic-update-slice"
                                or any(shapes.get(o) == inst.shape
                                       for o in inst.operands))
                    hbm_fused += (1.0 if in_place else m) * out_b
                    if op in ("dot", "convolution"):
                        hbm_fused += m * in_b
    return HloStats(flops=flops, hbm_bytes=hbm_fused,
                    hbm_bytes_unfused=hbm_unfused, collective_bytes=coll_b,
                    collective_counts=coll_c, dot_count=dots)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float) -> Dict[str, float]:
    """Per-device roofline times (seconds) and the dominant term.

    Inputs are per-device totals for one step; returns ``compute_s`` /
    ``memory_s`` / ``collective_s`` at the ``HW`` constants plus
    ``dominant``, the largest of the three.
    """
    t_compute = flops_per_device / HW["peak_flops"]
    t_memory = bytes_per_device / HW["hbm_bw"]
    t_coll = collective_bytes_per_device / (HW["n_links"] * HW["link_bw"])
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    return dict(compute_s=t_compute, memory_s=t_memory, collective_s=t_coll,
                dominant=dominant)
