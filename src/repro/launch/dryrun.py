"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as a module entry point:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out experiments/dryrun

Emits one JSON artifact per cell with memory_analysis, cost_analysis,
collective bytes (HLO-parsed, trip-count aware) and the three roofline terms.
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax locks
# the device count at first init, so these two lines precede ANY other import.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from ..configs.base import SHAPES, cells_for, get_config, list_configs  # noqa: E402
from ..optim.adamw import AdamWConfig  # noqa: E402
from ..parallel import sharding as sh  # noqa: E402
from ..train.steps import make_decode_step, make_prefill_step, make_train_step  # noqa: E402
from . import hlo_analysis as H  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .specs import cache_specs, input_specs, train_state_specs  # noqa: E402


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               save_hlo: bool = False, overrides: dict | None = None):
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    opt_cfg = AdamWConfig(state_dtype=cfg.opt_state_dtype)

    p_sh = sh.to_shardings(sh.param_pspecs(cfg, mesh), mesh)
    o_sh = sh.to_shardings(sh.opt_pspecs(cfg, mesh), mesh)
    b_sh = sh.to_shardings(sh.batch_pspecs(cfg, shape, mesh), mesh)
    params_spec, opt_spec = train_state_specs(cfg, opt_cfg)
    repl = jax.sharding.NamedSharding(mesh, P())

    with mesh, sh.activation_mesh(mesh):
        ba = sh.batch_axes(mesh)
        bsz = int(np.prod([mesh.shape[a] for a in ba]))
        if shape.global_batch % bsz == 0:
            baxis = ba
        elif shape.global_batch % mesh.shape["data"] == 0:
            baxis = ("data",)
        else:
            baxis = None
        if shape.kind == "train":
            step = make_train_step(cfg, opt_cfg)
            fn = jax.jit(step,
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, repl),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_spec, opt_spec, input_specs(cfg, shape))
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, max_len=shape.seq_len)
            if cfg.causal:
                c_sh = sh.to_shardings(sh.cache_pspecs(cfg, shape, mesh), mesh)
                logit_sh = sh.to_shardings(P(baxis, None), mesh)
                out_sh = (logit_sh, c_sh)
            else:  # encoder-only: all-position logits, no cache
                logit_sh = sh.to_shardings(P(baxis, None, None), mesh)
                out_sh = (logit_sh, None)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=out_sh)
            lowered = fn.lower(params_spec, input_specs(cfg, shape))
        else:  # decode
            step = make_decode_step(cfg)
            c_sh = sh.to_shardings(sh.cache_pspecs(cfg, shape, mesh), mesh)
            ins = input_specs(cfg, shape)
            tok_spec = P(baxis, None) if cfg.frontend != "none" else P(baxis)
            tok_sh = sh.to_shardings(tok_spec, mesh)
            logit_sh = sh.to_shardings(P(baxis, None), mesh)
            fn = jax.jit(step,
                         in_shardings=(p_sh, tok_sh, c_sh, repl),
                         out_shardings=(logit_sh, c_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(params_spec, ins["token"],
                               cache_specs(cfg, shape), ins["cur_pos"])
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    stats = H.analyze_hlo(hlo)    # trip-count-aware per-device accounting
    n_chips = int(np.prod(list(mesh.shape.values())))
    flops_dev = stats.flops
    bytes_dev = stats.hbm_bytes
    coll_dev = stats.total_collective_bytes
    terms = H.roofline_terms(flops_dev, bytes_dev, coll_dev)

    model_flops = _model_flops(cfg, shape)
    result = dict(
        arch=arch, shape=shape_name,
        mesh=("2x16x16" if multi_pod else "16x16"), chips=n_chips,
        compile_seconds=round(compile_s, 1),
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            peak_bytes=(getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        ),
        cost=dict(flops_per_device=flops_dev, bytes_per_device=bytes_dev,
                  bytes_per_device_unfused_ub=stats.hbm_bytes_unfused,
                  xla_cost_flops=float(cost.get("flops", 0.0)),
                  xla_cost_bytes=float(cost.get("bytes accessed", 0.0))),
        collectives=dict(bytes_by_kind=stats.collective_bytes,
                         count_by_kind=stats.collective_counts,
                         total_bytes_per_device=coll_dev),
        roofline=terms,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / max(n_chips * flops_dev, 1.0)),
        params=cfg.param_count(), active_params=cfg.active_param_count(),
    )
    if save_hlo:
        result["hlo_len"] = len(hlo)
    return result, hlo


def _model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D for train; 2*N_active*D for fwd-only."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n = cfg.active_param_count()
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    cells = []
    archs = [a for a in list_configs() if a != "lm100m"] if (args.all or not args.arch) \
        else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shapes = [s.name for s in cells_for(cfg)] if (args.all or not args.shape) \
            else [args.shape]
        for s in shapes:
            meshes = [False, True] if args.both_meshes else [args.multi_pod]
            for mp in meshes:
                cells.append((arch, s, mp))

    failures = 0
    for arch, s, mp in cells:
        tag = f"{arch}__{s}__{'2x16x16' if mp else '16x16'}"
        path = out / f"{tag}.json"
        if path.exists():
            print(f"[skip] {tag}")
            continue
        print(f"[lower+compile] {tag} ...", flush=True)
        try:
            t0 = time.time()
            result, hlo = lower_cell(arch, s, mp)
            path.write_text(json.dumps(result, indent=1))
            print(f"  ok in {time.time()-t0:.0f}s — dominant={result['roofline']['dominant']} "
                  f"compute={result['roofline']['compute_s']:.4f}s "
                  f"coll={result['roofline']['collective_s']:.4f}s", flush=True)
        except Exception as e:
            failures += 1
            (out / f"{tag}.FAILED").write_text(traceback.format_exc())
            print(f"  FAILED: {e}", flush=True)
    print(f"done: {len(cells) - failures}/{len(cells)} cells passed")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
