"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` gives the batch for a training step; for serving
it gives the request batch (prefill) or the (token, caches, pos) operands
(decode).  Dtypes are weak-type-correct and shardable.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from ..models import model as M
from ..optim.adamw import AdamWConfig, adamw_init

__all__ = ["input_specs", "train_state_specs", "cache_specs"]

Sds = jax.ShapeDtypeStruct


def _dt(name: str):
    return dict(float32=jnp.float32, bfloat16=jnp.bfloat16)[name]


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        if cfg.frontend != "none":
            tok = Sds((B, cfg.d_model), _dt(cfg.compute_dtype))
        else:
            tok = Sds((B,), jnp.int32)
        return dict(token=tok, cur_pos=Sds((), jnp.int32))
    batch: Dict[str, Any] = {}
    if cfg.frontend != "none":
        batch["embeds"] = Sds((B, S, cfg.d_model), _dt(cfg.compute_dtype))
    else:
        batch["tokens"] = Sds((B, S), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = Sds((B, S), jnp.int32)
    return batch


def train_state_specs(cfg: ArchConfig, opt_cfg: AdamWConfig) -> Tuple[Any, Any]:
    """(params, opt_state) ShapeDtypeStructs via eval_shape (no allocation)."""
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params)
    return params, opt


def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    B = shape.global_batch
    return jax.eval_shape(lambda: M.init_cache(cfg, B, shape.seq_len))
