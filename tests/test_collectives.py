"""Topology-aware collective cost model + placement guarantees."""
import numpy as np
import pytest

from repro.core import topologies as T
from repro.core.collectives import NetworkModel, network_from_topology, tpu_v5e_ici
from repro.core.placement import empirical_subset_bw, ramanujan_placement_guarantee
from repro.core.ramanujan import lps


def test_v5e_pod_model():
    net = tpu_v5e_ici(16, 16)
    assert net.n == 256 and net.radix == 4
    assert net.bisection_links == 32
    assert net.diameter == 16


def test_allreduce_monotone_in_bytes():
    net = tpu_v5e_ici()
    assert net.all_reduce(1 << 30) > net.all_reduce(1 << 20) > 0


def test_ramanujan_beats_torus_at_equal_radix_and_nodes():
    """The paper's thesis, quantified for LM collectives: an LPS-like network
    with the same number of nodes/links has a far larger certified bisection,
    so bisection-limited collectives are predicted faster."""
    torus = network_from_topology(T.torus(16, 2), vertex_transitive=True)
    g = lps(13, 5)   # 2184 nodes, radix 6 — compare *per-node* figures instead
    ram = network_from_topology(g, vertex_transitive=True)
    # normalize: compare bisection links per node
    assert ram.bisection_links / ram.n > 5 * torus.bisection_links / torus.n
    # all-to-all (MoE dispatch) is bisection-limited: Ramanujan wins per node
    b = 1 << 20
    t_torus = torus.all_to_all(b) * torus.n
    t_ram = ram.all_to_all(b) * ram.n
    assert t_ram / ram.n < t_torus / torus.n


def test_allreduce_injection_floor():
    """With a huge bisection, time approaches the injection bound."""
    net = NetworkModel("ideal", n=256, radix=4, bisection_links=1e9, diameter=1)
    b = 1 << 30
    expect = 2 * b * 255 / 256 / (4 * net.link_bw)
    assert abs(net.all_reduce(b) - expect) / expect < 0.01


def test_degrade_zero_is_exact_noop():
    net = tpu_v5e_ici(16, 16)
    assert net.degrade(0.0) is net
    assert net.degrade(0.0).all_reduce(1 << 30) == net.all_reduce(1 << 30)


@pytest.mark.parametrize("model", ["link", "node"])
def test_degrade_collective_times_monotone_in_fault_rate(model):
    net = network_from_topology(T.torus(16, 2), vertex_transitive=True)
    rates = [0.0, 0.02, 0.05, 0.1, 0.2, 0.4]
    b = 1 << 24
    for kind in ("all-reduce", "all-gather", "all-to-all"):
        times = [net.degrade(r, model=model).collective_time(kind, b)
                 for r in rates]
        assert all(t1 <= t2 + 1e-15 for t1, t2 in zip(times, times[1:])), \
            (kind, model, times)


def test_degrade_reflects_guaranteed_bisection_and_injection():
    net = tpu_v5e_ici(16, 16)
    d = net.degrade(0.25, model="link")
    assert d.bisection_links == pytest.approx(0.75 * net.bisection_links)
    assert d.effective_radix == pytest.approx(0.75 * net.radix)
    assert d.rho2 == pytest.approx(0.75 * net.rho2)
    assert d.n == net.n and d.diameter >= net.diameter
    # node faults: a cut link dies when either endpoint dies
    dn = net.degrade(0.25, model="node")
    assert dn.bisection_links == pytest.approx(0.75 ** 2 * net.bisection_links)
    assert dn.n == round(0.75 * net.n)


def test_degrade_composes_and_validates():
    net = tpu_v5e_ici()
    twice = net.degrade(0.1).degrade(0.1)
    assert twice.fault_rate == pytest.approx(1 - 0.9 * 0.9)
    assert twice.effective_radix == pytest.approx(net.radix * 0.81)
    with pytest.raises(ValueError):
        net.degrade(1.5)
    with pytest.raises(ValueError):
        net.degrade(0.1, model="gremlins")


def test_placement_guarantee_vs_torus_empirical():
    """Discrepancy floor (Ramanujan) vs measured worst-case subset cut (torus)."""
    g = lps(13, 17)              # n=1092, k=18
    alpha = 0.9
    guar = ramanujan_placement_guarantee(g.n, g.radix, alpha)
    assert guar.guaranteed_bisection_edges > 0
    emp = empirical_subset_bw(g, alpha, trials=8, seed=0)
    assert emp >= guar.guaranteed_bisection_edges * 0.9  # floor holds empirically
    # torus of comparable size has no useful floor at the same alpha: its
    # empirical subset bandwidth per node is far lower
    t = T.torus(33, 2)           # 1089 nodes
    emp_t = empirical_subset_bw(t, alpha, trials=8, seed=0)
    assert emp / g.n > 2 * emp_t / t.n
