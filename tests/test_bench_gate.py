"""Bench runner selectors + regression-gate semantics (no heavy benches run:
everything here drives argument handling and gate logic on synthetic
payloads)."""
import json

import pytest

import benchmarks.check_regression as CR
import benchmarks.run as BR


# --------------------------------------------------------------------------
# benchmarks.run --list / --only
# --------------------------------------------------------------------------

def test_run_list_names_every_bench(capsys):
    assert BR.main(["--list"]) == 0
    out = capsys.readouterr().out
    for name, (_, bench_json) in BR.BENCHES.items():
        assert name in out
        if bench_json:
            assert bench_json in out


def test_run_only_rejects_unknown_name():
    with pytest.raises(SystemExit):
        BR.main(["--only", "no_such_bench"])


def test_run_only_runs_just_the_named_bench(capsys, tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)          # BENCH outputs land in tmp
    assert BR.main(["--only", "roofline"]) == 0
    out = capsys.readouterr().out
    assert "roofline_dryrun_table" in out
    assert "table1" not in out
    assert (tmp_path / "benchmarks/out/BENCH_roofline.json").exists()


def test_every_gated_bench_json_has_a_gate():
    emitted = {j for _, j in BR.BENCHES.values() if j}
    assert emitted == set(CR.GATES)


def test_ci_bench_matrix_covers_every_gate():
    """The sharded bench-gate job always passes --only, so a gated BENCH
    file missing from every matrix entry would silently never be checked in
    CI — the union of the matrix selectors must cover GATES, and every bench
    name the matrix runs must exist."""
    import pathlib
    import re

    ci = pathlib.Path(__file__).parents[1] / ".github/workflows/ci.yml"
    text = ci.read_text()
    gated = set(re.findall(r"--only (BENCH_\w+\.json)", text))
    assert gated == set(CR.GATES)
    run_names = set(re.findall(r"--only (\w+)(?=[\s\"])", text)) - gated
    assert {n for n in run_names if not n.startswith("BENCH_")} <= \
        set(BR.BENCHES)


def test_collective_model_equal_radix_invariant(tmp_path, monkeypatch):
    """The gated boolean compares matched-radix pairs on unrounded seconds
    (radix-4 ramanujan vs the 2D torus, radix-6 vs the 3D torus)."""
    import benchmarks.collective_model as CM

    monkeypatch.chdir(tmp_path)
    rows = CM.run()
    payload = json.loads(
        (tmp_path / "benchmarks/out/BENCH_collective_model.json").read_text())
    assert payload["correctness"]["ramanujan_never_slower_than_torus"] is True
    nets = {r["network"] for r in rows}
    assert {"torus(16x16)", "ramanujan(k=4)", "torus(8x8x4)3d",
            "ramanujan(k=6)"} <= nets


# --------------------------------------------------------------------------
# check_regression gate logic
# --------------------------------------------------------------------------

def _payload(total=2.0, cal=0.1, cases=3, ok=True):
    return dict(bench="table1_survey", total_seconds=total,
                calibration_seconds=cal, cases=cases,
                all_rho2_bounds_hold=ok)


def _write(tmp_path, name, baseline, current):
    (tmp_path / "baselines").mkdir(exist_ok=True)
    (tmp_path / "out").mkdir(exist_ok=True)
    (tmp_path / "baselines" / name).write_text(json.dumps(baseline))
    (tmp_path / "out" / name).write_text(json.dumps(current))


def _gate(tmp_path, *extra):
    return CR.main(["--baseline-dir", str(tmp_path / "baselines"),
                    "--out-dir", str(tmp_path / "out"),
                    "--only", "BENCH_survey.json", *extra])


def test_gate_passes_on_identical_payloads(tmp_path):
    _write(tmp_path, "BENCH_survey.json", _payload(), _payload())
    assert _gate(tmp_path) == 0


def test_gate_fails_on_correctness_drift(tmp_path):
    _write(tmp_path, "BENCH_survey.json", _payload(ok=True),
           _payload(ok=False))
    assert _gate(tmp_path) == 1


def test_gate_fails_on_injected_slowdown(tmp_path):
    _write(tmp_path, "BENCH_survey.json", _payload(), _payload())
    assert _gate(tmp_path, "--simulate-slowdown", "1.5") == 1


def test_gate_skips_sub_floor_timings(tmp_path):
    """A 10x 'regression' on a 5ms bench is scheduler noise, not a verdict."""
    _write(tmp_path, "BENCH_survey.json", _payload(total=0.005),
           _payload(total=0.05))
    assert _gate(tmp_path) == 0


def test_gate_catches_sub_floor_bench_climbing_past_the_floor(tmp_path):
    """The floor is a noise filter, not an exemption: a 5ms bench that now
    takes 5s must still fail the ratio gate."""
    _write(tmp_path, "BENCH_survey.json", _payload(total=0.005),
           _payload(total=5.0))
    assert _gate(tmp_path) == 1


def test_gate_only_rejects_unknown_bench_file(tmp_path):
    with pytest.raises(SystemExit):
        CR.main(["--only", "BENCH_nope.json"])


def _sim_payload(ring_ok=True, rank_ok=True):
    return dict(bench="collective_sim", total_seconds=30.0,
                calibration_seconds=0.1, payload_bytes=2.0 ** 26,
                families=["a", "b"],
                correctness=dict(cases=2, ring_time_geq_model_lb=ring_ok,
                                 thpt_rank_matches_spectral=rank_ok,
                                 workload_matches_static_ecmp=True))


def test_required_true_fails_even_when_baseline_agrees(tmp_path):
    """The acceptance invariants are gated on literal truth: regenerating a
    baseline with a broken bound must NOT launder the failure."""
    _write(tmp_path, "BENCH_simulate.json", _sim_payload(ring_ok=False),
           _sim_payload(ring_ok=False))
    rc = CR.main(["--baseline-dir", str(tmp_path / "baselines"),
                  "--out-dir", str(tmp_path / "out"),
                  "--only", "BENCH_simulate.json"])
    assert rc == 1


def test_required_true_passes_when_invariants_hold(tmp_path):
    _write(tmp_path, "BENCH_simulate.json", _sim_payload(), _sim_payload())
    rc = CR.main(["--baseline-dir", str(tmp_path / "baselines"),
                  "--out-dir", str(tmp_path / "out"),
                  "--only", "BENCH_simulate.json"])
    assert rc == 0
