"""Reduction Lemma (Lemma 1) — exact replications of the paper's uses + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import reduction as red
from repro.core import spectral as S
from repro.core import topologies as T


def test_butterfly_reduces_to_multiplicity_cycle():
    """Prop 1's proof: layer orbits reduce Butterfly(k,s) to C_s with multiplicity k."""
    k, s = 3, 4
    b = T.butterfly(k, s)
    orbits = np.arange(b.n) // (k ** s)
    H = red.quotient(b, orbits)
    C = T.cycle(s).adjacency() * k
    np.testing.assert_allclose(H, C)
    # hence rho2 <= 2k - 2k cos(2 pi / s)
    rho2 = S.algebraic_connectivity(b)
    assert rho2 <= 2 * k - 2 * k * np.cos(2 * np.pi / s) + 1e-8


def test_data_vortex_reduces_to_cycle_box_looped_path():
    """Prop 2's proof: height-bit-flip orbits reduce DV(A,C) to C_A box P'_C."""
    A, C = 5, 4
    dv = T.data_vortex(A, C)
    orbits = np.arange(dv.n) // (2 ** (C - 1))
    H = red.quotient(dv, orbits)
    ref = T._cartesian_product(T.cycle(A), T.path_looped(C), "ref").adjacency()
    np.testing.assert_allclose(np.sort(np.linalg.eigvals(H).real),
                               np.sort(np.linalg.eigvalsh(ref)), atol=1e-8)


def test_slimfly_reduces_to_kqq_with_loops():
    """Prop 9's proof: +zeta-shift orbits reduce SlimFly(q) to K_{q,q} + (q-1)/2 loops."""
    q = 5
    sf = T.slimfly(q)
    orbits = np.arange(sf.n) // q   # orbit = (block, x): {s} x {x} x F_q
    H = red.quotient(sf, orbits)
    # expected: bipartite complete between the two blocks + (q-1)/2 loop weight
    expect = np.full((2 * q, 2 * q), 0.0)
    expect[:q, q:] = 1.0
    expect[q:, :q] = 1.0
    np.fill_diagonal(expect, (q - 1) / 2.0)
    np.testing.assert_allclose(H, expect)


def test_fat_tree_reduction():
    """Fig 3: level orbits of the fat tree give a weighted path quotient."""
    ft = T.fat_tree(3)
    levels = np.floor(np.log2(np.arange(ft.n) + 1)).astype(int)
    H = red.quotient(ft, levels)
    spec_h = np.linalg.eigvals(H)
    assert red.spectrum_subset(spec_h, S.adjacency_spectrum(ft))


def test_quotient_rejects_non_orbit_partition():
    g = T.path(5)  # ends and middle are NOT exchangeable under one partition
    bad = np.array([0, 1, 0, 1, 1])
    with pytest.raises(ValueError):
        red.quotient(g, bad)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2, max_value=6), st.integers(min_value=2, max_value=5))
def test_reduction_lemma_property_circulant_blowup(r, b):
    """Property: blow each vertex of a circulant into b twins; twin-orbits are
    automorphism orbits, and spec(quotient) ⊆ spec(G)."""
    n = 2 * r + 1
    base = T.cycle(n)
    # blow up: replace vertex v by b copies; edges become complete bipartite
    edges = []
    for (u, v) in base.edges:
        for i in range(b):
            for j in range(b):
                edges.append((u * b + i, v * b + j))
    g = T.Topology("blowup", n * b, np.array(edges))
    orbits = np.arange(n * b) // b
    H = red.quotient(g, orbits)
    assert red.spectrum_subset(np.linalg.eigvals(H), S.adjacency_spectrum(g))


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=3, max_value=8), st.integers(min_value=1, max_value=3))
def test_reduction_lemma_property_torus_rings(k, d_sel):
    """Orbits = rings of a 2-torus under rotation in one axis."""
    t = T.torus(k, 2)
    orbits = np.arange(t.n) // k
    H = red.quotient(t, orbits)
    assert red.spectrum_subset(np.linalg.eigvals(H), S.adjacency_spectrum(t))
