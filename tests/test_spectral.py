"""Spectral solver correctness: dense relations + JAX Lanczos vs dense oracle."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import spectral as S
from repro.core import topologies as T
from repro.core.ramanujan import lps


def test_regular_spectral_relations():
    """For k-regular G: rho2 = k*mu2 = k - lambda2 (paper §2)."""
    g = T.torus(5, 2)
    k = g.radix
    lam = np.sort(S.adjacency_spectrum(g))
    rho = np.sort(S.laplacian_spectrum(g))
    mu = np.sort(S.normalized_laplacian_spectrum(g))
    assert abs(rho[1] - (k - lam[-2])) < 1e-8
    assert abs(rho[1] - k * mu[1]) < 1e-8


def test_spectral_gap_positive_connected():
    g = T.hypercube(4)
    assert S.spectral_gap(g) > 0


@pytest.mark.parametrize("topo_fn", [
    lambda: T.hypercube(6),
    lambda: T.torus(6, 2),
    lambda: T.slimfly(5),
    lambda: T.random_regular(128, 6, seed=3),
])
def test_lanczos_matches_dense(topo_fn):
    g = topo_fn()
    dense = float(S.laplacian_spectrum(g)[1])
    lz = S.rho2_lanczos(g, iters=100)
    assert abs(dense - lz) < 1e-3 * max(1.0, dense)


def test_lanczos_extremes_on_known_operator():
    """Deflated Lanczos on the cycle: lambda2 = 2cos(2pi/n), lambda_min = -2 (n even)."""
    n = 64
    g = T.cycle(n)
    mv = S.table_matvec(g.neighbor_table())
    lmax, lmin = S.lanczos_extremes(mv, n, m=n, deflate_vectors=[np.ones(n)])
    assert abs(lmax - 2 * np.cos(2 * np.pi / n)) < 1e-4
    assert abs(lmin - (-2.0)) < 1e-4


def test_lanczos_bipartite_deflation():
    g = lps(13, 5)  # bipartite PGL case
    assert g.meta["bipartite"]
    rho2_dense = float(S.laplacian_spectrum(g)[1])
    rho2_lz = S.rho2_lanczos(g, iters=120)
    assert abs(rho2_dense - rho2_lz) < 1e-3


def test_fiedler_vector_orthogonal_to_ones():
    g = T.torus(4, 2)
    f = S.fiedler_vector(g)
    assert abs(f.sum()) < 1e-8


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=3, max_value=10))
def test_cycle_spectrum_property(n):
    s = np.sort(S.adjacency_spectrum(T.cycle(n)))
    expect = np.sort([2 * np.cos(2 * np.pi * j / n) for j in range(n)])
    np.testing.assert_allclose(s, expect, atol=1e-8)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=8, max_value=64).filter(lambda n: n % 2 == 0),
       st.integers(min_value=3, max_value=5))
def test_lanczos_random_regular_property(n, k):
    """Lanczos rho2 agrees with dense on random regular graphs."""
    if k >= n:
        return
    g = T.random_regular(n, k, seed=n * 7 + k)
    import networkx as nx
    if not nx.is_connected(g.to_networkx()):
        return
    dense = float(S.laplacian_spectrum(g)[1])
    lz = S.rho2_lanczos(g, iters=min(n, 80))
    assert abs(dense - lz) < 5e-3 * max(1.0, dense)
