"""Non-minimal & adaptive routing schemes: Valiant/UGAL/ksp closed forms,
the MCF throughput ceiling, spmv-backend invariance of the canonical
adversarial demand, and the sampled-estimator bias fixes."""
import numpy as np
import pytest

from repro.core import topologies as T
from repro.core.ramanujan import lps
from repro.core.routing import analyze_routing, reverse_slot_index
from repro.core.synthesis import xpander
from repro.core.spectral import canonical_fiedler
from repro.core.traffic import (ROUTING_SCHEMES, demand_matrix,
                                evaluate_traffic, ksp_link_loads,
                                mcf_throughput_ub, scheme_link_loads)

HAVE_SCIPY = True
try:                                    # mirrors the traffic-module guard
    import scipy  # noqa: F401
except ImportError:                     # pragma: no cover - scipy-less CI
    HAVE_SCIPY = False

needs_scipy = pytest.mark.skipif(
    not HAVE_SCIPY, reason="scipy not installed: MCF LP ceiling unavailable")


def _uniform_served(g, routing):
    D = demand_matrix("uniform", g.n)
    return np.where(routing.dist >= 0, D, 0.0)


# --------------------------------------------------------------------------
# Valiant closed forms
# --------------------------------------------------------------------------

def test_valiant_complete_graph_closed_form():
    """K_n: every link carries exactly 2/n under uniform Valiant (one
    leg in, one leg out through every intermediate), so saturation
    throughput is n/2 — below minimal ECMP's (n-1)/2... Valiant pays its
    2x tax even where it buys nothing."""
    n = 12
    g = T.complete(n)
    r = analyze_routing(g)
    t = evaluate_traffic(g, "uniform", scheme="valiant", routing=r)
    live = g.gather_operands()[0] >= 0
    np.testing.assert_allclose(t.link_loads[live], 2.0 / n, rtol=1e-5)
    assert t.saturation_throughput == pytest.approx(n / 2.0, rel=1e-5)


def test_valiant_cycle_loads_all_equal():
    """C_n is edge-transitive: uniform Valiant load is identical on every
    directed link."""
    g = T.cycle(10)
    t = evaluate_traffic(g, "uniform", scheme="valiant")
    table = g.gather_operands()[0]
    lv = t.link_loads[table >= 0]
    np.testing.assert_allclose(lv, lv[0], rtol=1e-5)


# --------------------------------------------------------------------------
# UGAL
# --------------------------------------------------------------------------

def test_ugal_reduces_to_minimal_under_uniform():
    """Uniform traffic spreads minimal load evenly, so UGAL's load
    comparison keeps every pair minimal and the loads are bit-identical
    to minimal ECMP."""
    for g in (T.hypercube(4), T.petersen(), T.slimfly(5)):
        r = analyze_routing(g)
        t_min = evaluate_traffic(g, "uniform", scheme="minimal", routing=r)
        t_ugal = evaluate_traffic(g, "uniform", scheme="ugal", routing=r)
        np.testing.assert_array_equal(t_min.link_loads, t_ugal.link_loads)
        assert t_min.saturation_throughput == t_ugal.saturation_throughput


def test_nonminimal_adversarial_no_worse_than_minimal_on_expanders():
    """The acceptance invariant at test scale: non-minimal routing recovers
    adversarial throughput on expander families.  (UGAL needs enough scale
    for its load estimate to pay off — lps(5,13) at n=120 is below that, so
    its UGAL leg is only asserted at bench scale on lps(13,5).)"""
    for g, check_ugal in ((lps(5, 13), False), (T.slimfly(5), True),
                          (xpander(64, 6, 0, 0), True)):
        r = analyze_routing(g)
        fiedler = canonical_fiedler(g)
        kw = dict(routing=r, fiedler=fiedler)
        t_min = evaluate_traffic(g, "adversarial", scheme="minimal", **kw)
        t_val = evaluate_traffic(g, "adversarial", scheme="valiant", **kw)
        assert t_val.saturation_throughput >= \
            t_min.saturation_throughput - 1e-9
        if check_ugal:
            t_ugal = evaluate_traffic(g, "adversarial", scheme="ugal", **kw)
            assert t_ugal.saturation_throughput >= \
                t_min.saturation_throughput - 1e-9


# --------------------------------------------------------------------------
# k-shortest-path ECMP
# --------------------------------------------------------------------------

@pytest.mark.parametrize("build", [T.petersen, lambda: T.hypercube(4),
                                   lambda: T.slimfly(5)],
                         ids=["petersen", "hypercube4", "slimfly5"])
@pytest.mark.parametrize("pattern", ["uniform", "bit_complement"])
def test_ksp_slack_zero_is_minimal(build, pattern):
    """slack=0 admits exactly the shortest paths with walk-count weights =
    ECMP's path-count weights (every shortest walk is a path)."""
    g = build()
    r = analyze_routing(g)
    t_min = evaluate_traffic(g, pattern, scheme="minimal", routing=r)
    t_ksp = evaluate_traffic(g, pattern, scheme="ksp", slack=0, routing=r)
    # minimal accumulates in f32, ksp in f64: equal to f32 roundoff
    np.testing.assert_allclose(t_min.link_loads, t_ksp.link_loads,
                               rtol=1e-5, atol=1e-6)
    assert t_min.saturation_throughput == pytest.approx(
        t_ksp.saturation_throughput, rel=1e-5)


def test_ksp_conserves_demand_and_spreads_load():
    """slack=1 serves the full demand (conservation) and cannot raise the
    peak load above minimal by more than the extra hops admit."""
    g = T.petersen()
    r = analyze_routing(g)
    t = evaluate_traffic(g, "adversarial", scheme="ksp", slack=1, routing=r,
                         fiedler=canonical_fiedler(g))
    assert t.conservation_error < 1e-6
    # detours can only lengthen the demand-weighted mean path
    t_min = evaluate_traffic(g, "adversarial", scheme="minimal", routing=r,
                             fiedler=canonical_fiedler(g))
    assert t.avg_hops >= t_min.avg_hops - 1e-9
    assert t.saturation_throughput > 0


def test_ksp_rejects_negative_slack():
    g = T.petersen()
    r = analyze_routing(g)
    served = _uniform_served(g, r)
    with pytest.raises(ValueError):
        ksp_link_loads(g.gather_operands()[0], r, served, slack=-1)


# --------------------------------------------------------------------------
# MCF throughput ceiling
# --------------------------------------------------------------------------

@needs_scipy
def test_mcf_complete_graph_exact():
    """K_n uniform: direct single-hop routing saturates every link at
    1/(n-1) per unit injection, so theta* = n-1 exactly."""
    n = 12
    ub = mcf_throughput_ub(T.complete(n))
    assert ub == pytest.approx(n - 1, rel=1e-6)


@needs_scipy
@pytest.mark.parametrize("build", [
    T.petersen, lambda: T.hypercube(4), lambda: T.cycle(10),
    lambda: T.torus(4, 2), lambda: T.slimfly(5),
    lambda: T.cube_connected_cycles(3), lambda: T.butterfly(2, 3),
    lambda: T.random_regular(48, 4, seed=0),
], ids=["petersen", "hypercube4", "cycle10", "torus4x2", "slimfly5",
        "ccc3", "butterfly2x3", "rr48"])
@pytest.mark.parametrize("pattern", ["uniform", "adversarial"])
def test_mcf_ub_dominates_every_scheme(build, pattern):
    """No routing scheme may beat the optimal-routing LP ceiling."""
    g = build()
    r = analyze_routing(g)
    fiedler = canonical_fiedler(g) if pattern == "adversarial" else None
    ub = mcf_throughput_ub(g, pattern, fiedler=fiedler)
    assert np.isfinite(ub) and ub > 0
    for scheme in ROUTING_SCHEMES:
        t = evaluate_traffic(g, pattern, scheme=scheme, routing=r,
                             fiedler=fiedler)
        assert t.saturation_throughput <= ub * (1 + 1e-6) + 1e-9, \
            (scheme, t.saturation_throughput, ub)


@needs_scipy
def test_mcf_grouping_only_loosens():
    """Merging commodities relaxes the LP: fewer groups => UB no smaller."""
    g = T.petersen()
    fine = mcf_throughput_ub(g, groups=g.n)
    coarse = mcf_throughput_ub(g, groups=2)
    assert coarse >= fine - 1e-9


def test_mcf_raises_without_scipy(monkeypatch):
    from repro.core import traffic as TR

    monkeypatch.setattr(TR, "_scipy_linprog", None)
    with pytest.raises(RuntimeError, match="scipy"):
        TR.mcf_throughput_ub(T.petersen())


# --------------------------------------------------------------------------
# backend invariance of the canonical adversarial demand (the PR-8 bugfix)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("build", [lambda: T.butterfly(2, 3),
                                   lambda: T.hypercube(4)],
                         ids=["butterfly", "hypercube"])
def test_adversarial_demand_backend_invariant(build):
    """Degenerate Fiedler eigenspaces (butterfly, hypercube) must yield the
    SAME canonical vector — hence bit-identical demand matrices and
    throughputs — whatever spmv backend or eigensolver produced rho2."""
    g = build()
    f = canonical_fiedler(g)
    D = demand_matrix("adversarial", g.n, fiedler=f)
    results = {}
    for backend in ("ref", "pallas_interpret"):
        r = analyze_routing(g, backend=backend)
        D_b = demand_matrix("adversarial", g.n, fiedler=canonical_fiedler(g))
        np.testing.assert_array_equal(D, D_b)
        t = evaluate_traffic(g, "adversarial", routing=r, fiedler=f,
                             backend=backend)
        results[backend] = t.saturation_throughput
    assert results["ref"] == results["pallas_interpret"]


def test_canonical_fiedler_matches_lanczos_path():
    """Dense recompute and the Lanczos-vector entry point agree (dense
    canonicalization ignores the provided vector below the threshold)."""
    from repro.core.spectral import fiedler_lanczos

    g = T.butterfly(2, 3)
    dense = canonical_fiedler(g)
    via_lanczos = canonical_fiedler(g, fiedler_lanczos(g, iters=120, seed=0))
    np.testing.assert_array_equal(dense, via_lanczos)


# --------------------------------------------------------------------------
# sampled-source parity and the UCB fix
# --------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", list(ROUTING_SCHEMES))
def test_sampled_fraction_one_matches_exact(scheme):
    """sample_fraction=1.0 must reproduce the exact evaluation bitwise for
    every scheme (the degenerate-limit contract of the scale subsystem)."""
    g = T.slimfly(5)
    r_exact = analyze_routing(g)
    r_full = analyze_routing(g, sample_fraction=1.0, seed=0)
    t_exact = evaluate_traffic(g, "uniform", scheme=scheme, routing=r_exact)
    t_full = evaluate_traffic(g, "uniform", scheme=scheme, routing=r_full)
    np.testing.assert_array_equal(t_exact.link_loads, t_full.link_loads)
    assert t_exact.saturation_throughput == t_full.saturation_throughput


def test_sampled_ucb_bounds_point_estimate():
    """The bootstrap UCB is never below the sampled point estimate, and the
    sampled saturation throughput is computed from the UCB (conservative),
    so it never exceeds the point-estimate throughput."""
    g = T.random_regular(128, 4, seed=1)
    r = analyze_routing(g, sample_fraction=0.25, seed=3)
    t = evaluate_traffic(g, "uniform", routing=r)
    assert not t.exact
    assert t.max_link_load_ucb >= t.max_link_load - 1e-12
    assert t.saturation_throughput == pytest.approx(
        1.0 / t.max_link_load_ucb)


def test_sampled_ucb_covers_true_max():
    """On a healthy sample the 95% UCB should cover the exact max link
    load (checked across seeds; statistically near-certain margin)."""
    g = T.random_regular(128, 4, seed=1)
    exact = evaluate_traffic(g, "uniform", routing=analyze_routing(g))
    covered = 0
    for seed in range(5):
        r = analyze_routing(g, sample_fraction=0.3, seed=seed)
        t = evaluate_traffic(g, "uniform", routing=r)
        covered += t.max_link_load_ucb >= exact.max_link_load
    assert covered >= 4


def test_exact_run_has_ucb_equal_max():
    g = T.petersen()
    t = evaluate_traffic(g, "uniform", routing=analyze_routing(g))
    assert t.max_link_load_ucb == t.max_link_load


# --------------------------------------------------------------------------
# reverse_slot_index
# --------------------------------------------------------------------------

def test_reverse_slot_index_involutive():
    for g in (T.petersen(), T.hypercube(4), T.cycle(3), T.slimfly(5)):
        table = g.gather_operands()[0]
        rev = reverse_slot_index(table)
        u, j = np.where(table >= 0)
        v = table[u, j]
        # (u --slot j--> v) reversed points back at u ...
        assert np.array_equal(table[v, rev[u, j]], u)
        # ... through the partner slot (involution), pads self-mapping
        assert np.array_equal(rev[v, rev[u, j]], j)
        pu, pj = np.where(table < 0)
        assert np.array_equal(rev[pu, pj], pj)


def test_reverse_slot_index_rejects_asymmetric():
    table = T.petersen().gather_operands()[0].copy()
    table[0, 0] = 5 if table[0, 0] != 5 else 6   # break symmetry
    with pytest.raises(ValueError):
        reverse_slot_index(table)


# --------------------------------------------------------------------------
# scheme wiring: dispatcher, simulator, survey
# --------------------------------------------------------------------------

def test_scheme_link_loads_rejects_unknown():
    g = T.petersen()
    r = analyze_routing(g)
    with pytest.raises(ValueError, match="scheme"):
        scheme_link_loads(g.gather_operands()[0], r,
                          _uniform_served(g, r), "compass")


def test_simulator_rides_nonminimal_paths():
    """simulate_traffic(scheme=) must agree with the static traffic layer's
    saturation throughput for every scheme."""
    from repro.core.simulate import simulate_traffic

    g = T.hypercube(4)
    r = analyze_routing(g)
    for scheme in ROUTING_SCHEMES:
        sim = simulate_traffic(g, "uniform", payloads=1 << 20, routing=r,
                               scheme=scheme)
        static = evaluate_traffic(g, "uniform", scheme=scheme, routing=r)
        assert sim.saturation_throughput == pytest.approx(
            static.saturation_throughput, rel=2e-5)


def test_analysis_traffic_scheme_cache_keys():
    from repro.api import Analysis

    a = Analysis("petersen")
    t1 = a.traffic("uniform")
    t2 = a.traffic("uniform", scheme="valiant")
    t3 = a.traffic("uniform", scheme="ksp", slack=2)
    assert t1 is a.traffic("uniform")
    assert t2 is not t1 and t3 is not t2
    assert t2.scheme == "valiant" and t3.scheme == "ksp"


@needs_scipy
def test_survey_scheme_columns():
    from repro.api.survey import ROUTING_COLUMNS, survey

    res = survey(["petersen"], routing=dict(pattern="adversarial",
                                            schemes=True))
    row = res.rows[0]
    for col in ("thpt_valiant", "thpt_ugal", "thpt_ksp", "thpt_mcf_ub",
                "thpt_gap_to_opt"):
        assert col in ROUTING_COLUMNS
        assert row[col] is not None
    assert 0 < row["thpt_gap_to_opt"] <= 1 + 1e-6


def test_survey_without_schemes_leaves_columns_none():
    from repro.api.survey import survey

    row = survey(["petersen"], routing=True).rows[0]
    assert row["thpt_valiant"] is None and row["thpt_mcf_ub"] is None
