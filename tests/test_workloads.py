"""Closed-form pins of the workload-lowering pass (repro.core.workloads).

Every byte count in a :class:`CommPlan` is closed-form, so these tests pin
them against independently computed figures from the model configs and the
sharding rules: DP all-reduce bytes equal parameter bytes at ``tp=1``, TP
collective ops move exactly ``tokens_per_rank * d_model`` activations, and
the MoE all-to-all demand matrix carries the padded-slot-tensor invariant
(row sums = ``bytes_per_rank * (ep-1)/ep``).  Parsing round-trips, the HLO
byte audit, placement strategies, and the Analysis/survey/fault_sweep wiring
are covered alongside.
"""
import numpy as np
import pytest

from repro.api import Analysis, WORKLOAD_COLUMNS, build, survey
from repro.configs.base import SHAPES, get_config
from repro.core import workloads as W
from repro.core.placement import place_ranks
from repro.models.moe import capacity

DENSE = "qwen2_7b"          # prefix of qwen2-7b (dense, 28 attn+mlp layers)
MOE = "grok_1_314b"         # prefix of grok-1-314b (8 experts, all-MoE)


# --------------------------------------------------------------------------
# spec parsing
# --------------------------------------------------------------------------

def test_parse_resolves_prefix_and_round_trips():
    ws = W.parse_workload(f"{MOE}@dp=8,tp=2,ep=4")
    assert ws.arch == "grok-1-314b"          # unique-prefix resolution
    assert (ws.dp, ws.tp, ws.ep) == (8, 2, 4)
    assert ws.world == 16
    assert W.parse_workload(ws.spec) == ws   # canonical string round-trips
    # passing a WorkloadSpec through is the identity
    assert W.parse_workload(ws) is ws


def test_parse_defaults_and_shape_key():
    ws = W.parse_workload(DENSE)
    assert (ws.dp, ws.tp, ws.ep, ws.shape) == (1, 1, 1, "train_4k")
    assert "shape=" not in ws.spec           # default shape omitted
    ws2 = W.parse_workload(f"{DENSE}@dp=2,shape=train_4k")
    assert ws2.shape == "train_4k"


@pytest.mark.parametrize("bad", [
    "no_such_model@dp=2",                    # unknown model
    "qwen2@dp=2",                            # ambiguous: qwen2-7b / qwen2-vl-7b
    f"{DENSE}@zz=3",                         # unknown key
    f"{DENSE}@dp",                           # missing =value
    f"{DENSE}@dp=x",                         # non-integer
    f"{DENSE}@dp=0",                         # < 1
    f"{DENSE}@dp=7",                         # 7 does not divide global_batch 256
    f"{DENSE}@dp=4,ep=2",                    # dense arch cannot take ep > 1
    f"{MOE}@dp=4,ep=8",                      # ep must divide dp
    f"{MOE}@dp=6,ep=3",                      # ep must divide n_experts (8)
    f"{DENSE}@shape=decode_32k",             # non-train shape
    f"{DENSE}@shape=nope",                   # unknown shape
])
def test_parse_rejects_invalid_specs(bad):
    with pytest.raises(W.WorkloadSpecError):
        W.parse_workload(bad)
    # WorkloadSpecError is a ValueError, so generic handlers still catch it
    with pytest.raises(ValueError):
        W.parse_workload(bad)


# --------------------------------------------------------------------------
# closed-form byte pins
# --------------------------------------------------------------------------

def test_dp_allreduce_bytes_equal_param_bytes_at_tp1():
    """With no tensor parallelism every gradient element is all-reduced, so
    the DP phase total must equal the parameter bytes exactly — and both must
    match the analytic ``param_count`` at the param dtype width."""
    plan = W.plan_workload(f"{DENSE}@dp=8")
    cfg = get_config(plan.spec.arch)
    assert plan.param_bytes == cfg.param_count() * 2          # bf16 params
    assert plan.grad_bytes_per_rank == pytest.approx(plan.param_bytes)
    ar = plan.phase("dp_allreduce")
    assert ar.total_bytes == pytest.approx(plan.grad_bytes_per_rank)
    assert ar.ops_per_step == int(np.ceil(plan.param_bytes / W.BUCKET_BYTES))
    assert ar.bytes_per_rank <= W.BUCKET_BYTES


def test_tp_shard_factor_shrinks_dp_bytes():
    """tp=2 halves every 'model'-sharded gradient; the DP total must drop
    strictly below the parameter bytes but stay above bytes/tp (norms and
    the router stay replicated)."""
    p1 = W.plan_workload(f"{DENSE}@dp=8")
    p2 = W.plan_workload(f"{DENSE}@dp=8,tp=2")
    assert p2.grad_bytes_per_rank < p1.grad_bytes_per_rank
    assert p2.grad_bytes_per_rank > p1.grad_bytes_per_rank / 2


def test_tp_phase_moves_full_activation_per_op():
    plan = W.plan_workload(f"{DENSE}@dp=4,tp=2")
    cfg = get_config(plan.spec.arch)
    shape = SHAPES["train_4k"]
    tokens_rank = shape.global_batch * shape.seq_len // 4
    assert plan.tokens_per_rank == tokens_rank
    ag = plan.phase("tp_allgather")
    rs = plan.phase("tp_reducescatter")
    # each op carries the full tokens x d_model activation in compute dtype
    assert ag.bytes_per_rank == tokens_rank * cfg.d_model * 2
    assert rs.bytes_per_rank == ag.bytes_per_rank
    # attn (wq/wo) + dense mlp (wg/wd) = 2 sharded pairs per layer, fwd+bwd
    assert ag.ops_per_step == 2 * (2 * cfg.n_layers)
    assert rs.ops_per_step == ag.ops_per_step


def test_moe_phase_matches_padded_slot_tensor():
    plan = W.plan_workload(f"{MOE}@dp=8,ep=4")
    cfg = get_config(plan.spec.arch)
    shape = SHAPES["train_4k"]
    E, k = cfg.n_experts, cfg.experts_per_token
    C = capacity(shape.seq_len, E, k, cfg.capacity_factor)
    groups_per_rank = shape.global_batch // 8
    slot_elems = groups_per_rank * E * C * cfg.d_model
    disp = plan.phase("moe_dispatch")
    comb = plan.phase("moe_combine")
    disp_width = W._DTYPE_BYTES[cfg.moe_dispatch_dtype]
    assert disp.bytes_per_rank == slot_elems * disp_width
    assert comb.bytes_per_rank == slot_elems * 2      # bf16 return legs
    moe_layers = sum(1 for s in cfg.pattern if s.moe) * cfg.n_repeats
    assert disp.ops_per_step == moe_layers
    assert comb.ops_per_step == 3 * moe_layers        # fwd return + 2 bwd legs


def test_dense_plan_has_no_moe_phase_and_dp1_no_allreduce():
    plan = W.plan_workload(f"{DENSE}@tp=2")
    names = [p.name for p in plan.phases]
    assert "dp_allreduce" not in names                # dp=1: nothing to reduce
    assert "moe_dispatch" not in names
    with pytest.raises(KeyError):
        plan.phase("moe_dispatch")


# --------------------------------------------------------------------------
# logical demand invariants
# --------------------------------------------------------------------------

def test_all_to_all_demand_row_sums_are_routed_fraction():
    """Each rank keeps 1/ep of its slot tensor local; the off-diagonal demand
    row must sum to exactly bytes_per_rank * (ep-1)/ep."""
    plan = W.plan_workload(f"{MOE}@dp=8,ep=4")
    phase = plan.phase("moe_dispatch")
    groups = W._phase_groups(plan, "ep")
    assert len(groups) == 2                           # (dp/ep) * tp groups
    node_of = np.arange(plan.world)                   # identity placement
    D, rounds = W._phase_demand(phase, groups, node_of, plan.world)
    want = phase.bytes_per_rank * (4 - 1) / 4
    np.testing.assert_allclose(D.sum(axis=1), want, rtol=1e-12)
    assert rounds == phase.ops_per_step               # a2a: one round per op


def test_ring_demand_rounds_and_per_edge_payload():
    plan = W.plan_workload(f"{DENSE}@dp=4,tp=2")
    ar = plan.phase("dp_allreduce")
    groups = W._phase_groups(plan, "dp")
    node_of = np.arange(plan.world)
    D, rounds = W._phase_demand(ar, groups, node_of, plan.world)
    # ring all-reduce: 2(g-1) rounds of 1/g payload along each group edge
    assert rounds == 2 * (4 - 1) * ar.ops_per_step
    np.testing.assert_allclose(D.sum(axis=1), ar.bytes_per_rank / 4,
                               rtol=1e-12)
    # DP groups stride by tp, so rank r talks to r +- tp, never r +- 1
    assert D[0, 1] == 0.0 and D[0, 2] > 0.0


def test_colocated_ranks_communicate_for_free():
    """Oversubscription folds whole TP groups onto one node under linear
    placement; their demand lands on the (zeroed) diagonal."""
    plan = W.plan_workload(f"{DENSE}@dp=4,tp=2")     # world 8
    node_of = place_ranks(4, plan.world, strategy="linear")   # 2 ranks/node
    tp = plan.phase("tp_allgather")
    D, _ = W._phase_demand(tp, W._phase_groups(plan, "tp"), node_of, 4)
    assert D.sum() == 0.0                             # every TP pair co-located


# --------------------------------------------------------------------------
# HLO byte audit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [f"{DENSE}@dp=8,tp=2", f"{MOE}@dp=8,ep=4"])
def test_hlo_crosscheck_agrees(spec):
    check = W.hlo_crosscheck(spec)
    assert check["ok"], check
    kinds = check["kinds"]
    plan = W.plan_workload(spec)
    assert set(kinds) == set(plan.collective_byte_totals())
    for row in kinds.values():
        assert row["plan_bytes"] > 0


# --------------------------------------------------------------------------
# rank placement
# --------------------------------------------------------------------------

def test_place_ranks_strategies_and_balance():
    n, world = 8, 20
    for strategy in ("linear", "round_robin", "random"):
        nodes = place_ranks(n, world, strategy=strategy, seed=3)
        assert nodes.shape == (world,)
        loads = np.bincount(nodes, minlength=n)
        assert loads.max() - loads.min() <= 1         # balanced
    assert np.array_equal(place_ranks(n, world, strategy="round_robin"),
                          np.arange(world) % n)
    # random is a seeded relabeling: deterministic per seed, differs by seed
    r0 = place_ranks(n, world, strategy="random", seed=0)
    assert np.array_equal(r0, place_ranks(n, world, strategy="random", seed=0))
    assert any(not np.array_equal(r0, place_ranks(n, world, strategy="random",
                                                  seed=s)) for s in (1, 2, 3))


def test_place_ranks_rejects_bad_arguments():
    with pytest.raises(ValueError):
        place_ranks(0, 4)
    with pytest.raises(ValueError):
        place_ranks(4, 0)
    with pytest.raises(ValueError):
        place_ranks(4, 8, strategy="nope")


# --------------------------------------------------------------------------
# execution + API wiring
# --------------------------------------------------------------------------

def test_simulate_workload_composition():
    topo = build("hypercube(3)")                      # n = 8 = world
    res = W.simulate_workload(topo, f"{DENSE}@dp=4,tp=2", placement="random",
                              seed=1)
    assert res.n == 8 and res.plan.world == 8
    # step composition: compute + tp + moe + exposed dp, all non-negative
    want = (res.compute_seconds + res.tp_seconds + res.moe_seconds
            + res.exposed_dp_seconds)
    assert res.step_seconds == pytest.approx(want)
    assert res.exposed_dp_seconds <= res.dp_seconds
    assert 0.0 <= res.exposed_comm_fraction < 1.0
    assert res.dropped_frac == 0.0                    # hypercube is connected
    assert set(res.phase_seconds()) == {p.name for p in res.plan.phases}
    d = res.to_dict()
    assert d["step_ms"] == pytest.approx(res.step_seconds * 1e3, rel=1e-6)
    assert "step time" in res.report() and plan_text_ok(res.plan.report())


def plan_text_ok(text: str) -> bool:
    return "workload" in text and "compute/rank" in text


def test_analysis_simulate_workload_caches():
    a = Analysis("hypercube(3)")
    r1 = a.simulate(workload=f"{DENSE}@dp=4,tp=2", placement="linear")
    r2 = a.simulate(workload=f"{DENSE}@dp=4,tp=2", placement="linear")
    assert r1 is r2                                   # memoized per (spec, ...)
    r3 = a.simulate(workload=f"{DENSE}@dp=4,tp=2", placement="round_robin")
    assert r3 is not r1


def test_survey_appends_workload_columns():
    sr = survey(["hypercube(3)"], columns=["spec", "nodes", "rho2"],
                workload=f"{DENSE}@dp=4,tp=2")
    row = sr.rows[0]
    for col in WORKLOAD_COLUMNS:
        assert col in row, col
    assert row["workload"] == W.parse_workload(f"{DENSE}@dp=4,tp=2").spec
    assert row["step_time_ms"] > row["compute_ms"] > 0
    assert row["comm_total_ms"] == pytest.approx(
        row["comm_dp_ms"] + row["comm_tp_ms"] + row["comm_moe_ms"], rel=1e-6)


def test_fault_sweep_appends_workload_fields():
    a = Analysis("hypercube(3)")
    sweep = a.fault_sweep(rates=[0.05], samples=2,
                          workload=f"{DENSE}@dp=4,tp=2", workload_samples=1)
    row = sweep.rows[0]
    assert row["workload_step_mean"] > 0
    assert row["workload_step_max"] >= row["workload_step_mean"]
    assert 0.0 <= row["workload_dropped_frac_mean"] <= 1.0


# --------------------------------------------------------------------------
# spectral agreement statistic
# --------------------------------------------------------------------------

def test_spectral_rank_correlation_extremes_and_ties():
    perfect = [dict(rho2=r, step_ms=s) for r, s in
               [(4.0, 10.0), (3.0, 20.0), (2.0, 30.0), (1.0, 40.0)]]
    assert W.spectral_rank_correlation(perfect) == pytest.approx(1.0)
    reverse = [dict(rho2=r, step_ms=s) for r, s in
               [(4.0, 40.0), (3.0, 30.0), (2.0, 20.0), (1.0, 10.0)]]
    assert W.spectral_rank_correlation(reverse) == pytest.approx(-1.0)
    assert W.spectral_rank_correlation([dict(rho2=1.0, step_ms=1.0)]) is None
    assert W.spectral_rank_correlation(
        [dict(rho2=1.0, step_ms=None), dict(rho2=None, step_ms=2.0)]) is None
    # all-tied step times carry no ordering information
    tied = [dict(rho2=r, step_ms=5.0) for r in (3.0, 2.0, 1.0)]
    assert W.spectral_rank_correlation(tied) is None
