"""Link-level simulator: schedule compiler + round engine + API wiring.

Closed-form cross-checks on graphs where the executed schedule's time is
computable by hand, conservation invariants tying the compiler to the ECMP
routing layer, consistency of the vmapped fault-stack path with the
single-topology path, and the measured-vs-model validation contract.
"""
import numpy as np
import pytest

from repro.api import SIM_COLUMNS, Analysis, build, survey
from repro.core import faults as F
from repro.core import simulate as SM
from repro.core import topologies as T
from repro.core.collectives import (LINK_BW, PER_HOP_LATENCY,
                                    network_from_topology)

BW, LAT = LINK_BW, PER_HOP_LATENCY


def _stack(topo, degraded):
    width = max(int(np.bincount(topo.edges.reshape(-1),
                                minlength=topo.n).max()), 1)
    return F.stacked_operands(degraded, width=width)[0]


# --------------------------------------------------------------------------
# schedule compiler
# --------------------------------------------------------------------------

def test_ring_allreduce_schedule_shape():
    g = T.cycle(8)
    s = SM.compile_schedule(g, "all_reduce", "ring")
    assert s.unique_rounds == 1            # identical rounds stored once
    assert s.rounds == 2 * (g.n - 1)
    assert s.hops.tolist() == [1]          # ring successors are cycle edges
    assert s.dropped_demand == 0.0


@pytest.mark.parametrize("collective,algorithm,phases", [
    ("all_reduce", "ring", 2), ("reduce_scatter", "ring", 1),
    ("all_gather", "ring", 1)])
def test_ring_round_counts_per_collective(collective, algorithm, phases):
    g = T.torus(4, 2)
    s = SM.compile_schedule(g, collective, algorithm)
    assert s.rounds == phases * (g.n - 1)


def test_schedule_conservation_matches_ecmp():
    """Per-round link bytes must conserve flow: sum of slot loads equals the
    demand-weighted hop count (the routing/traffic invariant, now per round)."""
    g = T.petersen()
    a = Analysis(g)
    r = a.routing()
    s = SM.compile_schedule(g, "all_reduce", "ring", routing=r)
    D = SM._logical_rounds_ring(g.n, phases=1)[0][0]
    hops_weighted = float((D * np.maximum(r.dist, 0)).sum())
    assert float(s.round_bytes[0].sum()) == pytest.approx(hops_weighted,
                                                          rel=1e-5)


def test_halving_doubling_requires_power_of_two():
    with pytest.raises(ValueError, match="power-of-two"):
        SM.compile_schedule(T.cycle(6), "all_reduce", "halving_doubling")


def test_unknown_collective_and_algorithm_raise():
    g = T.cycle(4)
    with pytest.raises(ValueError, match="unknown collective"):
        SM.compile_schedule(g, "all_to_all")
    with pytest.raises(ValueError, match="unknown algorithm"):
        SM.compile_schedule(g, "all_reduce", "bruck")


def test_single_node_rejected_with_clear_error():
    with pytest.raises(ValueError, match="at least 2 nodes"):
        SM.simulate_collective(T.path(1), "all_gather", "bruck")
    with pytest.raises(ValueError, match="at least 2 nodes"):
        SM.simulate_traffic(T.path(1), "neighbor")


def test_total_sent_bytes_match_model_traffic_factors():
    """Where every transfer is a single physical hop, total link bytes equal
    the logical volume the (alpha, beta) model charges per node: all-reduce
    2B(n-1)/n, all-gather B(n-1)/n."""
    hc = T.hypercube(4)                   # halving/doubling partners adjacent
    s = SM.compile_schedule(hc, "all_reduce", "halving_doubling")
    assert s.total_link_bytes().sum() / hc.n == pytest.approx(
        2.0 * (hc.n - 1) / hc.n, rel=1e-5)
    kn = T.complete(8)                    # every Bruck partner adjacent
    s = SM.compile_schedule(kn, "all_gather", "bruck")
    assert s.total_link_bytes().sum() / kn.n == pytest.approx(
        (kn.n - 1) / kn.n, rel=1e-5)


def test_bfs_tree_broadcast_loads_only_physical_links():
    g = T.cycle(9)
    s = SM.compile_schedule(g, "broadcast", "bfs_tree")
    assert s.hops.max() == 1                      # every transfer is one hop
    assert s.unique_rounds == 4                   # depth of C9 from the root
    # round d carries full payload on each parent->child link
    assert float(s.round_bytes.max()) == pytest.approx(1.0)
    # 8 tree edges total (spanning tree of 9 vertices)
    assert float(s.total_link_bytes().sum()) == pytest.approx(8.0)


def test_broadcast_root_parameter():
    g = T.path(5)                                 # path: root matters
    s0 = SM.compile_schedule(g, "broadcast", "bfs_tree", root=0)
    s2 = SM.compile_schedule(g, "broadcast", "bfs_tree", root=2)
    assert s0.unique_rounds == 4 and s2.unique_rounds == 2


# --------------------------------------------------------------------------
# round engine: closed-form cross-checks
# --------------------------------------------------------------------------

def test_ring_allreduce_on_cycle_closed_form():
    """On C_n the ring successor IS the physical link: every round moves
    B/n on each (s, s+1) link, so t = 2(n-1) (B/(n bw) + lat)."""
    g = T.cycle(8)
    B_ = float(1 << 24)
    r = SM.simulate_collective(g, "all_reduce", "ring", payloads=B_)
    expect = 2 * 7 * (B_ / (8 * BW) + LAT)
    assert float(r.time_seconds[0]) == pytest.approx(expect, rel=1e-5)
    # every directed cycle link carries the same bytes: utilization is flat
    assert r.utilization_max == pytest.approx(r.utilization_mean, rel=1e-5)


def test_halving_doubling_on_hypercube_closed_form():
    """Hypercube partners s^2^i are physical neighbors: round i moves
    B/2^(i+1) on dimension-i links, twice (halving + doubling)."""
    d = 4
    g = T.hypercube(d)
    B_ = float(1 << 24)
    r = SM.simulate_collective(g, "all_reduce", "halving_doubling",
                               payloads=B_)
    expect = 2 * sum(B_ / (2 ** (i + 1) * BW) + LAT for i in range(d))
    assert float(r.time_seconds[0]) == pytest.approx(expect, rel=1e-5)
    assert r.rounds == 2 * d


def test_binomial_broadcast_on_complete_closed_form():
    """On K_n every binomial-tree edge is physical: ceil(log2 n) rounds of
    the full payload at one hop each."""
    g = T.complete(8)
    B_ = float(1 << 22)
    r = SM.simulate_collective(g, "broadcast", "binomial", payloads=B_)
    assert float(r.time_seconds[0]) == pytest.approx(3 * (B_ / BW + LAT),
                                                     rel=1e-5)


def test_engine_time_affine_in_payload():
    """t(B) = alpha + beta*B for a fixed schedule — one vmapped call sweeps
    the payload axis and the result is exactly affine."""
    g = T.torus(4, 2)
    pays = [float(1 << 20), float(1 << 21), float(1 << 22)]
    r = SM.simulate_collective(g, "all_reduce", "ring", payloads=pays)
    t = r.time_seconds
    assert t[0] < t[1] < t[2]
    d1, d2 = t[1] - t[0], (t[2] - t[1]) / 2.0
    assert d1 == pytest.approx(d2, rel=1e-3)


def test_utilization_accounting():
    g = T.cycle(6)
    r = SM.simulate_collective(g, "all_reduce", "ring",
                               payloads=float(1 << 24))
    util = r.utilization()
    assert 0.0 < r.utilization_max <= 1.0 + 1e-6
    assert util.shape == g.gather_operands()[0].shape
    hist = r.utilization_histogram(bins=5)
    # the ring chain loads exactly the n forward-direction slots
    assert sum(hist["counts"]) == g.n
    hot = r.hot_links(g.gather_operands()[0], top=3)
    assert len(hot) == 3 and all(0 <= u < g.n and 0 <= v < g.n
                                 for u, v, _ in hot)


def test_result_summaries_are_json_ready():
    import json

    r = SM.simulate_collective(T.petersen(), "all_reduce", "ring",
                               payloads=[float(1 << 20), float(1 << 24)])
    d = json.loads(json.dumps(r.to_dict()))
    assert d["collective"] == "all_reduce" and d["rounds"] == r.rounds
    assert len(d["time_seconds"]) == 2
    text = r.report()
    assert "all_reduce/ring" in text and "utilization" in text


# --------------------------------------------------------------------------
# traffic workloads
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", ["uniform", "adversarial"])
def test_workload_throughput_matches_static_ecmp(pattern):
    a = Analysis("petersen_torus(3,3)")
    sim = a.simulate("traffic", pattern=pattern)
    static = a.traffic(pattern)
    assert sim.saturation_throughput == pytest.approx(
        static.saturation_throughput, rel=1e-4)


def test_traffic_sim_rejects_pattern_on_collectives():
    a = Analysis("cycle(6)")
    with pytest.raises(ValueError, match="traffic"):
        a.simulate("all_reduce", pattern="uniform")
    # ...and the mirror image: a schedule algorithm on a traffic workload
    with pytest.raises(ValueError, match="ECMP"):
        a.simulate("traffic", "ring")


# --------------------------------------------------------------------------
# measured vs predicted (the validation loop)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["slimfly(5)", "torus(6,2)", "hypercube(5)",
                                  "ccc(4)"])
def test_measured_at_or_above_model_lower_bound(spec):
    """The paper-thesis check: an executed ring all-reduce can never beat the
    spectral (alpha, beta) lower bound at the same constants."""
    a = Analysis(spec)
    sim = a.simulate("all_reduce", "ring",
                     payload=[float(1 << 20), float(1 << 26)])
    val = a.network_model().validate(sim)
    assert val["all_measured_geq_predicted"]
    assert all(r["ratio"] >= 1.0 - 1e-6 for r in val["rows"])


def test_broadcast_bound_holds_for_central_roots():
    """The broadcast latency floor must be root-agnostic: a root whose
    eccentricity is below the diameter still cannot beat ceil(diam/2) hops,
    so a correct BFS-tree execution is never flagged as a violation."""
    a = Analysis(T.random_regular(20, 3, seed=0))
    # latency-dominated payload from a central root (ecc < diameter)
    sim = a.simulate("broadcast", "bfs_tree", payload=1.0, root=2)
    val = a.network_model().validate(sim)
    assert val["all_measured_geq_predicted"]


def test_validate_rejects_unknown_collective():
    a = Analysis("cycle(6)")
    sim = a.simulate("traffic", pattern="uniform")
    with pytest.raises(ValueError, match="cannot validate"):
        a.network_model().validate(sim)


def test_validate_flags_an_impossible_measurement():
    """A measured time below the analytic bound must be flagged, not
    celebrated."""
    a = Analysis("cycle(8)")
    sim = a.simulate("all_reduce", "ring")
    fake = SM.SimulationResult(**{**sim.__dict__,
                                  "time_seconds": sim.time_seconds * 1e-6})
    val = a.network_model().validate(fake)
    assert not val["all_measured_geq_predicted"]


# --------------------------------------------------------------------------
# fault stacks: vmapped path == per-sample path, composition with faults
# --------------------------------------------------------------------------

def test_stacked_ring_matches_single_topology_path():
    g = T.hypercube(5)
    degraded = [F.apply_faults(g, F.make_scenario(g, "link", 0.1, seed=i))
                for i in range(4)]
    tabs = _stack(g, degraded)
    out = SM.stacked_ring_allreduce(tabs, payload=float(1 << 22))
    assert out["rounds"] == 2 * (g.n - 1)
    for i in range(len(degraded)):
        single = SM.simulate_collective((tabs[i], g.n), "all_reduce", "ring",
                                        payloads=float(1 << 22))
        assert float(single.time_seconds[0]) == pytest.approx(
            float(out["time_seconds"][i]), rel=1e-6)


def test_stacked_ring_drops_disconnected_demand():
    """Cutting both links of one cycle vertex strands it: the ring demand
    touching it is dropped and reported, and the time stays finite."""
    g = T.cycle(8)
    # kill both edges incident to vertex 3, stranding it
    failed = np.nonzero((g.edges == 3).any(axis=1))[0].astype(np.int64)
    assert failed.size == 2
    sc = F.FaultScenario(kind="link", rate=0.25, seed=0, failed_links=failed,
                         failed_nodes=np.empty(0, dtype=np.int64))
    tabs = _stack(g, [F.apply_faults(g, sc)])
    out = SM.stacked_ring_allreduce(tabs, payload=float(1 << 20))
    assert out["dropped_frac"][0] > 0.0
    assert np.isfinite(out["time_seconds"]).all()


def test_fault_sweep_simulate_appends_measured_times():
    a = Analysis("hypercube(5)")
    sweep = a.fault_sweep(rates=[0.0, 0.1], samples=4, simulate=True,
                          sim_payload=float(1 << 22))
    r0, r1 = sweep.rows
    healthy = a.simulate("all_reduce", "ring", payload=float(1 << 22))
    assert r0["sim_allreduce_mean"] == pytest.approx(
        float(healthy.time_seconds[0]), rel=1e-5)
    assert r1["sim_allreduce_max"] >= r1["sim_allreduce_mean"] > 0
    assert "sim_dropped_frac_mean" in r1


# --------------------------------------------------------------------------
# API wiring: Analysis caching, survey columns, synthesized topologies
# --------------------------------------------------------------------------

def test_analysis_simulate_caches_per_configuration():
    a = Analysis("cycle(8)")
    s1 = a.simulate("all_reduce", payload=float(1 << 20))
    assert a.simulate("all_reduce", payload=float(1 << 20)) is s1
    # defaults resolve before keying: explicit 'ring' / 'uniform' hit the
    # same entries as the implicit defaults
    assert a.simulate("all_reduce", "ring", payload=float(1 << 20)) is s1
    t1 = a.simulate("traffic", payload=float(1 << 20))
    assert a.simulate("traffic", pattern="uniform",
                      payload=float(1 << 20)) is t1
    assert a.simulate("all_reduce", payload=float(1 << 21)) is not s1
    with pytest.raises(ValueError, match="unknown collective"):
        a.simulate("all_to_all")


def test_survey_simulate_rejects_traffic_collective():
    with pytest.raises(ValueError, match="pattern="):
        survey(["petersen"], simulate=dict(collective="traffic"))


def test_survey_simulate_appends_sim_columns():
    res = survey(["petersen", "torus(4,2)"], simulate=True)
    assert all(c in res.columns for c in SIM_COLUMNS)
    for row in res:
        assert row["sim_geq_model"] is True
        assert row["sim_time_ms"] >= row["model_time_ms"]
        assert row["sim_thpt_uniform"] > 0


def test_survey_simulate_config_dict():
    res = survey(["hypercube(4)"],
                 simulate=dict(algorithm="halving_doubling",
                               payload=float(1 << 20), pattern=None))
    row = res.rows[0]
    assert row["sim_algorithm"] == "halving_doubling"
    assert row["sim_thpt_uniform"] is None


def test_survey_simulate_payload_sweep_reports_largest():
    """With a payload sweep, every SIM column describes the LARGEST payload
    (the one utilization is accounted at), regardless of list order."""
    pays = [float(1 << 26), float(1 << 20)]
    row = survey(["petersen"], simulate=dict(payload=pays)).rows[0]
    a = Analysis("petersen")
    big = a.network_model().validate(
        a.simulate("all_reduce", payload=float(1 << 26)))["rows"][0]
    assert row["sim_time_ms"] == pytest.approx(big["measured_s"] * 1e3)


def test_subsystem_composes_with_synthesis_and_faults():
    """The acceptance run: simulate + fault_sweep(simulate=True) on a
    synthesized xpander(512,6) registry instance, unchanged."""
    a = Analysis(build("xpander(512,6,0,40)"))   # small search budget: the
    assert a.n == 512                            # product is still (512, 6)
    row = survey([a], simulate=dict(payload=float(1 << 22))).rows[0]
    assert row["sim_geq_model"] is True
    sweep = a.fault_sweep(rates=[0.05], samples=2, simulate=True,
                          sim_payload=float(1 << 22))
    assert sweep.rows[0]["sim_allreduce_mean"] > 0
    assert sweep.rows[0]["sim_dropped_frac_mean"] >= 0.0
