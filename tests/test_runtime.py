"""Runtime: checkpoint atomicity/roundtrip, restart equivalence, straggler
monitor, elastic reshard, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs import get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.optim.adamw import AdamWConfig
from repro.optim.compression import (apply_error_feedback, compress,
                                     decompress, init_error_state)
from repro.runtime.checkpoint import (latest_step, list_checkpoints,
                                      restore_checkpoint, save_checkpoint)
from repro.runtime.fault_tolerance import (StragglerMonitor,
                                           degraded_operation_certificate,
                                           plan_elastic_remesh, reshard)
from repro.runtime.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    return reduced(get_config("qwen2-7b"), repeats=1)


def _mk_trainer(tmp, **kw):
    cfg = _tiny_cfg()
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    data = DataConfig(global_batch=4, seq_len=32, vocab_size=cfg.vocab_size)
    tcfg = TrainerConfig(total_steps=kw.pop("total_steps", 8),
                         ckpt_every=kw.pop("ckpt_every", 4),
                         ckpt_dir=str(tmp / "ckpt"), **kw)
    return Trainer(cfg, opt, data, tcfg)


def test_checkpoint_roundtrip(tmp_path):
    state = dict(a=jnp.arange(10, dtype=jnp.float32),
                 b=[jnp.ones((3, 3), jnp.bfloat16), jnp.zeros(2)],
                 step=jnp.int32(7))
    save_checkpoint(str(tmp_path), 7, state)
    like = jax.tree.map(jnp.zeros_like, state)
    restored, step = restore_checkpoint(str(tmp_path), like)
    assert step == 7
    for x, y in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_gc_and_latest(tmp_path):
    state = dict(a=jnp.zeros(3))
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, state, keep=3)
    assert list_checkpoints(str(tmp_path)) == [3, 4, 5]
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_torn_latest_falls_back(tmp_path):
    state = dict(a=jnp.zeros(3))
    save_checkpoint(str(tmp_path), 1, state)
    save_checkpoint(str(tmp_path), 2, state)
    # simulate torn pointer: LATEST names a deleted dir
    (tmp_path / "LATEST").write_text("step_000000099")
    assert latest_step(str(tmp_path)) == 2


def test_restart_equivalence(tmp_path):
    """Train 8 steps straight == train 4, 'crash', restore, train 4 more."""
    t1 = _mk_trainer(tmp_path / "a", total_steps=8, ckpt_every=4)
    t1.init_or_restore()
    h1 = t1.run()
    loss_straight = h1[-1]["loss"]

    t2 = _mk_trainer(tmp_path / "b", total_steps=8, ckpt_every=4)
    t2.init_or_restore()
    t2.run(steps=4)
    # "crash": rebuild a fresh trainer, restore from checkpoint
    t3 = _mk_trainer(tmp_path / "b", total_steps=8, ckpt_every=4)
    resumed_at = t3.init_or_restore()
    assert resumed_at == 4
    h3 = t3.run()
    assert abs(h3[-1]["loss"] - loss_straight) < 1e-4, \
        "restart must reproduce the straight-through loss"


def test_straggler_monitor_flags_outlier():
    m = StragglerMonitor(window=16, min_samples=4, threshold=3.0)
    for i in range(8):
        m.step_end(i, duration=1.0 + 0.01 * (i % 2))
    assert not m.flagged
    assert m.step_end(9, duration=5.0)
    assert m.flagged and m.flagged[0][0] == 9


def test_elastic_plan_and_reshard():
    plan = plan_elastic_remesh(n_devices=512, lost=16, model_axis=16)
    assert plan.new_devices == 496 // 16 * 16 == 496
    assert plan.new_mesh_shape == (31, 16)
    # reshard a tree onto the (single) local device
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = NamedSharding(mesh, P())
    tree = dict(w=np.ones((4, 4), np.float32))
    out = reshard(tree, dict(w=sh))
    assert out["w"].sharding == sh


def test_degraded_certificate_positive_at_scale():
    cert = degraded_operation_certificate(n=4896, radix=18, alpha=0.95)
    assert cert.guaranteed_bisection_edges > 0


def test_compression_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 128)) * 0.01
    q, s = compress(g)
    err = np.abs(np.asarray(decompress(q, s) - g))
    assert err.max() <= np.asarray(s).max() / 2 + 1e-9   # half-ulp of int8 scale


def test_error_feedback_reduces_bias():
    """Accumulated dequantized gradients converge to accumulated true grads."""
    key = jax.random.PRNGKey(1)
    grads = [dict(w=jax.random.normal(jax.random.fold_in(key, i), (32, 32)) * 0.01)
             for i in range(50)]
    err = init_error_state(grads[0])
    acc_q = np.zeros((32, 32))
    acc_t = np.zeros((32, 32))
    for g in grads:
        dq, err = apply_error_feedback(g, err)
        acc_q += np.asarray(dq["w"], np.float32)
        acc_t += np.asarray(g["w"], np.float32)
    # residual is bounded by the final error buffer, not growing with steps
    resid = np.abs(acc_q - acc_t)
    assert resid.max() <= np.abs(np.asarray(err["w"])).max() + 1e-6


def test_trainer_grad_compression_trains(tmp_path):
    t = _mk_trainer(tmp_path, total_steps=6, ckpt_every=100,
                    grad_compression=True)
    t.init_or_restore()
    h = t.run()
    assert np.isfinite(h[-1]["loss"])
    assert h[-1]["loss"] < h[0]["loss"] + 1.0   # not diverging


def test_data_pipeline_deterministic():
    dc = DataConfig(global_batch=4, seq_len=16, vocab_size=101, seed=3)
    b1 = synthetic_batch(dc, step=7)
    b2 = synthetic_batch(dc, step=7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synthetic_batch(dc, step=8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
