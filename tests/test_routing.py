"""Path-level routing & traffic evaluation: BFS exactness, ECMP conservation,
and degraded-topology consistency with the fault subsystem."""
import numpy as np
import pytest

from repro.core import faults as F
from repro.core import properties as P
from repro.core import topologies as T
from repro.core.routing import (analyze_routing, bfs_distances,
                                routing_stats_stacked, shortest_path_counts)
from repro.core.traffic import (TRAFFIC_PATTERNS, demand_matrix,
                                evaluate_traffic, spectral_throughput_estimate)


# --------------------------------------------------------------------------
# BFS distances / diameter
# --------------------------------------------------------------------------

@pytest.mark.parametrize("build", [
    T.petersen,
    lambda: T.complete(4),
    lambda: T.cycle(9),
    lambda: T.cycle(12),
    lambda: T.torus(5, 2),
    lambda: T.generalized_grid([4, 3]),
], ids=["petersen", "K4", "ring9", "ring12", "torus5x2", "grid4x3"])
def test_bfs_diameter_matches_properties(build):
    g = build()
    r = analyze_routing(g)
    assert r.exact
    assert r.diameter == P.diameter(g)
    assert r.unreachable_pairs == 0


def test_bfs_distances_match_networkx():
    nx = pytest.importorskip("networkx")
    g = T.random_regular(24, 3, seed=2)
    dist = bfs_distances(g.gather_operands()[0])
    G = g.to_networkx()
    for s in range(g.n):
        lengths = nx.single_source_shortest_path_length(G, s)
        for t in range(g.n):
            assert dist[s, t] == lengths.get(t, -1)


def test_closed_form_diameters():
    """Registered Table-1 diameter closed forms match measured BFS."""
    from repro.api import Analysis

    for spec in ["torus(6,2)", "torus(5,3)", "hypercube(6)", "cycle(11)",
                 "complete(9)", "petersen", "grid(4,3,2)", "slimfly(5)"]:
        a = Analysis(spec)
        cf = a.closed_forms
        assert cf is not None and "diameter" in cf, spec
        assert a.routing().diameter == int(cf["diameter"]), spec


def test_hop_distribution_symmetry_vertex_transitive():
    """Every source of a vertex-transitive graph sees the same hop profile."""
    for g in (T.petersen(), T.torus(5, 2), T.hypercube(5), T.cycle(10)):
        r = analyze_routing(g)
        hists = np.stack([np.bincount(row[row > 0],
                                      minlength=r.diameter + 1)
                          for row in r.dist])
        assert (hists == hists[0]).all(), g.name


def test_sampled_sources_give_lower_bound():
    g = T.generalized_grid([9])      # path: diameter 8, ecc(4) = 4
    r = analyze_routing(g, sources=[4])
    assert not r.exact
    assert r.diameter == 4           # sampled diameter is only a lower bound


# --------------------------------------------------------------------------
# minimal-path counts (path diversity)
# --------------------------------------------------------------------------

def test_path_counts_match_networkx():
    nx = pytest.importorskip("networkx")
    g = T.random_regular(20, 4, seed=1)
    tab = g.gather_operands()[0]
    dist = bfs_distances(tab)
    sigma = shortest_path_counts(tab, dist)
    G = g.to_networkx()
    for s in [0, 7, 13]:
        for t in range(g.n):
            if s == t:
                assert sigma[s, t] == 1
                continue
            want = len(list(nx.all_shortest_paths(G, s, t)))
            assert sigma[s, t] == want, (s, t)


def test_path_counts_known_graphs():
    # Petersen (girth 5): all shortest paths unique
    r = analyze_routing(T.petersen())
    assert r.path_diversity_mean == 1.0 and r.path_diversity_min == 1.0
    # hypercube: sigma(s, t) = (hamming distance)!
    r = analyze_routing(T.hypercube(4))
    import math
    for t in range(16):
        assert r.sigma[0, t] == math.factorial(bin(t).count("1"))


# --------------------------------------------------------------------------
# traffic patterns
# --------------------------------------------------------------------------

def test_demand_matrices_normalized():
    n = 16
    for pattern in ("uniform", "bit_complement", "transpose", "neighbor"):
        D = demand_matrix(pattern, n)
        assert D.shape == (n, n)
        assert np.all(np.diag(D) == 0.0)
        assert np.all(D.sum(axis=1) <= 1.0 + 1e-12), pattern
    # permutations really are permutations: row/col sums are one unit, except
    # fixed points (transpose's diagonal a == b), which send nothing
    D = demand_matrix("bit_complement", n)
    assert np.allclose(D.sum(axis=1), 1.0) and np.allclose(D.sum(axis=0), 1.0)
    D = demand_matrix("transpose", n)
    row = D.sum(axis=1)
    m = 4
    assert (row == 0.0).sum() == m           # the m fixed points (a, a)
    assert np.allclose(row[row > 0], 1.0)
    assert np.array_equal(D.sum(axis=0), row)
    with pytest.raises(ValueError):
        demand_matrix("transpose", 12)       # not square
    with pytest.raises(ValueError):
        demand_matrix("adversarial", 8)      # needs the Fiedler vector
    with pytest.raises(ValueError):
        demand_matrix("carpool", 8)


def test_adversarial_demands_are_permutation():
    from repro.core.spectral import fiedler_vector

    g = T.torus(4, 2)
    D = demand_matrix("adversarial", g.n, fiedler=fiedler_vector(g))
    assert np.allclose(D.sum(axis=1), 1.0)
    assert np.allclose(D.sum(axis=0), 1.0)


# --------------------------------------------------------------------------
# ECMP load accounting
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", ["uniform", "bit_complement", "neighbor"])
@pytest.mark.parametrize("build", [
    T.petersen, lambda: T.torus(4, 2), lambda: T.random_regular(18, 4, seed=0),
], ids=["petersen", "torus4x2", "rr18"])
def test_ecmp_load_conservation(build, pattern):
    """Sum of directed link loads == sum of demand * hops (each unit of flow
    occupies one load unit per hop)."""
    g = build()
    t = evaluate_traffic(g, pattern)
    want = t.total_demand * t.avg_hops
    assert t.link_loads.sum() == pytest.approx(want, rel=1e-5)
    assert t.conservation_error < 1e-4
    assert t.dropped_demand == 0.0


def test_ecmp_complete_graph_uniform():
    """K_n: every pair is one hop, each directed link carries exactly its
    source's per-peer demand 1/(n-1); throughput saturates at n-1."""
    t = evaluate_traffic(T.complete(8), "uniform")
    loads = t.link_loads
    assert np.allclose(loads, 1.0 / 7.0)
    assert t.saturation_throughput == pytest.approx(7.0, rel=1e-5)


def test_ecmp_splits_across_parallel_shortest_paths():
    """4-cycle, opposite corners: two equal shortest paths, half a unit each."""
    g = T.cycle(4)
    D = np.zeros((4, 4))
    D[0, 2] = 1.0
    t = evaluate_traffic(g, demands=D)
    # every traversed directed link carries exactly 0.5
    loaded = t.link_loads[t.link_loads > 0]
    assert np.allclose(loaded, 0.5) and loaded.size == 4
    assert t.max_link_load == pytest.approx(0.5)


def test_unreachable_demand_is_dropped():
    g = T.Topology("twopairs", 4, np.array([[0, 1], [2, 3]]))
    t = evaluate_traffic(g, "uniform")
    # only the in-component demand is served
    assert t.dropped_demand == pytest.approx(4 * 2 / 3)
    assert t.total_demand == pytest.approx(4 * 1 / 3)
    assert t.conservation_error < 1e-5


def test_spectral_throughput_estimate_units():
    # the cut-based prediction is ~rho2 (uncapped, like the measured figure)
    assert spectral_throughput_estimate(256, 2.0) == pytest.approx(2.0, rel=0.02)
    assert spectral_throughput_estimate(256, 0.15) == pytest.approx(
        0.15, rel=0.02)
    assert spectral_throughput_estimate(338, 13.0) == pytest.approx(13.0, rel=0.02)


# --------------------------------------------------------------------------
# degraded-topology routing (fault subsystem integration)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("model,rate", [("link", 0.15), ("node", 0.1)])
def test_degraded_routing_consistent_with_apply_faults(model, rate):
    """Routing over the stacked padded operands == routing the materialized
    apply_faults topology directly."""
    g = T.torus(5, 2)
    scens = [F.make_scenario(g, model, rate, seed=s) for s in range(4)]
    degraded = [F.apply_faults(g, sc) for sc in scens]
    tabs, _, _ = F.stacked_operands(degraded)
    stacked = routing_stats_stacked(tabs)
    for d, st in zip(degraded, stacked):
        direct = analyze_routing(d)
        assert st["diameter"] == direct.diameter
        assert st["avg_path_length"] == pytest.approx(direct.avg_path_length)
        assert st["unreachable_pairs"] == direct.unreachable_pairs


def test_fault_sweep_routing_rows():
    from repro.api import Analysis

    a = Analysis("petersen_torus(5,4)")
    sweep = a.fault_sweep(rates=(0.0, 0.1), model="link", samples=4,
                          routing=True)
    r0, r1 = sweep.rows
    healthy = a.routing()
    # rate 0: the measured degraded structure equals the healthy one
    assert r0["bfs_diameter_mean"] == healthy.diameter
    assert r0["bfs_avg_hops_mean"] == pytest.approx(healthy.avg_path_length)
    assert r0["reachable_frac_mean"] == 1.0
    # removing links never shortens paths
    assert r1["bfs_diameter_mean"] >= r0["bfs_diameter_mean"]
    assert r1["bfs_avg_hops_mean"] >= r0["bfs_avg_hops_mean"]
    assert 0.0 <= r1["reachable_frac_mean"] <= 1.0


def test_fault_sweep_routing_disconnected_samples_report_none():
    """A shattered sample must not report its shrunken max-over-reachable
    figure as a 'diameter': cutting 2 Fiedler-heavy edges splits a cycle."""
    from repro.api import Analysis

    sweep = Analysis("cycle(8)").fault_sweep(
        rates=(0.25,), model="attack_spectral", routing=True)
    row = sweep.rows[0]
    assert row["reachable_frac_mean"] < 1.0
    assert row["bfs_diameter_mean"] is None
    assert row["bfs_diameter_max"] is None


# --------------------------------------------------------------------------
# API / survey / cost-model wiring
# --------------------------------------------------------------------------

def test_analysis_routing_cached_and_traffic():
    from repro.api import Analysis

    a = Analysis("torus(4,2)")
    assert a.routing() is a.routing()          # cached default
    assert a.traffic("uniform") is a.traffic("uniform")
    assert a.routing().diameter == a.diameter == 4
    sub = a.routing(sources=[0, 1])            # sampled: fresh, not cached
    assert sub.sources.size == 2 and not sub.exact


def test_survey_routing_columns():
    from repro.api import ROUTING_COLUMNS, survey

    res = survey(["petersen", "torus(4,2)", "complete(6)"], routing=True)
    for col in ROUTING_COLUMNS:
        assert col in res.columns
    by = {r["topology"]: r for r in res.rows}
    assert by["petersen"]["diameter_bfs"] == 2
    assert by["petersen"]["diameter_ok"] is True
    assert by["torus"]["diameter_ok"] is True
    assert by["complete"]["saturation_throughput"] == pytest.approx(5.0)
    for r in res.rows:
        assert r["traffic_pattern"] == "uniform"
        assert r["throughput_spectral"] > 0
    # an empty config dict means "all defaults", not "off"
    res2 = survey(["petersen"], routing={})
    assert "diameter_bfs" in res2.columns and res2.rows[0]["diameter_bfs"] == 2
    # and False/None disable
    assert "diameter_bfs" not in survey(["petersen"], routing=False).columns


def test_network_model_uses_measured_routing():
    from repro.api import Analysis
    from repro.core.collectives import network_from_topology

    a = Analysis("torus(4,2)")
    net = network_from_topology(a.topo, rho2=a.rho2, routing=a.routing())
    assert net.diameter == a.routing().diameter
    assert net.avg_hops == pytest.approx(a.routing().avg_path_length)
    assert net.permute_hops < net.diameter     # avg hops < diameter here
    # permute latency uses measured avg hops; degraded view drops it
    assert net.degrade(0.1).avg_hops is None
    plain = network_from_topology(a.topo, rho2=a.rho2)
    assert plain.avg_hops is None and plain.permute_hops == plain.diameter
    assert net.collective_time("collective-permute", 1 << 20) <= \
        plain.collective_time("collective-permute", 1 << 20)


def test_traffic_accepts_sampled_routing_with_correction():
    """Sampled routing routes only its S source rows; every extensive figure
    carries the n/S unbiasedness correction and conservation still holds."""
    g = T.torus(4, 2)
    n = g.n
    partial = analyze_routing(g, sources=list(range(4)))
    res = evaluate_traffic(g, "uniform", routing=partial)
    assert res.exact is False
    assert res.sample_correction == pytest.approx(n / 4)
    assert res.conservation_error < 1e-5
    # uniform demand offers 1 unit per sampled source, scaled back to n
    assert res.total_demand == pytest.approx(n, rel=1e-6)
    # all-sources routing reproduces the exact figures with correction 1
    full = evaluate_traffic(g, "uniform", routing=analyze_routing(g))
    assert full.exact is True and full.sample_correction == 1.0
    # torus(4,2) is vertex-transitive, so each source contributes the same
    # hop mass: the corrected total load reproduces the exact census sum
    # (mean_link_load averages over USED links only, so it is not comparable
    # across samples that light up different link subsets)
    assert np.sum(res.link_loads) == pytest.approx(np.sum(full.link_loads),
                                                   rel=1e-5)
