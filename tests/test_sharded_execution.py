"""Integration: the sharded train step EXECUTES on an 8-device host mesh and
reproduces single-device numerics.  Runs in a subprocess because the device
count must be set before jax initializes (tests elsewhere need 1 device)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import reduced, ShapeSpec
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.parallel import sharding as sh
from repro.train.steps import init_train_state, make_train_step

arch = os.environ["TEST_ARCH"]
cfg = reduced(get_config(arch))
opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
key = jax.random.PRNGKey(0)
B, S = 8, 32
dc = DataConfig(global_batch=B, seq_len=S, vocab_size=cfg.vocab_size)
batch = synthetic_batch(dc, 0, frontend=cfg.frontend, d_model=cfg.d_model)
shape = ShapeSpec("t", S, B, "train")

# --- single device reference ---
params, opt = init_train_state(cfg, opt_cfg, key)
step = make_train_step(cfg, opt_cfg)
_, _, m_ref = jax.jit(step)(params, opt, batch)

# --- 2x4 mesh execution ---
mesh = jax.make_mesh((2, 4), ("data", "model"))
p_sh = sh.to_shardings(sh.param_pspecs(cfg, mesh), mesh)
o_sh = sh.to_shardings(sh.opt_pspecs(cfg, mesh), mesh)
b_sh = sh.to_shardings(sh.batch_pspecs(cfg, shape, mesh), mesh)
params2, opt2 = init_train_state(cfg, opt_cfg, key)
params2 = jax.device_put(params2, p_sh)
opt2 = jax.device_put(opt2, o_sh)
batch2 = jax.device_put(batch, b_sh)
with mesh, sh.activation_mesh(mesh):
    fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))
    _, _, m_mesh = fn(params2, opt2, batch2)
print(json.dumps(dict(loss_ref=float(m_ref["loss"]),
                      loss_mesh=float(m_mesh["loss"]),
                      gnorm_ref=float(m_ref["grad_norm"]),
                      gnorm_mesh=float(m_mesh["grad_norm"]))))
"""


@pytest.mark.parametrize("arch", ["qwen2-7b", "jamba-v0.1-52b", "kimi-k2-1t-a32b",
                                  "falcon-mamba-7b", "gemma3-12b"])
def test_sharded_step_matches_single_device(arch):
    env = dict(os.environ, TEST_ARCH=arch,
               PYTHONPATH=os.path.abspath("src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["loss_ref"] - res["loss_mesh"]) < 2e-3, res
    assert abs(res["gnorm_ref"] - res["gnorm_mesh"]) / max(res["gnorm_ref"], 1) < 2e-2, res
