"""The CI docs gate must pass from the repo checkout (dead intra-repo links,
repro.api coverage of docs/api.md, registered-family coverage)."""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).parents[1]


def test_docs_gate_passes():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py"),
         "--root", str(ROOT)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr


def test_docs_gate_catches_dead_link(tmp_path):
    """The checker actually fires: a doc tree with a dead link fails."""
    (tmp_path / "docs").mkdir()
    (tmp_path / "src/repro/api").mkdir(parents=True)
    (tmp_path / "src/repro/core").mkdir(parents=True)
    (tmp_path / "src/repro/api/__init__.py").write_text('__all__ = ["build"]')
    for f in ("topologies.py", "ramanujan.py", "synthesis.py"):
        (tmp_path / "src/repro/core" / f).write_text("")
    (tmp_path / "docs/api.md").write_text("`build` documented")
    (tmp_path / "README.md").write_text("[gone](docs/missing.md)")
    for f in ("architecture.md", "theory.md", "synthesis.md"):
        (tmp_path / "docs" / f).write_text("ok")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py"),
         "--root", str(tmp_path)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert "dead link" in proc.stderr


def test_docs_gate_catches_undocumented_symbol(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "src/repro/api").mkdir(parents=True)
    (tmp_path / "src/repro/core").mkdir(parents=True)
    (tmp_path / "src/repro/api/__init__.py").write_text(
        '__all__ = ["build", "UNHEARD_OF"]')
    for f in ("topologies.py", "ramanujan.py", "synthesis.py"):
        (tmp_path / "src/repro/core" / f).write_text("")
    (tmp_path / "docs/api.md").write_text("`build` documented")
    (tmp_path / "README.md").write_text("no links")
    for f in ("architecture.md", "theory.md", "synthesis.md"):
        (tmp_path / "docs" / f).write_text("ok")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py"),
         "--root", str(tmp_path)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert "UNHEARD_OF" in proc.stderr
