"""Test-session bootstrap.

``hypothesis`` is a hard dependency of five test modules (see
requirements.txt).  Hermetic CI containers cannot always pip-install, so when
the real package is missing we install a minimal deterministic shim that
supports exactly the strategy surface these tests use (``integers``,
``sampled_from``, ``booleans``, ``.filter``) and runs each ``@given`` test on
``max_examples`` pseudo-random draws from a fixed seed.  With real hypothesis
installed the shim is inert.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types


def _install_hypothesis_shim() -> None:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def filter(self, pred):
            def draw(rnd, _self=self, _pred=pred, _tries=1000):
                for _ in range(_tries):
                    v = _self._draw(rnd)
                    if _pred(v):
                        return v
                raise ValueError("hypothesis-shim: filter rejected all draws")
            return _Strategy(draw)

        def map(self, fn):
            return _Strategy(lambda rnd: fn(self._draw(rnd)))

    def integers(min_value=None, max_value=None):
        lo = 0 if min_value is None else min_value
        hi = lo + 100 if max_value is None else max_value
        return _Strategy(lambda rnd: rnd.randint(lo, hi))

    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rnd: items[rnd.randrange(len(items))])

    def booleans():
        return _Strategy(lambda rnd: rnd.random() < 0.5)

    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

    def just(value):
        return _Strategy(lambda rnd: value)

    def settings(max_examples=10, deadline=None, **_):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rnd = random.Random(0)
                n = getattr(wrapper, "_shim_max_examples", 10)
                for _ in range(n):
                    drawn = tuple(s._draw(rnd) for s in strategies)
                    drawn_kw = {k: s._draw(rnd) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # hide the drawn parameters from pytest's fixture resolution
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.booleans = booleans
    st.floats = floats
    st.just = just

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__is_shim__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:  # pragma: no cover - depends on the environment
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    _install_hypothesis_shim()
