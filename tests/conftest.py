"""Test-session bootstrap.

``hypothesis`` is a hard dependency of several test modules (see
requirements.txt).  Hermetic CI containers cannot always pip-install, so when
the real package is missing we install a minimal deterministic shim that
supports exactly the strategy surface these tests use (``integers``,
``sampled_from``, ``booleans``, ``floats``, ``just``, ``.filter``/``.map``)
and runs each ``@given`` test on ``max_examples`` pseudo-random draws from a
fixed seed.  With real hypothesis installed the shim is inert.

Either way, a deterministic **"ci" profile** is registered and loaded at the
bottom of this file — fixed seed (``derandomize``), no deadline, and a
``HYPOTHESIS_MAX_EXAMPLES``-scaled example count — so the shim and real
hypothesis draw the same role in CI: reproducible runs, no flaky deadline
kills, tunable cost.  Select another profile with ``HYPOTHESIS_PROFILE``.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types


def _install_hypothesis_shim() -> None:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def filter(self, pred):
            def draw(rnd, _self=self, _pred=pred, _tries=1000):
                for _ in range(_tries):
                    v = _self._draw(rnd)
                    if _pred(v):
                        return v
                raise ValueError("hypothesis-shim: filter rejected all draws")
            return _Strategy(draw)

        def map(self, fn):
            return _Strategy(lambda rnd: fn(self._draw(rnd)))

    def integers(min_value=None, max_value=None):
        lo = 0 if min_value is None else min_value
        hi = lo + 100 if max_value is None else max_value
        return _Strategy(lambda rnd: rnd.randint(lo, hi))

    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rnd: items[rnd.randrange(len(items))])

    def booleans():
        return _Strategy(lambda rnd: rnd.random() < 0.5)

    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

    def just(value):
        return _Strategy(lambda rnd: value)

    class settings:
        """Shim of ``hypothesis.settings``: decorator + profile registry.

        Mirrors the real API surface the suite uses — ``settings(...)`` as a
        test decorator, ``settings.register_profile(name, **kw)`` and
        ``settings.load_profile(name)`` — so tests/conftest configure both
        implementations identically.  The shim is always derandomized (every
        ``@given`` run draws from ``random.Random(0)``).
        """
        _profiles: dict = {"default": {"max_examples": 10}}
        _current: dict = {"max_examples": 10}

        def __init__(self, max_examples=None, **_ignored):
            self._max_examples = max_examples

        def __call__(self, fn):
            if self._max_examples is not None:
                fn._shim_max_examples = self._max_examples
            return fn

        @classmethod
        def register_profile(cls, name, **kwargs):
            cls._profiles[name] = dict(kwargs)

        @classmethod
        def load_profile(cls, name):
            cls._current = {**cls._profiles.get("default", {}),
                            **cls._profiles.get(name, {})}

    def given(*strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rnd = random.Random(0)
                n = getattr(wrapper, "_shim_max_examples",
                            settings._current.get("max_examples", 10))
                for _ in range(n):
                    drawn = tuple(s._draw(rnd) for s in strategies)
                    drawn_kw = {k: s._draw(rnd) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # hide the drawn parameters from pytest's fixture resolution
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.booleans = booleans
    st.floats = floats
    st.just = just

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__is_shim__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:  # pragma: no cover - depends on the environment
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    _install_hypothesis_shim()
    import hypothesis  # noqa: F401


def _register_ci_profile() -> None:
    """One deterministic profile for both implementations (see module doc).

    ``derandomize=True`` fixes the PRNG (the shim is always derandomized);
    ``deadline=None`` disarms per-example wall-time kills, which misfire on
    first-call JIT compilation; ``max_examples`` scales with
    ``HYPOTHESIS_MAX_EXAMPLES`` so CI can trade coverage for wall time.
    """
    from hypothesis import settings

    settings.register_profile(
        "ci",
        max_examples=int(os.environ.get("HYPOTHESIS_MAX_EXAMPLES", "12")),
        derandomize=True,
        deadline=None,
    )
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))


_register_ci_profile()
