"""The observability substrate: spans, counters, telemetry, recompile hygiene.

Four layers of coverage:

* ``repro.obs`` primitives — counters + deltas, span nesting and the
  disabled-path no-op, ``tracing()`` buffer semantics, Chrome-trace JSON,
  ``render_tree``, and the phase interval-union of ``metrics_report``.
* Per-round simulator telemetry — ``RoundTelemetry`` arrays from
  ``run_schedule(telemetry=True)`` / ``Analysis.simulate(telemetry=True)``:
  the max over rounds of the per-unit-payload link load must equal the
  static ECMP ``max_link_load`` on uniform traffic (the ISSUE-10 acceptance
  identity, checked on 3+ families), and ``sum(counts * round_seconds)``
  must reproduce the engine's measured completion time.
* Recompile hygiene — a survey over small instances of the nine bench
  families must trigger exactly one batched solve per same-shape engine
  group (pins the PR-1 batching), and re-running an identical survey must
  add NO jit traces beyond the per-instance fresh-closure Lanczos solves
  (pins the PR-8 trace-time backend resolution via counters, not probes).
* Backend-dispatch counters — ``spmv/matvec/<backend>`` replaces the old
  monkey-patch call counting.
"""
import json

import jax
import numpy as np
import pytest

from repro import obs
from repro.api.analysis import Analysis
from repro.api.registry import build
from repro.api.survey import survey
from repro.core import topologies as T
from repro.core.simulate import RoundTelemetry, compile_schedule, run_schedule


# --------------------------------------------------------------------------
# counters
# --------------------------------------------------------------------------

def test_count_and_delta():
    before = obs.counters()
    obs.count("test/x")
    obs.count("test/x", 4)
    obs.count("test/y")
    d = obs.counter_delta(before)
    assert d["test/x"] == 5 and d["test/y"] == 1
    assert obs.counter_delta(before, prefix="test/x") == {"test/x": 5}
    # unchanged counters never appear in a delta
    assert "test/x" not in obs.counter_delta(obs.counters())


def test_counters_prefix_filter():
    obs.count("pfx/a")
    obs.count("other/b")
    snap = obs.counters("pfx/")
    assert "pfx/a" in snap and all(k.startswith("pfx/") for k in snap)


# --------------------------------------------------------------------------
# spans
# --------------------------------------------------------------------------

def test_span_disabled_is_shared_noop():
    obs.disable()
    s1 = obs.span("a")
    s2 = obs.span("b", phase="execute")
    assert s1 is s2                      # the shared null object
    with s1:
        pass
    assert obs.trace_events() == [] or all(
        e["name"] not in ("a", "b") for e in obs.trace_events())


def test_span_nesting_depth_and_tags():
    with obs.tracing():
        obs.reset_spans()
        with obs.span("outer", phase="build", family="petersen"):
            with obs.span("inner", phase="build"):
                pass
        evs = obs.trace_events()
    names = {e["name"]: e for e in evs}
    assert set(names) == {"outer", "inner"}
    assert names["inner"]["args"]["depth"] == 1
    assert names["outer"]["args"]["depth"] == 0
    assert names["outer"]["args"]["family"] == "petersen"
    # the inner interval lies within the outer one
    o, i = names["outer"], names["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-3


def test_traced_decorator_and_enable_toggle():
    @obs.traced("test/fn", phase="execute", kind="unit")
    def fn(x):
        return x + 1

    obs.disable()
    obs.reset_spans()
    assert fn(1) == 2
    assert obs.trace_events() == []      # disabled: no recording
    with obs.tracing():
        assert fn(2) == 3
        evs = obs.trace_events()
    assert [e["name"] for e in evs] == ["test/fn"]
    assert evs[0]["args"]["kind"] == "unit"
    assert evs[0]["cat"] == "execute"


def test_tracing_writes_chrome_trace_json(tmp_path):
    path = tmp_path / "trace.json"
    with obs.tracing(path):
        with obs.span("root", phase="build"):
            with obs.span("child"):
                pass
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert {e["name"] for e in evs} == {"root", "child"}
    for e in evs:                        # Chrome trace-event "X" schema
        assert e["ph"] == "X"
        assert isinstance(e["ts"], float) and isinstance(e["dur"], float)
        assert e["pid"] == 1 and "tid" in e and "args" in e


def test_tracing_nesting_outermost_owns_buffer():
    with obs.tracing():
        with obs.span("before"):
            pass
        with obs.tracing():              # inner: must NOT clear the buffer
            with obs.span("within"):
                pass
        assert {e["name"] for e in obs.trace_events()} >= {"before", "within"}
        assert obs.enabled()             # inner exit must not disable
    assert not obs.enabled()


def test_render_tree_indents_by_depth():
    with obs.tracing():
        obs.reset_spans()
        with obs.span("parent", phase="execute"):
            with obs.span("child", instance="petersen"):
                pass
    txt = obs.render_tree()
    lines = txt.splitlines()
    assert lines[0].startswith("parent")
    assert lines[1].startswith("  child")
    assert "instance=petersen" in lines[1]


def test_metrics_report_phases_interval_union():
    """Nested same-phase spans must not double-count phase seconds."""
    with obs.tracing():
        obs.reset_spans()
        with obs.span("outer", phase="execute"):
            with obs.span("inner", phase="execute"):
                pass
    rep = obs.metrics_report()
    outer = rep.spans["outer"].total_seconds
    inner = rep.spans["inner"].total_seconds
    assert rep.phases["execute"] <= outer + 1e-9     # union, not sum
    assert rep.phases["execute"] >= inner
    d = rep.to_dict()
    assert set(d) == {"spans", "phases", "counters", "peak_rss_kb"}
    json.dumps(d)                        # JSON-clean
    assert "peak RSS" in rep.report()


def test_peak_rss_is_positive_high_water():
    assert obs.peak_rss_kb() > 0


# --------------------------------------------------------------------------
# per-round telemetry (the tentpole acceptance identity)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["petersen", "hypercube(5)", "torus(6,2)"])
def test_telemetry_max_round_load_matches_static_ecmp(spec):
    """max over rounds of the per-unit-payload link load == the static ECMP
    ``max_link_load`` on uniform traffic (same demand, same lowering)."""
    a = Analysis(build(spec))
    sim = a.simulate("traffic", pattern="uniform", telemetry=True)
    tel = sim.telemetry
    assert isinstance(tel, RoundTelemetry)
    static = a.traffic("uniform").max_link_load
    assert np.isclose(tel.round_max_link_load.max(), static, rtol=1e-6)
    # 1 / max load is the saturation throughput both layers report
    assert np.isclose(1.0 / tel.round_max_link_load.max(),
                      sim.saturation_throughput, rtol=1e-6)


def test_telemetry_round_times_reproduce_engine_total():
    """sum(counts * round_seconds) == the engine's measured completion time
    at the telemetry payload (the straggler-hop breakdown is exact)."""
    g = T.torus(4, 2)
    sched = compile_schedule(g, "all_reduce", "ring")
    res = run_schedule(sched, payloads=(1 << 16, 1 << 24), telemetry=True)
    tel = res.telemetry
    assert tel.payload_bytes == float(1 << 24)       # largest of the sweep
    assert np.isclose(tel.total_seconds(), res.time_seconds[-1], rtol=1e-4)
    assert tel.unique_rounds == sched.unique_rounds
    assert np.array_equal(tel.counts, sched.counts)
    assert np.array_equal(tel.hops, sched.hops)
    # breakdown: round = bandwidth term + latency term, utilization in (0, 1]
    np.testing.assert_allclose(
        tel.round_seconds, tel.round_bw_seconds + tel.round_latency_seconds)
    assert ((tel.round_util_max > 0) & (tel.round_util_max <= 1.0)).all()
    assert (tel.round_util_mean <= tel.round_util_max + 1e-12).all()


def test_telemetry_argmax_link_is_a_real_link():
    g = T.petersen()
    sched = compile_schedule(g, "broadcast", "bfs_tree")
    res = run_schedule(sched, telemetry=True)
    node, slot = res.telemetry.argmax_link()
    tab, _ = g.gather_operands()
    assert 0 <= node < g.n and 0 <= slot < tab.shape[1]
    u = int(res.telemetry.round_max_link_load.argmax())
    assert sched.round_bytes[u, node, slot] == sched.round_bytes[u].max()


def test_telemetry_off_by_default_and_cached_separately():
    a = Analysis(build("petersen"))
    plain = a.simulate("traffic", pattern="uniform")
    assert plain.telemetry is None
    teled = a.simulate("traffic", pattern="uniform", telemetry=True)
    assert teled.telemetry is not None
    assert plain is not teled            # cache keys on the telemetry flag
    assert plain is a.simulate("traffic", pattern="uniform")
    d = teled.to_dict()
    assert d["telemetry"]["unique_rounds"] == teled.telemetry.unique_rounds
    json.dumps(d)


def test_telemetry_through_collective_driver():
    sim = Analysis(build("hypercube(4)")).simulate(
        "all_reduce", "ring", telemetry=True)
    tel = sim.telemetry
    assert tel is not None
    assert int(tel.counts.sum()) == sim.rounds


# --------------------------------------------------------------------------
# recompile hygiene over the nine bench families (satellite: one trace per
# same-shape engine group; counters replace the old monkey-patch probes)
# --------------------------------------------------------------------------

#: small instances of the nine benchmark families of
#: benchmarks/collective_sim.py (same constructors, test-sized parameters).
BENCH_FAMILIES_SMALL = [
    "lps(5,13)", "slimfly(5)", "torus(4,2)", "hypercube(4)", "ccc(3)",
    "butterfly(2,3)", "petersen_torus(3,3)", "dragonfly",
    "xpander(32,4,0,40)",
]


def _survey_nine():
    return survey(BENCH_FAMILIES_SMALL, columns=["instance", "nodes", "rho2"],
                  dense_threshold=8, lanczos_iters=40)


def test_nine_families_cover_the_bench_specs():
    import pathlib
    src = pathlib.Path(__file__).resolve().parents[1] \
        / "benchmarks" / "collective_sim.py"
    text = src.read_text()
    for spec in BENCH_FAMILIES_SMALL:
        fam = spec.split("(")[0]
        assert fam in text, f"family {fam} not in the bench spec list"


def test_survey_one_batched_solve_per_same_shape_group():
    """torus(4,2) and hypercube(4) share (n=16, deg=4): exactly ONE batched
    group of exactly TWO instances; every other family solves per-instance."""
    jax.clear_caches()
    before = obs.counters()
    res = _survey_nine()
    assert len(res) == len(BENCH_FAMILIES_SMALL)
    d = obs.counter_delta(before)
    assert d.get("survey/lanczos_groups", 0) == 1
    assert d.get("survey/lanczos_grouped_instances", 0) == 2
    # at least 2 grouped + 7 singleton survey solves (the xpander build's
    # annealer adds its own signed-Lanczos solves on top)
    assert d.get("lanczos/solves", 0) >= len(BENCH_FAMILIES_SMALL)
    assert d.get("lanczos/iters", 0) >= 40 * len(BENCH_FAMILIES_SMALL)
    # trace-time backend resolution: one matvec closure per singleton, all on
    # the ambient default backend (PR-8 invariant, via counters not probes)
    from repro.kernels import spmv as KS
    assert d.get("spmv/matvec/" + KS.default_backend(), 0) == 7


def test_survey_rerun_adds_no_engine_retraces():
    """An identical re-survey must add NO jit traces beyond the per-instance
    Lanczos solves (whose fresh matvec closures always retrace); the batched
    same-shape group and every other engine hit their jit caches."""
    jax.clear_caches()
    _survey_nine()                       # populate every jit cache
    before = obs.counters("jit_trace/")
    _survey_nine()
    d = obs.counter_delta(before, "jit_trace/")
    assert set(d) <= {"jit_trace/lanczos_scan"}, f"unexpected retraces: {d}"
    # exactly the 7 ungrouped per-instance solves — the batched group must
    # hit its shape-keyed cache (0 new traces from it)
    assert d.get("jit_trace/lanczos_scan", 0) == 7


def test_same_shape_trio_one_batched_trace():
    """Three same-shape random_regular instances: one group, one batched
    Lanczos trace; a second identical survey re-traces nothing."""
    specs = ["random_regular(64,4,0)", "random_regular(64,4,1)",
             "random_regular(64,4,2)"]
    jax.clear_caches()
    before = obs.counters()
    survey(specs, columns=["instance", "rho2"], dense_threshold=8,
           lanczos_iters=30)
    d = obs.counter_delta(before)
    assert d.get("survey/lanczos_groups", 0) == 1
    assert d.get("survey/lanczos_grouped_instances", 0) == 3
    assert d.get("jit_trace/lanczos_scan", 0) == 1   # ONE vmapped trace
    before = obs.counters("jit_trace/")
    survey(specs, columns=["instance", "rho2"], dense_threshold=8,
           lanczos_iters=30)
    assert obs.counter_delta(before, "jit_trace/") == {}


def test_survey_trace_hook_records_rows(tmp_path):
    path = tmp_path / "survey_trace.json"
    survey(["petersen", "ccc(3)"], trace=path)
    doc = json.loads(path.read_text())
    names = [e["name"] for e in doc["traceEvents"]]
    assert names.count("survey/row") == 2
    assert "survey/build" in names
    rows = [e for e in doc["traceEvents"] if e["name"] == "survey/row"]
    assert {r["args"]["instance"] for r in rows} == {"petersen", "ccc(3)"}
