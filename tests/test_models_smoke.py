"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (deliverable f).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.configs.base import reduced
from repro.models import model as M

ARCHS = [a for a in list_configs() if a != "lm100m"]


def _batch(cfg, key, B=2, S=32):
    if cfg.frontend != "none":
        return dict(embeds=jax.random.normal(key, (B, S, cfg.d_model), jnp.float32),
                    labels=jax.random.randint(key, (B, S), 0, cfg.vocab_size))
    return dict(tokens=jax.random.randint(key, (B, S), 0, cfg.vocab_size),
                labels=jax.random.randint(key, (B, S), 0, cfg.vocab_size))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key)

    def loss(p):
        return M.loss_fn(p, batch, cfg)[0]

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val)), f"{arch}: non-finite loss"
    gleaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, dtype=np.float32))) for g in gleaves), \
        f"{arch}: non-finite grads"
    # loss should be near ln(vocab) at init
    assert abs(float(M.loss_fn(params, batch, cfg)[1]["loss"]) - np.log(cfg.vocab_size)) < 2.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)
    h, aux = M.forward_hidden(params, batch, cfg)
    assert h.shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, dtype=np.float32)))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).causal])
def test_smoke_decode_matches_forward(arch):
    """Teacher-forced decode logits == full-forward logits (per position)."""
    from repro.models.layers import rms_norm
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    B, S, EXTRA = 2, 16, 4
    if cfg.frontend != "none":
        embeds = jax.random.normal(key, (B, S + EXTRA, cfg.d_model), jnp.float32)
        full_batch = dict(embeds=embeds)
        prefill_batch = dict(embeds=embeds[:, :S])
        def tok(i):
            return embeds[:, S + i]
    else:
        toks = jax.random.randint(key, (B, S + EXTRA), 0, cfg.vocab_size)
        full_batch = dict(tokens=toks)
        prefill_batch = dict(tokens=toks[:, :S])
        def tok(i):
            return toks[:, S + i]
    h, _ = M.forward_hidden(params, full_batch, cfg)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    full_logits = np.asarray((h @ M._head_weight(params, cfg)).astype(jnp.float32))
    logits, caches = M.prefill(params, prefill_batch, cfg, max_len=S + EXTRA)
    np.testing.assert_allclose(np.asarray(logits), full_logits[:, S - 1],
                               atol=2e-4, rtol=2e-4)
    for i in range(EXTRA):
        logits, caches = M.decode_step(params, tok(i), caches,
                                       jnp.int32(S + i), cfg)
        np.testing.assert_allclose(np.asarray(logits), full_logits[:, S + i],
                                   atol=2e-4, rtol=2e-4,
                                   err_msg=f"{arch} decode step {i}")


def test_encoder_only_prefill_logits():
    cfg = reduced(get_config("hubert-xlarge"))
    key = jax.random.PRNGKey(3)
    params = M.init_params(cfg, key)
    B, S = 2, 24
    logits, cache = M.prefill(params, _batch(cfg, key, B, S), cfg, max_len=S)
    assert cache is None
    assert logits.shape == (B, S, cfg.vocab_size)


def test_param_count_full_configs_match_published():
    expect = {
        "qwen2-7b": 7.6e9, "qwen2-vl-7b": 7.6e9, "falcon-mamba-7b": 7.3e9,
        "gemma-2b": 2.5e9, "gemma3-12b": 11.8e9, "grok-1-314b": 316e9,
        "kimi-k2-1t-a32b": 1.04e12, "jamba-v0.1-52b": 49.5e9,
        "h2o-danube-3-4b": 4.0e9, "hubert-xlarge": 1.26e9,
    }
    for name, target in expect.items():
        got = get_config(name).param_count()
        assert abs(got - target) / target < 0.05, f"{name}: {got:.3e} vs {target:.3e}"
