"""Mamba chunked scan vs sequential oracle; MoE dispatch vs dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.mamba import selective_scan_chunked, selective_scan_ref
from repro.models.moe import capacity, moe_forward, moe_ref


def _ssm_inputs(key, B, L, Di, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, L, Di), jnp.float32)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (B, L, Di)) * 0.5)
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.3)
    B_t = jax.random.normal(ks[3], (B, L, N), jnp.float32)
    C_t = jax.random.normal(ks[4], (B, L, N), jnp.float32)
    D = jnp.ones((Di,), jnp.float32)
    return x, delta, A, B_t, C_t, D


@pytest.mark.parametrize("L,chunk", [(16, 4), (33, 8), (64, 64), (7, 16)])
def test_chunked_scan_matches_ref(L, chunk):
    x, delta, A, B_t, C_t, D = _ssm_inputs(jax.random.PRNGKey(0), 2, L, 8, 4)
    y_ref = selective_scan_ref(x, delta, A, B_t, C_t, D)
    y, h = selective_scan_chunked(x, delta, A, B_t, C_t, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4, rtol=1e-4)


def test_chunked_scan_carry_continuation():
    """Scanning [0:L1] then [L1:L] with the carried state == scanning [0:L]."""
    L, L1 = 24, 16
    x, delta, A, B_t, C_t, D = _ssm_inputs(jax.random.PRNGKey(1), 2, L, 8, 4)
    y_full, _ = selective_scan_chunked(x, delta, A, B_t, C_t, D, chunk=8)
    y1, h1 = selective_scan_chunked(x[:, :L1], delta[:, :L1], A, B_t[:, :L1],
                                    C_t[:, :L1], D, chunk=8)
    y2, _ = selective_scan_chunked(x[:, L1:], delta[:, L1:], A, B_t[:, L1:],
                                   C_t[:, L1:], D, chunk=8, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 40), st.sampled_from([2, 4, 8]), st.sampled_from([4, 8]))
def test_chunked_scan_property(L, chunk, N):
    x, delta, A, B_t, C_t, D = _ssm_inputs(jax.random.PRNGKey(L * 7 + N), 1, L, 4, N)
    y_ref = selective_scan_ref(x, delta, A, B_t, C_t, D)
    y, _ = selective_scan_chunked(x, delta, A, B_t, C_t, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4, rtol=2e-4)


class _MoeCfg:
    def __init__(self, D, E, k, F, cf):
        self.d_model, self.n_experts, self.experts_per_token = D, E, k
        self.moe_d_ff, self.capacity_factor, self.mlp_act = F, cf, "silu"


def _moe_setup(key, D=32, E=8, F=16):
    ks = jax.random.split(key, 4)
    params = dict(router=jax.random.normal(ks[0], (D, E)) * 0.1,
                  wg=jax.random.normal(ks[1], (E, D, F)) * 0.1,
                  wu=jax.random.normal(ks[2], (E, D, F)) * 0.1,
                  wd=jax.random.normal(ks[3], (E, F, D)) * 0.1)
    return params


def test_moe_unbounded_capacity_matches_dense():
    cfg = _MoeCfg(32, 8, 2, 16, cf=8.0)  # capacity >= S*k/E*8: no drops
    params = _moe_setup(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 24, 32))
    y, aux = moe_forward(params, x, cfg)
    yref = moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-5, rtol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_bounded():
    """With cf=1.0 some tokens may drop, but output stays finite and close."""
    cfg = _MoeCfg(32, 8, 2, 16, cf=1.0)
    params = _moe_setup(jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 64, 32))
    y, _ = moe_forward(params, x, cfg)
    assert np.all(np.isfinite(np.asarray(y)))
    # dropped-token fraction is bounded by construction: relative deviation small
    yref = moe_ref(params, x, cfg)
    rel = (jnp.linalg.norm(y - yref) / jnp.linalg.norm(yref))
    assert float(rel) < 0.5


def test_moe_grad_flows():
    cfg = _MoeCfg(16, 4, 2, 8, cf=2.0)
    params = _moe_setup(jax.random.PRNGKey(2), 16, 4, 8)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 16, 16))

    def f(p):
        y, aux = moe_forward(p, x, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(f)(params)
    for k, v in g.items():
        assert np.all(np.isfinite(np.asarray(v))), k
        assert float(jnp.abs(v).max()) > 0, f"zero grad for {k}"


def test_capacity_formula():
    assert capacity(4096, 384, 8, 1.25) == 107
    assert capacity(1, 384, 8, 1.25) == 1
