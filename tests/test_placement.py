"""Edge cases of the §3 placement/degraded-operation guarantees.

The discrepancy-property floor is only meaningful above a per-radix alpha
threshold, must recover the full-graph Fiedler/Ramanujan bound at alpha = 1,
and ``empirical_subset_bw`` is the measured fallback for topologies that
carry no such guarantee — each regime is pinned here.
"""
import numpy as np
import pytest

from repro.core import bounds as B
from repro.core import placement as PL
from repro.core import topologies as T
from repro.core.spectral import algebraic_connectivity


@pytest.mark.parametrize("k", [3, 4, 6, 17])
def test_min_alpha_is_the_zero_crossing(k):
    """At the threshold alpha the closed-form floor is exactly zero; below it
    the raw bound goes negative (and the guarantee clamps to 0)."""
    a_min = PL.min_alpha_for_positive_guarantee(k)
    assert 0.0 < a_min < 1.0
    n = 1024
    assert B.active_subset_bw_lb(a_min, n, k) == pytest.approx(0.0, abs=1e-6)
    assert B.active_subset_bw_lb(a_min - 0.05, n, k) < 0.0
    assert B.active_subset_bw_lb(min(a_min + 0.05, 1.0), n, k) > 0.0


@pytest.mark.parametrize("k", [4, 6])
def test_guarantee_clamps_below_threshold(k):
    """At or below the threshold the *guarantee* is 0 (usable floor), never
    negative, and the record keeps the requested alpha/node count."""
    a_min = PL.min_alpha_for_positive_guarantee(k)
    for alpha in (a_min, a_min / 2, 0.1):
        g = PL.ramanujan_placement_guarantee(n=512, k=k, alpha=alpha)
        assert g.guaranteed_bisection_edges == pytest.approx(0.0, abs=1e-4)
        assert g.nodes_active == int(alpha * 512)
    above = PL.ramanujan_placement_guarantee(n=512, k=k,
                                             alpha=min(a_min + 0.05, 1.0))
    assert above.guaranteed_bisection_edges > 0.0


@pytest.mark.parametrize("k", [3, 4, 6, 17])
def test_alpha_one_recovers_full_graph_bound(k):
    """alpha = 1 (every node active) degenerates to the full-graph Ramanujan
    bisection floor — the Theorem-2 Fiedler bound at the Ramanujan rho2."""
    n = 1024
    full = B.active_subset_bw_lb(1.0, n, k)
    assert full == pytest.approx(B.ramanujan_bw_lb(n, k), rel=1e-12)
    assert full == pytest.approx(B.fiedler_bw_lb(n, B.ramanujan_rho2(k)),
                                 rel=1e-12)


def test_empirical_subset_bw_complete_graph_closed_form():
    """On K_n every balanced split of an na-subset cuts exactly
    floor(na/2) * ceil(na/2) edges — the empirical probe must find exactly
    that, for any seed."""
    g = T.complete(12)
    for alpha in (0.5, 1.0):
        na = max(2, int(alpha * g.n))
        expect = (na // 2) * (na - na // 2)
        for seed in (0, 7):
            assert PL.empirical_subset_bw(g, alpha, trials=4, seed=seed) \
                == expect


def test_empirical_subset_bw_deterministic_and_monotone_in_trials():
    g = T.torus(6, 2)
    a = PL.empirical_subset_bw(g, 0.4, trials=16, seed=3)
    assert a == PL.empirical_subset_bw(g, 0.4, trials=16, seed=3)
    # same seed, more trials extends the same RNG stream: the min can only fall
    assert PL.empirical_subset_bw(g, 0.4, trials=64, seed=3) <= a


def test_empirical_subset_bw_tiny_alpha_floors_at_two_nodes():
    """alpha below 2/n still probes a 2-node subset (cut is 0 or the number
    of parallel links between the pair)."""
    g = T.cycle(16)
    worst = PL.empirical_subset_bw(g, alpha=0.01, trials=32, seed=0)
    assert worst in (0.0, 1.0)


def test_non_ramanujan_fallback_measures_the_missing_guarantee():
    """The paper's §3 contrast: a torus offers NO subset guarantee — the
    worst observed alpha-subset bisection collapses far below the full-graph
    Fiedler floor, while alpha = 1 (a true balanced bisection of all nodes)
    always sits at or above it."""
    g = T.torus(8, 2)
    rho2 = algebraic_connectivity(g)
    floor_full = B.fiedler_bw_lb(g.n, rho2)
    # full-graph split: a certified bisection, so >= the Theorem-2 floor
    assert PL.empirical_subset_bw(g, alpha=1.0, trials=8, seed=0) >= floor_full
    # scattered 30%-subsets: internal bandwidth collapses (the fallback
    # figure a scheduler must use where the discrepancy property is absent)
    worst = PL.empirical_subset_bw(g, alpha=0.3, trials=32, seed=0)
    assert worst < floor_full
