"""LPS construction + Ramanujan certificates + expansion bounds (§2.1, §3)."""
import math

import numpy as np
import pytest

from repro.core import bounds as B
from repro.core import spectral as S
from repro.core import topologies as T
from repro.core.properties import diameter
from repro.core.ramanujan import (alon_boppana_lb, is_ramanujan, legendre, lps,
                                  lps_size, ramanujan_bound)


@pytest.mark.parametrize("p,q", [(13, 5), (13, 17), (17, 13)])
def test_lps_is_ramanujan(p, q):
    g = lps(p, q)
    assert g.n == lps_size(p, q)
    assert g.radix == q + 1
    ok, lam = is_ramanujan(g)
    assert ok, f"lambda={lam} > {ramanujan_bound(q + 1)}"


def test_lps_bipartiteness_matches_legendre():
    g1 = lps(13, 17)   # legendre(17,13)=legendre(4,13)=1 -> PSL, non-bipartite
    assert legendre(17, 13) == 1 and not g1.meta["bipartite"]
    g2 = lps(13, 5)    # legendre(5,13)=-1 -> PGL, bipartite
    assert legendre(5, 13) == -1 and g2.meta["bipartite"]
    import networkx as nx
    assert nx.is_bipartite(g2.to_networkx())
    assert not nx.is_bipartite(g1.to_networkx())


def test_lps_connected():
    import networkx as nx
    assert nx.is_connected(lps(13, 5).to_networkx())


def test_alon_boppana():
    """lambda >= 2 sqrt(k-1)(1 - 2/D) - 2/D for any k-regular graph."""
    for g in [T.torus(5, 2), T.hypercube(5), lps(13, 17)]:
        k = g.radix
        D = diameter(g, vertex_transitive=True)
        lam = S.lambda_nontrivial(g)
        assert lam >= alon_boppana_lb(k, D) - 1e-8


def test_hypercube_not_ramanujan_for_large_d():
    # Q_d has lambda = d - 2; Ramanujan needs d-2 <= 2 sqrt(d-1): fails for d >= 10
    g = T.hypercube(10)
    ok, lam = is_ramanujan(g)
    assert not ok and abs(lam - 8) < 1e-8


def test_torus_far_from_ramanujan():
    """The paper's headline: deployed topologies are well-separated from optimal."""
    g = T.torus(16, 2)  # v5e pod ICI
    rho2 = S.algebraic_connectivity(g)
    assert rho2 < 0.3 * B.ramanujan_rho2(g.radix)
    # and the gap widens with scale (Theta(1/k^2) vs constant):
    g3 = T.torus(16, 3)  # v5p-class 3D torus ICI, radix 6
    assert S.algebraic_connectivity(g3) < 0.11 * B.ramanujan_rho2(g3.radix)


def test_abelian_cayley_expansion_decay():
    """Cioabă: fixed-radix abelian Cayley graphs cannot stay expanders."""
    rho = [S.algebraic_connectivity(T.torus(k, 2)) for k in (4, 8, 16, 32)]
    assert rho[0] > rho[1] > rho[2] > rho[3]
    assert rho[3] < 0.05  # Theta(1/k^2) decay at fixed radix 4


def test_discrepancy_property_on_lps():
    """e(X,Y) concentration (§3) for random subsets of an LPS graph."""
    g = lps(13, 17)
    k, n = g.radix, g.n
    rng = np.random.default_rng(0)
    for _ in range(20):
        sx, sy = rng.integers(10, n // 2, size=2)
        X = rng.choice(n, size=sx, replace=False)
        Y = rng.choice(n, size=sy, replace=False)
        e = g.edge_count_between(X, Y)
        bound = B.discrepancy_edge_bound(n, k, sx, sy)
        assert abs(e - k * sx * sy / n) <= bound + 1e-6


def test_active_subset_bandwidth_positive():
    from repro.core.placement import (min_alpha_for_positive_guarantee,
                                      ramanujan_placement_guarantee)
    k = 18
    a0 = min_alpha_for_positive_guarantee(k)
    g = ramanujan_placement_guarantee(n=4896, k=k, alpha=min(1.0, a0 * 1.2))
    assert g.guaranteed_bisection_edges > 0
    g2 = ramanujan_placement_guarantee(n=4896, k=k, alpha=a0 * 0.5)
    assert g2.guaranteed_bisection_edges == 0.0
