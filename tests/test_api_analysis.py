"""Analysis session + survey engine: laziness, backend auto-selection,
Lanczos batching, and the consumer-facing row/CSV/JSON contract."""
import json

import numpy as np
import pytest

from repro.api import (Analysis, DEFAULT_COLUMNS, TABLE1_COLUMNS, survey)
from repro.core import spectral as S
from repro.core import topologies as T


def test_analysis_dense_backend_small_n():
    a = Analysis("torus(6,2)")
    assert a.backend == "dense"
    assert a.rho2 == pytest.approx(2 * (1 - np.cos(2 * np.pi / 6)))
    assert len(a.spectrum) == 36
    assert a.diameter == 6
    assert a.ramanujan["is_ramanujan"] in (True, False)


def test_analysis_lanczos_backend_above_threshold():
    a = Analysis("torus(12,2)", dense_threshold=100)
    assert a.backend == "lanczos"
    dense = float(S.laplacian_spectrum(T.torus(12, 2))[1])
    assert a.rho2 == pytest.approx(dense, rel=1e-3)
    # full spectrum is a dense-only feature
    with pytest.raises(RuntimeError, match="dense"):
        a.spectrum
    # witnessed bisection still available: Ritz-approximated Fiedler sweep
    bw = a.bisection_witness
    assert bw >= a.bounds["fiedler_bw_lb"] - 1e-6
    assert bw <= a.bounds["first_moment_bw_ub"] + 1e-6


def test_analysis_memoizes():
    a = Analysis("hypercube(6)")
    r1 = a.rho2
    assert a.__dict__["rho2"] == r1          # cached_property populated
    assert a.fiedler is a.fiedler            # same object, not recomputed


def test_analysis_accepts_topology_and_spec():
    g = T.hypercube(5)
    assert Analysis(g).rho2 == pytest.approx(2.0)
    assert Analysis("hypercube(5)").rho2 == pytest.approx(2.0)


def test_analysis_irregular_graph():
    a = Analysis("path(7)")
    assert a.radix is None
    assert a.rho2 == pytest.approx(2 * (1 - np.cos(np.pi / 7)))
    with pytest.raises(RuntimeError, match="irregular"):
        a.ramanujan


def test_analysis_loop_regularized_lanczos():
    """data_vortex needs the gather_operands (padded-table) matvec path."""
    g = T.data_vortex(5, 4)
    dense = float(S.laplacian_spectrum(g)[1])
    a = Analysis(g, dense_threshold=10, lanczos_iters=150)
    assert a.backend == "lanczos"
    assert a.rho2 == pytest.approx(dense, abs=1e-3)


def test_report_contains_key_lines():
    rep = Analysis("slimfly(5)").report()
    for fragment in ["topology        : slimfly(5)", "rho2 (measured) : 5.00000",
                     "Ramanujan comparison", "backend         : dense"]:
        assert fragment in rep


def test_survey_rows_and_columns():
    res = survey(["torus(6,2)", "hypercube(5)"], columns=TABLE1_COLUMNS)
    assert len(res) == 2
    assert res.columns == TABLE1_COLUMNS
    for row in res:
        assert row["rho2_ok"] is True
        assert set(TABLE1_COLUMNS) == set(row)


def test_survey_routes_large_instances_through_lanczos():
    res = survey(["torus(6,2)", "torus(16,2)"], dense_threshold=100,
                 columns=["spec", "nodes", "backend", "rho2", "rho2_ok"])
    by_spec = {r["spec"]: r for r in res}
    assert by_spec["torus(6,2)"]["backend"] == "dense"
    assert by_spec["torus(16,2)"]["backend"] == "lanczos"
    assert by_spec["torus(16,2)"]["rho2_ok"] is True


def test_survey_batches_same_shape_lanczos_solves():
    """Two same-(n, k) graphs share one vmapped solve; values match dense."""
    specs = ["torus(12,2)", "random_regular(144,4,seed=2)"]
    analyses = [Analysis(s, dense_threshold=50) for s in specs]
    res = survey(analyses, columns=["spec", "backend", "rho2"])
    # batching pre-populated the caches before row evaluation
    assert all("rho2" in a.__dict__ for a in analyses)
    ref = [float(S.laplacian_spectrum(T.torus(12, 2))[1]),
           float(S.laplacian_spectrum(T.random_regular(144, 4, seed=2))[1])]
    for row, expect in zip(res.rows, ref):
        assert row["backend"] == "lanczos"
        assert row["rho2"] == pytest.approx(expect, abs=2e-3)


def test_survey_unknown_column():
    with pytest.raises(KeyError, match="unknown survey column"):
        survey(["torus(6,2)"], columns=["nope"])


def test_survey_csv_json(tmp_path):
    res = survey(["torus(6,2)"], columns=["spec", "nodes", "rho2"])
    csv_path = tmp_path / "out.csv"
    text = res.to_csv(str(csv_path))
    assert csv_path.read_text() == text
    assert text.splitlines()[0] == "spec,nodes,rho2"
    # spec fields contain commas, so they are CSV-quoted
    assert text.splitlines()[1].startswith('"torus(6,2)",36,')
    import csv as csv_mod
    import io
    parsed = list(csv_mod.reader(io.StringIO(text)))
    assert parsed[1][0] == "torus(6,2)" and parsed[1][1] == "36"
    data = json.loads(res.to_json(str(tmp_path / "out.json")))
    assert data[0]["nodes"] == 36


def test_batched_rho2_matches_dense_for_loop_graphs():
    """gather_operands batching handles self-loop regularized graphs too."""
    topos = [T.data_vortex(5, 4), T.data_vortex(5, 4)]
    vals = S.rho2_lanczos_batched(topos, iters=150)
    dense = float(S.laplacian_spectrum(topos[0])[1])
    assert vals[0] == pytest.approx(dense, abs=1e-3)
    assert vals[1] == pytest.approx(dense, abs=1e-3)


def test_default_columns_all_known():
    res = survey(["slimfly(5)"])      # exercises DEFAULT_COLUMNS end to end
    assert res.columns == DEFAULT_COLUMNS
    assert res.rows[0]["rho2"] == pytest.approx(5.0)


def test_survey_use_pallas_kernel_matches_default_path():
    """survey(use_pallas_kernel=True) routes rho2 through the cayley_spmv
    kernel (interpret mode) and agrees with both the plain-jnp Lanczos path
    and the dense oracle."""
    specs = ["petersen", "cycle(12)"]
    kern = survey(specs, columns=["spec", "backend", "rho2"],
                  dense_threshold=4, use_pallas_kernel=True)
    plain = survey(specs, columns=["spec", "backend", "rho2"],
                   dense_threshold=4)
    dense = survey(specs, columns=["spec", "rho2"])
    assert all(r["backend"] == "lanczos" for r in kern.rows)
    for rk, rp, rd in zip(kern.rows, plain.rows, dense.rows):
        assert rk["rho2"] == pytest.approx(rp["rho2"], abs=1e-3)
        assert rk["rho2"] == pytest.approx(rd["rho2"], abs=1e-3)


def test_survey_use_pallas_kernel_skips_batched_grouping():
    """Same-shape kernel-routed specs must NOT be pre-solved by the plain
    batched Lanczos grouping — each row's matvec goes through the kernel
    (read from the ``spmv/matvec/<backend>`` counters of :mod:`repro.obs`)."""
    from repro import obs
    from repro.kernels import spmv as KS

    specs = ["random_regular(24,4,0)", "random_regular(24,4,1)"]
    before = obs.counters()
    kern = survey(specs, columns=["spec", "backend", "rho2"],
                  dense_threshold=4, use_pallas_kernel=True)
    delta = obs.counter_delta(before)
    # one kernel-resolved matvec closure per row, zero batched grouping
    assert delta.get("spmv/matvec/" + KS.kernel_backend(), 0) >= len(specs)
    assert delta.get("survey/lanczos_groups", 0) == 0
    plain = survey(specs, columns=["spec", "backend", "rho2"],
                   dense_threshold=4)
    for rk, rp in zip(kern.rows, plain.rows):
        assert rk["backend"] == "lanczos"
        assert rk["rho2"] == pytest.approx(rp["rho2"], abs=1e-3)


def test_analysis_use_pallas_kernel_rho2_on_loop_graph():
    """The kernel path must honor the padded gather contract (loop weights)."""
    g = T.data_vortex(4, 3)
    a = Analysis(g, dense_threshold=4, use_pallas_kernel=True)
    assert a.backend == "lanczos"
    expect = float(S.laplacian_spectrum(g)[1])
    assert a.rho2 == pytest.approx(expect, abs=2e-3)
