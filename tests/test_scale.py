"""Statistical contract of the sampled-source routing estimators.

The datacenter-scale path replaces all-sources BFS with
``analyze_routing(sample_fraction=...)``; these tests pin the contract that
makes the estimates trustworthy:

* the sampled diameter is a TRUE lower bound on the exact diameter, for
  every tier-1 bench family, across fractions and seeds;
* ``sample_fraction=1.0`` reproduces the exact analysis bit-for-bit
  (same dist/sigma matrices, same summary fields);
* the 95% bootstrap ``avg_hops_ci`` covers the exact average at >= the
  nominal rate across seeds (the bootstrap ignores the without-replacement
  variance reduction, so it is conservative by construction);
* sampling is deterministic in ``(n, s, seed)`` and cached results never
  alias across seeds or fractions (Analysis / survey plumbing);
* the sigma DP accumulates in float64 — the torus(32, 2) antipodal path
  count exceeds both int32 and float32-exact range (the old overflow).

The n=65536 smoke test runs under ``-m slow`` in its own CI job.
"""
import math

import numpy as np
import pytest

from repro.api import Analysis, build, survey
from repro.core import routing as R
from repro.core import traffic as TR

#: the tier-1 bench families (benchmarks/routing_eval.SPECS), all n <= 2184
TIER1_SPECS = [
    "lps(13,5)",
    "slimfly(13)",
    "torus(16,2)",
    "hypercube(8)",
    "ccc(6)",
    "butterfly(3,4)",
    "petersen_torus(5,4)",
    "dragonfly",
    "random_regular(256,6,0)",
]

_EXACT_CACHE = {}


def _exact(spec):
    if spec not in _EXACT_CACHE:
        _EXACT_CACHE[spec] = R.analyze_routing(build(spec))
    return _EXACT_CACHE[spec]


# --------------------------------------------------------------------------
# sample_sources
# --------------------------------------------------------------------------

def test_sample_sources_deterministic_and_sorted():
    a = R.sample_sources(100, 17, seed=4)
    b = R.sample_sources(100, 17, seed=4)
    c = R.sample_sources(100, 17, seed=5)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.array_equal(a, np.sort(a))
    assert np.unique(a).size == 17
    assert a.min() >= 0 and a.max() < 100


def test_sample_sources_full_coverage_is_arange():
    assert np.array_equal(R.sample_sources(50, 50, seed=9), np.arange(50))
    assert np.array_equal(R.sample_sources(50, 99, seed=9), np.arange(50))


def test_sample_sources_rejects_empty():
    with pytest.raises(ValueError):
        R.sample_sources(10, 0)


# --------------------------------------------------------------------------
# diameter lower bound + fraction=1.0 exactness, every tier-1 family
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spec", TIER1_SPECS)
def test_sampled_diameter_is_true_lower_bound(spec):
    exact = _exact(spec)
    for frac, seed in [(0.05, 0), (0.2, 1), (0.5, 2)]:
        r = R.analyze_routing(build(spec), sample_fraction=frac, seed=seed)
        assert r.diameter_lb == r.diameter
        assert r.diameter_lb <= exact.diameter, (spec, frac, seed)
        assert not r.exact or frac == 1.0


@pytest.mark.parametrize("spec", TIER1_SPECS)
def test_sample_fraction_one_reproduces_exact_bitwise(spec):
    exact = _exact(spec)
    r = R.analyze_routing(build(spec), sample_fraction=1.0, seed=123)
    assert r.exact is True
    assert np.array_equal(r.sources, exact.sources)
    assert np.array_equal(r.dist, exact.dist)
    assert np.array_equal(r.sigma, exact.sigma)
    assert r.diameter == exact.diameter == r.diameter_lb
    assert r.avg_path_length == exact.avg_path_length
    assert np.array_equal(r.hop_histogram, exact.hop_histogram)
    assert r.path_diversity_mean == exact.path_diversity_mean
    assert r.unreachable_pairs == exact.unreachable_pairs
    assert r.avg_hops_ci == (exact.avg_path_length, exact.avg_path_length)


# --------------------------------------------------------------------------
# bootstrap CI coverage
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spec,frac", [
    ("random_regular(256,6,0)", 0.25),
    ("petersen_torus(5,4)", 0.3),
    ("ccc(6)", 0.25),
])
def test_avg_hops_ci_covers_exact_at_nominal_rate(spec, frac):
    """One-sided binomial test that coverage is >= the nominal 95% rate.

    H0: per-seed coverage >= 0.95.  Over 40 independent seeds the lower
    0.5%-tail of Binomial(40, 0.95) is 33, so observing <= 33 hits rejects
    H0 at alpha ~ 0.003; a raw `hits/40 >= 0.95` cut would flake on
    binomial noise alone (P(hits <= 37 | p=.95) ~ 0.32).  Sources are drawn
    without replacement while the bootstrap resamples with replacement, so
    true coverage sits at or above nominal."""
    topo = build(spec)
    exact_avg = _exact(spec).avg_path_length
    seeds = range(40)
    hits = 0
    for seed in seeds:
        r = R.analyze_routing(topo, sample_fraction=frac, seed=seed)
        lo, hi = r.avg_hops_ci
        assert lo <= r.avg_path_length <= hi   # estimate inside its own CI
        hits += lo <= exact_avg <= hi
    assert hits >= 34, f"{spec}: coverage {hits}/40 rejects nominal 95%"


def test_vertex_transitive_ci_degenerates_to_truth():
    """Every source of a vertex-transitive graph has the same hop profile, so
    any sample is exact in expectation and the CI collapses onto it."""
    exact = _exact("hypercube(8)")
    r = R.analyze_routing(build("hypercube(8)"), sample_fraction=0.1, seed=3)
    lo, hi = r.avg_hops_ci
    assert lo == pytest.approx(exact.avg_path_length, rel=1e-12)
    assert hi == pytest.approx(exact.avg_path_length, rel=1e-12)


# --------------------------------------------------------------------------
# sigma float64 (the int32/float32 overflow regression)
# --------------------------------------------------------------------------

def test_sigma_survives_overflow_on_torus32():
    """torus(32, 2): the antipodal pair has 4 * C(32, 16) minimal paths
    (two shortest directions per even cycle x interleavings).  That count
    exceeds int32 AND is not float32-representable — the old accumulator
    could not return it.  One BFS source suffices to pin it."""
    want = 4 * math.comb(32, 16)            # 2,404,321,560
    assert want > 2 ** 31                   # int32 would overflow
    assert float(np.float32(want)) != want  # float32 would round
    topo = build("torus(32,2)")
    tab, _ = topo.gather_operands()
    dist = R.bfs_distances(tab, sources=[0])
    sigma = R.shortest_path_counts(tab, dist)
    antipode = 16 * 32 + 16                 # (16, 16) in row-major (32, 32)
    assert sigma[0, antipode] == want


def test_sigma_still_exact_small():
    """The float64 DP reproduces the known hypercube central count d!."""
    topo = build("hypercube(6)")
    tab, _ = topo.gather_operands()
    dist = R.bfs_distances(tab, sources=[0])
    sigma = R.shortest_path_counts(tab, dist)
    assert sigma[0, 63] == math.factorial(6)


# --------------------------------------------------------------------------
# seed determinism + cache-key isolation (Analysis / survey)
# --------------------------------------------------------------------------

def test_analyze_routing_deterministic_in_seed():
    topo = build("random_regular(128,4,0)")
    a = R.analyze_routing(topo, sample_fraction=0.25, seed=11)
    b = R.analyze_routing(topo, sample_fraction=0.25, seed=11)
    assert np.array_equal(a.sources, b.sources)
    assert np.array_equal(a.dist, b.dist)
    assert a.avg_hops_ci == b.avg_hops_ci
    c = R.analyze_routing(topo, sample_fraction=0.25, seed=12)
    assert not np.array_equal(a.sources, c.sources)


def test_analyze_routing_rejects_sources_plus_fraction():
    topo = build("torus(4,2)")
    with pytest.raises(ValueError):
        R.analyze_routing(topo, sources=[0, 1], sample_fraction=0.5)
    with pytest.raises(ValueError):
        R.analyze_routing(topo, sample_fraction=0.0)
    with pytest.raises(ValueError):
        R.analyze_routing(topo, sample_fraction=1.5)


def test_analysis_routing_cache_keys_dont_alias():
    a = Analysis("random_regular(128,4,0)", seed=0)
    exact = a.routing()
    s1 = a.routing(sample_fraction=0.25, seed=1)
    s2 = a.routing(sample_fraction=0.25, seed=2)
    s3 = a.routing(sample_fraction=0.5, seed=1)
    # same config returns the SAME cached object; different configs never do
    assert a.routing() is exact
    assert a.routing(sample_fraction=0.25, seed=1) is s1
    assert s1 is not s2 and s1 is not s3 and s2 is not s3
    assert not np.array_equal(s1.sources, s2.sources)
    assert s1.sources.size != s3.sources.size
    # default seed is the session's
    d = a.routing(sample_fraction=0.25)
    assert d is a.routing(sample_fraction=0.25, seed=0)


def test_analysis_traffic_cache_keys_dont_alias():
    a = Analysis("random_regular(128,4,0)", seed=0)
    t_exact = a.traffic("uniform")
    t1 = a.traffic("uniform", sample_fraction=0.25, seed=1)
    t2 = a.traffic("uniform", sample_fraction=0.25, seed=2)
    assert a.traffic("uniform") is t_exact
    assert a.traffic("uniform", sample_fraction=0.25, seed=1) is t1
    assert t1 is not t2
    assert t_exact.exact is True and t1.exact is False
    assert t1.sample_correction == pytest.approx(4.0)


def test_survey_threads_sampled_routing_config():
    rows = survey(["random_regular(128,4,0)"],
                  ["instance", "diameter_bfs", "diameter_lb", "avg_hops",
                   "avg_hops_ci"],
                  routing=dict(sample_fraction=0.25, seed=7)).rows
    row = rows[0]
    exact = R.analyze_routing(build("random_regular(128,4,0)"))
    assert row["diameter_lb"] <= exact.diameter
    lo, hi = row["avg_hops_ci"]
    assert lo <= row["avg_hops"] <= hi
    # same seed reproduces the row; a different seed may not
    again = survey(["random_regular(128,4,0)"],
                   ["instance", "avg_hops", "avg_hops_ci"],
                   routing=dict(sample_fraction=0.25, seed=7)).rows[0]
    assert again["avg_hops"] == row["avg_hops"]
    assert again["avg_hops_ci"] == row["avg_hops_ci"]


def test_survey_sampled_diameter_ok_means_lower_bound():
    """With a registered closed form, sampled diameter_ok asserts LB <= truth
    (not equality — the sample may miss the eccentric pair)."""
    rows = survey(["hypercube(8)"],
                  ["instance", "diameter_bfs", "diameter_ok"],
                  routing=dict(sample_fraction=0.05, seed=0)).rows
    assert rows[0]["diameter_ok"] is True


def test_sampled_traffic_unbiased_on_uniform():
    """Scaled sampled loads average to the exact loads over seeds (unbiased
    estimator of the per-link census) on a non-transitive family."""
    topo = build("random_regular(64,4,1)")
    exact_r = R.analyze_routing(topo)
    exact_t = TR.evaluate_traffic(topo, "uniform", routing=exact_r)
    acc = np.zeros_like(exact_t.link_loads)
    seeds = range(24)
    for seed in seeds:
        r = R.analyze_routing(topo, sample_fraction=0.25, seed=seed)
        acc += TR.evaluate_traffic(topo, "uniform", routing=r).link_loads
    mean = acc / len(list(seeds))
    # mean over 24 disjoint-ish samples approaches the census; loose tol
    assert np.abs(mean - exact_t.link_loads).max() < \
        0.35 * exact_t.max_link_load


def test_demand_rows_matches_demand_matrix_all_patterns():
    n = 64
    fied = np.sin(np.arange(n) * 0.37)
    srcs = np.array([0, 3, 17, 63])
    for pattern in TR.TRAFFIC_PATTERNS:
        kw = dict(fiedler=fied) if pattern == "adversarial" else {}
        D = TR.demand_matrix(pattern, n, **kw)
        rows = TR.demand_rows(pattern, n, srcs, **kw)
        assert np.array_equal(rows, D[srcs]), pattern
        full = TR.demand_rows(pattern, n, np.arange(n), **kw)
        assert np.array_equal(full, D), pattern


# --------------------------------------------------------------------------
# n=65536 smoke (dedicated CI job; excluded from tier-1 via the slow marker)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_full_survey_row_at_65536():
    """One complete survey row — rho2 + sampled routing + sampled traffic —
    at n=65536 (hypercube(16): cheap to build, diameter/avg-hops known in
    closed form, so every estimate is checked against ground truth)."""
    topo = build("hypercube(16)")
    assert topo.n == 65536
    a = Analysis(topo, lanczos_iters=48, seed=0)
    rho2 = a.rho2
    assert rho2 == pytest.approx(2.0, abs=5e-3)
    r = a.routing(sample_fraction=64 / 65536, seed=0)
    assert r.exact is False and r.sources.size == 64
    assert r.diameter_lb <= 16
    # vertex-transitive: any source sees the full eccentricity profile
    assert r.diameter_lb == 16
    exact_avg = 16 * 32768 / 65535    # sum_d d*C(16,d) / (2^16 - 1)
    lo, hi = r.avg_hops_ci
    assert lo <= exact_avg <= hi
    assert r.avg_path_length == pytest.approx(exact_avg, rel=1e-6)
    t = a.traffic("uniform", sample_fraction=64 / 65536, seed=0)
    assert t.exact is False
    assert t.conservation_error < 1e-4
    assert t.total_demand == pytest.approx(topo.n, rel=1e-3)
