"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.cayley_spmv.kernel import cayley_spmv
from repro.kernels.cayley_spmv.ref import spmv_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.kernel import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

FA_CASES = [
    dict(B=2, H=2, S=256, D=64, causal=True, dtype=jnp.float32),
    dict(B=1, H=4, S=128, D=128, causal=False, dtype=jnp.float32),
    dict(B=2, H=1, S=200, D=64, causal=True, dtype=jnp.float32),   # ragged
    dict(B=1, H=2, S=256, D=64, causal=True, dtype=jnp.bfloat16),
    dict(B=1, H=1, S=384, D=256, causal=True, dtype=jnp.float32),  # big head
]


@pytest.mark.parametrize("c", FA_CASES, ids=[str(i) for i in range(len(FA_CASES))])
def test_flash_attention_sweep(c):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    shape = (c["B"], c["H"], c["S"], c["D"])
    q = jax.random.normal(ks[0], shape, c["dtype"])
    k = jax.random.normal(ks[1], shape, c["dtype"])
    v = jax.random.normal(ks[2], shape, c["dtype"])
    out = flash_attention(q, k, v, causal=c["causal"], block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=c["causal"])
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[c["dtype"]], rtol=TOL[c["dtype"]])


@settings(max_examples=8, deadline=None)
@given(st.integers(65, 320), st.sampled_from([64, 128]), st.booleans())
def test_flash_attention_property(S, D, causal):
    key = jax.random.PRNGKey(S * 7 + D)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, S, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64, 256), (3, 17, 512), (1000, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],), dtype) + 1.0
    out = rmsnorm(x, w, interpret=True)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


# --------------------------------------------------------------------------
# cayley spmv
# --------------------------------------------------------------------------

def test_cayley_spmv_on_lps():
    from repro.core.ramanujan import lps
    g = lps(13, 5)
    tab = g.neighbor_table()
    x = jax.random.normal(jax.random.PRNGKey(2), (g.n,), jnp.float32)
    out = cayley_spmv(x, jnp.asarray(tab), block_rows=256, interpret=True)
    ref = spmv_ref(x, jnp.asarray(tab))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # against the dense adjacency oracle too
    dense = g.adjacency() @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(out), dense, atol=1e-3)


@pytest.mark.parametrize("n,k,block", [(100, 3, 32), (513, 6, 128), (64, 4, 64)])
def test_cayley_spmv_random_regular(n, k, block):
    from repro.core.topologies import random_regular
    g = random_regular(n if (n * k) % 2 == 0 else n + 1, k, seed=n)
    tab = g.neighbor_table()
    x = jax.random.normal(jax.random.PRNGKey(n), (g.n,), jnp.float32)
    out = cayley_spmv(x, jnp.asarray(tab), block_rows=block, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(spmv_ref(x, jnp.asarray(tab))),
                               atol=1e-5)


def test_cayley_spmv_with_loops():
    """Loop-regularized, edge-irregular graph via padded gather operands."""
    from repro.core.topologies import data_vortex
    g = data_vortex(4, 3)
    tab, w = g.gather_operands()
    x = jax.random.normal(jax.random.PRNGKey(9), (g.n,), jnp.float32)
    lw = jnp.asarray(w, jnp.float32)
    out = cayley_spmv(x, jnp.asarray(tab), lw, block_rows=16, interpret=True)
    ref = spmv_ref(x, jnp.asarray(tab), lw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    dense = g.adjacency() @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(out), dense, atol=1e-3)


def _even_regular(n, k, seed):
    """Random k-regular simple graph, bumping n once if n*k is odd."""
    from repro.core.topologies import random_regular
    return random_regular(n if (n * k) % 2 == 0 else n + 1, k, seed=seed)


@settings(max_examples=12, deadline=None)
@given(st.integers(20, 90), st.sampled_from([3, 4, 6]),
       st.sampled_from([8, 16, 33, 128]),
       st.sampled_from([jnp.float32, jnp.bfloat16]), st.booleans())
def test_cayley_spmv_property_vs_ref_and_dense(n, k, block, dtype, with_loops):
    """Randomized parity: kernel == jnp oracle == dense adjacency matvec over
    (n, k, block_rows, dtype, loops) — block sizes that do not divide n
    exercise the ragged (padded) last grid block."""
    g = _even_regular(n, k, seed=n * 7 + k)
    tab = g.neighbor_table()
    rng = np.random.default_rng(n * 13 + block)
    loops = jnp.asarray(rng.integers(0, 3, size=g.n), dtype) if with_loops \
        else None
    x = jax.random.normal(jax.random.PRNGKey(n + block), (g.n,), dtype)
    out = cayley_spmv(x, jnp.asarray(tab), loops, block_rows=block,
                      interpret=True)
    assert out.shape == (g.n,) and out.dtype == dtype
    ref = spmv_ref(x, jnp.asarray(tab), loops)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])
    A = g.adjacency()
    if with_loops:
        A[np.arange(g.n), np.arange(g.n)] += np.asarray(loops, np.float64)
    dense = A @ np.asarray(x, np.float64)
    tol = 1e-3 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(out, np.float32), dense,
                               atol=tol * k, rtol=tol)


@settings(max_examples=8, deadline=None)
@given(st.integers(16, 60), st.sampled_from([3, 5]),
       st.sampled_from([8, 24]), st.integers(1, 6))
def test_cayley_spmv_property_padded_gather_operands(n, k, block, drop):
    """Edge-irregular graphs through gather_operands: the self-index padding
    + compensating negative loop weights must cancel exactly in the kernel."""
    g = _even_regular(n, k, seed=n + k)
    edges = g.edges[: g.m - (drop % g.m)]          # drop edges -> irregular
    from repro.core.graphs import Topology
    h = Topology("ragged", g.n, edges)
    tab, w = h.gather_operands()
    lw = jnp.asarray(w, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(n * 3 + drop), (h.n,), jnp.float32)
    out = cayley_spmv(x, jnp.asarray(tab), lw, block_rows=block, interpret=True)
    ref = spmv_ref(x, jnp.asarray(tab), lw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    dense = h.adjacency() @ np.asarray(x, np.float64)
    np.testing.assert_allclose(np.asarray(out), dense, atol=1e-3)


def test_lanczos_with_kernel_matvec():
    """End-to-end: Lanczos on the Pallas matvec reproduces rho2 of SlimFly."""
    from repro.core import spectral as S
    from repro.core.topologies import slimfly
    from repro.kernels.cayley_spmv.ops import kernel_matvec
    g = slimfly(5)
    mv = kernel_matvec(g.neighbor_table())
    lmax, _ = S.lanczos_extremes(mv, g.n, m=60,
                                 deflate_vectors=[np.ones(g.n)])
    rho2 = g.radix - lmax
    assert abs(rho2 - 5.0) < 1e-3


# --------------------------------------------------------------------------
# mamba scan
# --------------------------------------------------------------------------

MS_CASES = [
    dict(B=2, L=64, Di=32, N=8, chunk=16),
    dict(B=1, L=100, Di=16, N=4, chunk=32),   # ragged L
    dict(B=2, L=32, Di=64, N=16, chunk=32),
]


@pytest.mark.parametrize("c", MS_CASES, ids=[str(i) for i in range(len(MS_CASES))])
def test_mamba_scan_sweep(c):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (c["B"], c["L"], c["Di"]), jnp.float32)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (c["B"], c["L"], c["Di"])) * 0.5)
    A = -jnp.exp(jax.random.normal(ks[2], (c["Di"], c["N"])) * 0.3)
    B_t = jax.random.normal(ks[3], (c["B"], c["L"], c["N"]), jnp.float32)
    C_t = jax.random.normal(ks[4], (c["B"], c["L"], c["N"]), jnp.float32)
    D = jnp.ones((c["Di"],), jnp.float32)
    out = mamba_scan(x, delta, A, B_t, C_t, D, chunk=c["chunk"],
                     block_d=16, interpret=True)
    ref = mamba_scan_ref(x, delta, A, B_t, C_t, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)
