"""HLO roofline analyzer: parsing, trip-count scaling, collective accounting."""
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo, parse_module, roofline_terms

SYNTH = """\
HloModule test

%wide.body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %dot.1 = f32[128,256]{1,0} dot(%lhs, %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%add
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p2 = (s32[], f32[128,256]) parameter(0)
  %compare = pred[] compare(%a, %b), direction=LT
}

ENTRY %main (a: f32[128,64], b: f32[64,256]) -> f32[128,256] {
  %lhs = f32[128,64]{1,0} parameter(0)
  %rhs = f32[64,256]{1,0} parameter(1)
  %while.1 = (s32[], f32[128,256]) while(%init), condition=%cond, body=%wide.body, backend_config={"known_trip_count":{"n":"12"}}
  %all-gather.9 = f32[128,256]{1,0} all-gather(%small), dimensions={0}
}
"""


def test_parse_module_headers_and_instrs():
    comps, shapes, entry = parse_module(SYNTH)
    assert entry == "main"
    assert "wide.body" in comps and "cond" in comps
    assert shapes["dot.1"].startswith("f32[128,256]")


def test_trip_count_scaling():
    stats = analyze_hlo(SYNTH)
    # dot flops = 2 * 128*256 * 64 (contracting dim of lhs f32[128,64])
    expect_dot = 2 * 128 * 256 * 64
    assert abs(stats.flops - 12 * expect_dot) < 1e-6
    # all-reduce operand bytes x 12 trips
    ar = 128 * 256 * 4
    assert abs(stats.collective_bytes["all-reduce"] - 12 * ar) < 1e-6
    # entry-level all-gather counted once (output bytes)
    assert abs(stats.collective_bytes["all-gather"] - 128 * 256 * 4) < 1e-6


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 0.0, 0.0)
    assert t["dominant"] == "compute" and abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms(0.0, 0.0, 200e9)
    assert t["dominant"] == "collective" and abs(t["collective_s"] - 1.0) < 1e-9
