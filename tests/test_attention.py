"""Chunked (flash-style) attention vs naive softmax oracle."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import chunked_attention, decode_attention


def naive_attention(q, k, v, causal=True, window=None):
    B, Sq, H, hd = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, hd)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k).astype(jnp.float32) / math.sqrt(hd)
    qpos, kpos = jnp.arange(Sq), jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, hd)


CASES = [
    dict(B=2, S=32, H=4, Kv=2, hd=16, causal=True, window=None),
    dict(B=1, S=33, H=4, Kv=1, hd=8, causal=True, window=None),   # MQA + ragged
    dict(B=2, S=64, H=8, Kv=8, hd=8, causal=False, window=None),  # encoder MHA
    dict(B=2, S=48, H=4, Kv=2, hd=16, causal=True, window=16),    # SWA
    dict(B=1, S=40, H=2, Kv=2, hd=32, causal=True, window=8),
]


@pytest.mark.parametrize("case", CASES, ids=[str(i) for i in range(len(CASES))])
def test_chunked_matches_naive(case):
    key = jax.random.PRNGKey(0)
    B, S, H, Kv, hd = case["B"], case["S"], case["H"], case["Kv"], case["hd"]
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Kv, hd), jnp.float32)
    out = chunked_attention(q, k, v, causal=case["causal"], window=case["window"],
                            q_chunk=16, k_chunk=16)
    ref = naive_attention(q, k, v, case["causal"], case["window"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@settings(max_examples=12, deadline=None)
@given(st.integers(2, 6), st.integers(9, 70), st.sampled_from([1, 2, 4]),
       st.sampled_from([8, 16]), st.booleans(),
       st.sampled_from([None, 8, 24]))
def test_chunked_matches_naive_property(B, S, Kv, hd, causal, window):
    H = 4
    if H % Kv:
        return
    key = jax.random.PRNGKey(S * 131 + Kv)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Kv, hd), jnp.float32)
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=16, k_chunk=8)
    ref = naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_decode_attention_matches_last_row():
    key = jax.random.PRNGKey(7)
    B, S, H, Kv, hd = 2, 24, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Kv, hd), jnp.float32)
    ref = naive_attention(q, k, v, causal=True)
    out = decode_attention(q[:, -1:], k, v, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref[:, -1]),
                               atol=2e-5, rtol=2e-5)
