"""Topology synthesis subsystem: signed-objective parity with the dense
lifts.py oracle, lift/rewire search invariants, registry integration, and
end-to-end flow of designed topologies through the analysis stack."""
import numpy as np
import pytest

from repro.core import bounds as B
from repro.core import lifts as L
from repro.core import spectral as S
from repro.core import topologies as T
from repro.core.synthesis import (best_signing_batched, double_edge_swaps,
                                  lift_search, rewire_search,
                                  signed_slot_operands, synthesize)


# --------------------------------------------------------------------------
# signed-adjacency operands: gather-table form == dense lifts.py objective
# --------------------------------------------------------------------------

def test_signed_slot_operands_reproduce_dense_signed_adjacency():
    g = T.random_regular(20, 4, seed=3)
    table, edge_slot = signed_slot_operands(g)
    rng = np.random.default_rng(0)
    s = rng.choice([-1.0, 1.0], size=g.m)
    As = L._signed_adjacency(g, s)
    x = rng.normal(size=g.n)
    slot_signs = s[edge_slot]
    y = np.sum(slot_signs * x[table], axis=1)
    np.testing.assert_allclose(y, As @ x, atol=1e-12)


def test_signed_slot_operands_reject_loops_and_irregularity():
    with pytest.raises(ValueError, match="loop-free"):
        signed_slot_operands(T.path_looped(6))
    with pytest.raises(ValueError, match="edge-regular"):
        signed_slot_operands(T.path(5))


def test_signed_extremes_batched_match_dense_eigvals():
    """One vmapped solve over B signings == B dense signed eigensolves."""
    g = T.random_regular(24, 4, seed=1)
    table, edge_slot = signed_slot_operands(g)
    rng = np.random.default_rng(2)
    signings = rng.choice([-1.0, 1.0], size=(6, g.m))
    lmax, lmin = S.signed_extremes_batched(table, signings[:, edge_slot],
                                           iters=60, seed=5)
    for i in range(signings.shape[0]):
        ev = L._signed_eigvals(g, signings[i])
        assert lmax[i] == pytest.approx(ev[-1], abs=1e-3)
        assert lmin[i] == pytest.approx(ev[0], abs=1e-3)


def test_best_signing_batched_deterministic_and_valid():
    g = T.complete(6)
    s1, top1, rad1 = best_signing_batched(g, batch=8, steps=40, seed=4)
    s2, top2, rad2 = best_signing_batched(g, batch=8, steps=40, seed=4)
    np.testing.assert_array_equal(s1, s2)
    assert (top1, rad1) == (top2, rad2)
    assert set(np.unique(s1)) <= {-1.0, 1.0} and s1.shape == (g.m,)
    # reported values match the dense oracle on the returned signing
    assert rad1 == pytest.approx(L.signed_spectral_radius(g, s1), abs=1e-3)
    assert top1 <= rad1 + 1e-9


def test_best_signing_batched_refinement_no_worse_than_random():
    """Elitism: the SA-refined winner never scores above the best random
    candidate of the same seed (both are scored in the final exact solve)."""
    g = T.random_regular(16, 4, seed=0)
    _, top_refined, _ = best_signing_batched(g, batch=8, steps=60, seed=9)
    _, top_random, _ = best_signing_batched(g, batch=8, steps=0, seed=9)
    assert top_refined <= top_random + 1e-9


# --------------------------------------------------------------------------
# lift search
# --------------------------------------------------------------------------

def test_lift_search_reaches_target_and_tracks_trajectory():
    g, traj, evals = lift_search(32, 4, budget=240, batch=8, seed=0)
    assert g.n == 32 and g.radix == 4
    assert len(traj) == 1 + 2            # seed + 2 doublings (32 = 8 * 2^2)
    assert evals > 0
    # Bilu-Linial: trajectory is the running min of the predicted rho2
    assert all(b <= a + 1e-9 for a, b in zip(traj, traj[1:]))
    # prediction equals the measured gap of the final graph
    rho2 = float(S.laplacian_spectrum(g)[1])
    assert rho2 == pytest.approx(traj[-1], abs=2e-3)


def test_synthesize_lift_beats_matched_table1_family():
    res = synthesize(64, 4, method="lift", budget=400, batch=8, seed=0)
    assert res.n == 64 and res.k == 4
    assert res.topo.is_regular() and res.topo.radix == 4
    torus_rho2 = float(S.laplacian_spectrum(T.torus(8, 2))[1])   # n=64, k=4
    assert res.rho2 > 1.5 * torus_rho2
    assert res.gap_fraction > 1.0        # small graphs can beat the bound
    assert res.gap_fraction == pytest.approx(
        res.rho2 / B.ramanujan_rho2(4), abs=1e-9)


def test_synthesize_lift_unreachable_size_raises():
    with pytest.raises(ValueError, match="rewire"):
        synthesize(45, 4, method="lift")


def test_synthesize_validates_inputs():
    with pytest.raises(ValueError, match="k >= 3"):
        synthesize(16, 2)
    with pytest.raises(ValueError, match="unknown synthesis method"):
        synthesize(16, 4, method="bogus")
    with pytest.raises(ValueError, match="regular graph"):
        synthesize(15, 3, method="rewire")    # n*k odd


# --------------------------------------------------------------------------
# rewire search
# --------------------------------------------------------------------------

def test_double_edge_swaps_preserve_degrees_and_simplicity():
    g = T.random_regular(30, 4, seed=5)
    rng = np.random.default_rng(0)
    e = double_edge_swaps(g.edges, swaps=20, rng=rng)
    assert e.shape == g.edges.shape
    assert not np.array_equal(np.sort(e, axis=0), np.sort(g.edges, axis=0))
    deg = np.bincount(e.reshape(-1), minlength=g.n)
    np.testing.assert_array_equal(deg, np.full(g.n, 4))
    canon = {tuple(sorted(r)) for r in e.tolist()}
    assert len(canon) == e.shape[0]          # simple: no duplicate edges
    assert all(u != v for u, v in e)         # no loops


def test_rewire_search_monotone_and_reaches_non_lift_sizes():
    # n=50, k=3: halving gives n0=25 with 25*3 odd — no valid lift tower,
    # exactly the size class the rewiring method exists for
    with pytest.raises(ValueError):
        synthesize(50, 3, method="lift")
    res = synthesize(50, 3, method="rewire", budget=60, batch=5, seed=2)
    assert res.n == 50 and res.topo.radix == 3
    traj = res.trajectory
    assert all(b >= a - 1e-6 for a, b in zip(traj, traj[1:]))  # hill-climb
    assert res.rho2 >= traj[0] - 1e-6
    # deterministic in seed
    res2 = synthesize(50, 3, method="rewire", budget=60, batch=5, seed=2)
    np.testing.assert_array_equal(res.topo.edges, res2.topo.edges)


def test_rewire_search_improves_over_random_start():
    topo, traj, _ = rewire_search(40, 4, budget=120, batch=8, seed=0)
    assert traj[-1] > traj[0]
    dense = float(S.laplacian_spectrum(topo)[1])
    assert dense == pytest.approx(traj[-1], abs=2e-3)


# --------------------------------------------------------------------------
# registry + end-to-end analysis-stack integration
# --------------------------------------------------------------------------

def test_registered_families_build_from_specs():
    from repro.api import build, families

    assert "xpander" in families() and "rewired" in families()
    g = build("xpander(32,4,0,160)")
    assert g.n == 32 and g.radix == 4
    assert g.meta["family"] == "xpander"
    assert g.meta["spec"] == "xpander(32,4,0,160)"
    assert "synthesis" in g.meta and g.meta["synthesis"]["method"] == "lift"
    h = build("rewired(40,4,seed=1,budget=40)")
    assert h.n == 40 and h.radix == 4
    assert h.meta["synthesis"]["method"] == "rewire"


def test_synthesized_topology_flows_through_survey_faults_routing():
    """Acceptance: a designed topology runs the full analysis stack — survey
    with fault and routing columns — with no special-casing anywhere."""
    from repro.api import survey
    from repro.api.survey import FAULT_COLUMNS, ROUTING_COLUMNS

    res = survey(["rewired(40,4,1,40)", "torus(6,2)"],
                 columns=["spec", "nodes", "radix", "rho2", "rho2_ok"],
                 faults=dict(rate=0.05, samples=4),
                 routing=dict(pattern="uniform"))
    row = res.rows[0]
    assert row["nodes"] == 40 and row["radix"] == 4
    assert row["rho2"] > 0
    assert row["rho2_ok"] is None or row["rho2_ok"] is True
    for c in FAULT_COLUMNS + ROUTING_COLUMNS:
        assert c in row
    assert row["diameter_bfs"] >= 2
    assert row["saturation_throughput"] > 0
    assert 0.0 <= row["connectivity_prob"] <= 1.0


def test_analysis_accessors_on_synthesized_topology():
    from repro.api import Analysis

    a = Analysis("xpander(32,4,0,120)")
    assert a.family == "xpander"
    r = a.ramanujan
    assert r["rho2_ratio"] == pytest.approx(a.rho2 / B.ramanujan_rho2(4))
    sweep = a.fault_sweep(rates=[0.1], samples=4)
    assert sweep.rows[0]["rho2_mean"] <= a.rho2 + 1e-6
    assert a.routing().diameter >= 2


def test_xpander_like_batched_cutoff_path(monkeypatch):
    """Above DENSE_LIFT_CUTOFF, xpander_like switches to the batched search
    and still produces a valid near-expander lift tower."""
    monkeypatch.setattr(L, "DENSE_LIFT_CUTOFF", 8)
    seed = T.complete(6)
    g = L.xpander_like(seed, doublings=2, trials=8, seed=0)
    assert g.n == 24 and g.radix == 5
    assert len(g.meta["lift_lams"]) == 2
    # level 2 (n=12 > cutoff) went through the batched path; Bilu-Linial:
    # the tower's nontrivial spectrum is base union the signed spectra, so
    # the recorded radii must certify lambda(G) exactly
    lam = S.lambda_nontrivial(g)
    assert lam <= max(S.lambda_nontrivial(seed),
                      max(g.meta["lift_lams"])) + 1e-6
