"""Fault models + batched degraded-spectral sweeps (repro.core.faults)."""
import numpy as np
import pytest

from repro.api import Analysis, survey
from repro.core import faults as F
from repro.core import spectral as S
from repro.core import topologies as T


# --------------------------------------------------------------------------
# fault models
# --------------------------------------------------------------------------

def test_random_link_faults_seed_deterministic():
    g = T.torus(8, 2)
    a = F.random_link_faults(g, 0.1, seed=7)
    b = F.random_link_faults(g, 0.1, seed=7)
    c = F.random_link_faults(g, 0.1, seed=8)
    assert np.array_equal(a.failed_links, b.failed_links)
    assert not np.array_equal(a.failed_links, c.failed_links)
    assert a.n_failed_links == round(0.1 * g.m)


def test_random_node_faults_include_incident_links():
    g = T.hypercube(5)
    sc = F.random_node_faults(g, 0.2, seed=1)
    assert sc.n_failed_nodes == round(0.2 * g.n)
    dead = set(sc.failed_nodes.tolist())
    expect = {i for i, (u, v) in enumerate(g.edges)
              if u in dead or v in dead}
    assert set(sc.failed_links.tolist()) == expect


def test_adversarial_degree_attack_removes_claimed_nodes():
    """The degree adversary kills exactly the highest-degree routers, and the
    degraded graph contains none of their links."""
    g = T.fat_tree(3, 2)                      # genuinely irregular degrees
    deg = g.degrees(include_loops=False)
    sc = F.adversarial_degree_attack(g, 0.1)
    f = sc.n_failed_nodes
    assert f == round(0.1 * g.n)
    # every failed node's degree >= every survivor's degree
    alive = np.setdiff1d(np.arange(g.n), sc.failed_nodes)
    assert deg[sc.failed_nodes].min() >= deg[alive].max() - 1e-9
    d = F.apply_faults(g, sc)
    assert d.n == g.n - f
    # survivors' induced edge count matches the claimed removal exactly
    dead = np.zeros(g.n, dtype=bool)
    dead[sc.failed_nodes] = True
    kept = (~dead[g.edges[:, 0]]) & (~dead[g.edges[:, 1]])
    assert d.m == int(kept.sum()) == g.m - sc.n_failed_links


def test_adversarial_spectral_attack_removes_top_fiedler_edges():
    g = T.torus(8, 2)
    f = S.fiedler_vector(g)
    sc = F.adversarial_spectral_attack(g, 0.1, fiedler=f)
    energy = (f[g.edges[:, 0]] - f[g.edges[:, 1]]) ** 2
    t = sc.n_failed_links
    assert t == round(0.1 * g.m)
    # the claimed edge set carries at least as much Fiedler energy as any
    # other t-subset (i.e. it is the top-t set, modulo ties)
    claimed = np.sort(energy[sc.failed_links])
    top = np.sort(energy)[-t:]
    assert np.allclose(claimed, top)
    d = F.apply_faults(g, sc)
    assert d.m == g.m - t
    # and it is spectrally more damaging than a random cut of the same size
    rand = F.apply_faults(g, F.random_link_faults(g, 0.1, seed=0))
    assert S.laplacian_spectrum(d)[1] <= S.laplacian_spectrum(rand)[1] + 1e-9


def test_apply_faults_strips_healthy_only_meta():
    from repro.api import build

    g = build("torus(8,2)")                   # registry sets the tags
    assert g.meta.get("vertex_transitive")
    d = F.apply_faults(g, F.random_link_faults(g, 0.1, seed=0))
    assert "vertex_transitive" not in d.meta and "spec" not in d.meta
    assert d.meta["fault"]["kind"] == "link"


# --------------------------------------------------------------------------
# batched degraded solve vs dense oracle
# --------------------------------------------------------------------------

def test_stacked_operands_apply_exact_laplacian():
    g = T.fat_tree(3, 2)                      # irregular + loop-free
    scen = [F.random_link_faults(g, 0.15, seed=i) for i in range(4)]
    degraded = [F.apply_faults(g, s) for s in scen]
    tabs, ws, degs = F.stacked_operands(degraded)
    rng = np.random.default_rng(0)
    for i, d in enumerate(degraded):
        x = rng.normal(size=d.n)
        lx = degs[i] * x - (x[tabs[i]].sum(axis=1) + ws[i] * x)
        assert np.abs(lx - d.laplacian() @ x).max() < 1e-9


def test_batched_rho2_matches_dense_oracle():
    g = T.torus(8, 2)
    degraded = [F.apply_faults(g, F.random_link_faults(g, 0.12, seed=i))
                for i in range(8)]
    tabs, ws, degs = F.stacked_operands(degraded)
    got = S.rho2_laplacian_batched(tabs, ws, degs, iters=120, seed=0)
    want = np.array([S.laplacian_spectrum(d)[1] for d in degraded])
    assert np.abs(got - want).max() < 1e-3


def test_batched_rho2_flags_disconnection():
    """A sample cut into two components must report rho2 ~ 0."""
    g = T.cycle(32)
    sc = F.FaultScenario(kind="link", rate=2 / 32, seed=0,
                         failed_links=np.array([0, 16]),
                         failed_nodes=np.empty(0, dtype=np.int64))
    d = F.apply_faults(g, sc)
    assert F.connected_component_count(d.n, d.edges) == 2
    tabs, ws, degs = F.stacked_operands([d])
    got = S.rho2_laplacian_batched(tabs, ws, degs, iters=64, seed=0)
    assert got[0] < 1e-4


def test_connected_component_count_matches_networkx():
    import networkx as nx

    g = T.torus(6, 2)
    d = F.apply_faults(g, F.random_link_faults(g, 0.4, seed=5))
    want = nx.number_connected_components(d.to_networkx())
    assert F.connected_component_count(d.n, d.edges) == want


# --------------------------------------------------------------------------
# sweeps: determinism + analytic bounds
# --------------------------------------------------------------------------

def test_fault_sweep_seed_deterministic():
    g = T.hypercube(6)
    a = F.fault_sweep(g, rates=(0.05, 0.15), samples=8, seed=3, iters=80)
    b = F.fault_sweep(g, rates=(0.05, 0.15), samples=8, seed=3, iters=80)
    c = F.fault_sweep(g, rates=(0.05, 0.15), samples=8, seed=4, iters=80)
    for ra, rb in zip(a.rows, b.rows):
        assert ra["rho2_mean"] == rb["rho2_mean"]
        assert ra["connectivity_prob"] == rb["connectivity_prob"]
    assert any(ra["rho2_mean"] != rc["rho2_mean"]
               for ra, rc in zip(a.rows, c.rows))


def test_interlacing_bound_upper_bounds_sampled_gap():
    """Link removal only subtracts PSD terms from L, so every sampled
    degraded rho2 must sit at or below the healthy value."""
    for g in (T.torus(8, 2), T.slimfly(5)):
        sweep = F.fault_sweep(g, rates=(0.02, 0.1, 0.25), model="link",
                              samples=16, seed=0, iters=100)
        for row in sweep.rows:
            assert row["interlacing_rho2_ub"] == pytest.approx(
                sweep.rho2_healthy)
            assert row["rho2_max"] <= row["interlacing_rho2_ub"] + 1e-3
            assert row["rho2_min"] >= row["weyl_rho2_lb"] - 1e-3


def test_fault_sweep_single_batched_solve_per_rate():
    g = T.torus(8, 2)
    sweep = F.fault_sweep(g, rates=(0.05, 0.1, 0.2), samples=32, seed=0,
                          iters=60)
    assert sweep.batched_solves == 3          # one vmapped call per rate
    assert all(r["samples"] == 32 for r in sweep.rows)


def test_fault_sweep_rejects_unknown_model():
    with pytest.raises(ValueError, match="unknown fault model"):
        F.fault_sweep(T.petersen(), model="meteor")


# --------------------------------------------------------------------------
# api surface
# --------------------------------------------------------------------------

def test_analysis_fault_sweep_uses_cached_healthy_rho2():
    a = Analysis("torus(8,2)")
    sweep = a.fault_sweep(rates=(0.1,), samples=4)
    assert sweep.rho2_healthy == pytest.approx(a.rho2)
    assert "rate" in sweep.rows[0] and "fault model" in sweep.report()


def test_survey_faults_appends_resilience_columns():
    res = survey(["torus(6,2)", "petersen"], faults=dict(rate=0.1, samples=4))
    for col in ("fault_rate", "rho2_degraded", "rho2_retention",
                "connectivity_prob", "bw_fiedler_lb_degraded"):
        assert col in res.columns
        assert all(col in r for r in res.rows)
    assert all(r["fault_rate"] == 0.1 for r in res.rows)
    assert all(r["rho2_degraded"] <= r["rho2"] + 1e-3 for r in res.rows)
