"""The universal padded gather-table spmv: parity, dispatch, kernel routing.

Parity: ``spmv_padded`` (interpret-mode Pallas on CPU) vs ``spmv_ref`` vs the
dense adjacency oracle across dtypes, ragged block_rows, signed operands, and
loop-regularized irregular graphs.  Dispatch: backend resolution order and the
``use_backend`` override.  Routing: trace-count proofs — read from the
``spmv/pallas_trace`` counter of :mod:`repro.obs` — that the spectral /
faults / synthesis / simulate engines actually apply their matvecs through
the kernel under the kernel backend, and fall back cleanly to the reference
path where Pallas cannot compile (CPU default).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import spectral as S
from repro.core import topologies as T
from repro.kernels import spmv as KS

RNG = np.random.default_rng(7)


def _random_regular(n, k, seed=0):
    return T.random_regular(n, k, seed=seed)


# --------------------------------------------------------------------------
# parity: kernel vs reference vs dense
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,block", [(30, 4, 8), (64, 6, 64), (50, 3, 16),
                                       (128, 8, 33)])
def test_spmv_padded_matches_ref_and_dense(n, k, block):
    g = _random_regular(n, k)
    tab, w = g.gather_operands()
    x = RNG.standard_normal(n).astype(np.float32)
    want = g.adjacency() @ x
    ref = KS.spmv_ref(jnp.asarray(x), jnp.asarray(tab, jnp.int32),
                      jnp.asarray(w, jnp.float32))
    ker = KS.spmv_padded(jnp.asarray(x), jnp.asarray(tab, jnp.int32),
                         jnp.asarray(w, jnp.float32), block_rows=block,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(ref), want, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ker), want, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("block", [7, 16, 40])
def test_spmv_padded_ragged_blocks(block):
    """n not divisible by block_rows: padded rows must be sliced off."""
    g = _random_regular(40, 4, seed=3)
    tab, w = g.gather_operands()
    x = RNG.standard_normal(40).astype(np.float32)
    ref = np.asarray(KS.spmv_ref(jnp.asarray(x), jnp.asarray(tab, jnp.int32),
                                 jnp.asarray(w, jnp.float32)))
    ker = np.asarray(KS.spmv_padded(
        jnp.asarray(x), jnp.asarray(tab, jnp.int32),
        jnp.asarray(w, jnp.float32), block_rows=block, interpret=True))
    assert ker.shape == (40,)
    np.testing.assert_allclose(ker, ref, atol=1e-5)


def test_spmv_padded_bfloat16():
    g = _random_regular(32, 4, seed=1)
    tab, _ = g.gather_operands()
    x = jnp.asarray(RNG.standard_normal(32), jnp.bfloat16)
    ref = KS.spmv_ref(x.astype(jnp.float32), jnp.asarray(tab, jnp.int32))
    ker = KS.spmv_padded(x, jnp.asarray(tab, jnp.int32), block_rows=16,
                         interpret=True)
    assert ker.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(ker, dtype=np.float32),
                               np.asarray(ref), atol=0.15)


def test_spmv_padded_loop_regularized_irregular_graph():
    """Self-padded table + negative compensation weights: exact adjacency on
    an irregular graph (the gather_operands contract)."""
    g = T.data_vortex(4, 3)            # irregular, loop-regularized family
    tab, w = g.gather_operands()
    x = RNG.standard_normal(g.n).astype(np.float32)
    want = g.adjacency() @ x
    ker = np.asarray(KS.spmv_padded(
        jnp.asarray(x), jnp.asarray(tab, jnp.int32),
        jnp.asarray(w, jnp.float32), block_rows=16, interpret=True))
    np.testing.assert_allclose(ker, want, atol=1e-4)


def test_spmv_signed_matches_ref_and_dense():
    """Per-slot signs: the Bilu–Linial signed adjacency through both paths."""
    from repro.core.synthesis import signed_slot_operands

    g = _random_regular(24, 4, seed=5)
    table, edge_slot = signed_slot_operands(g)
    signing = RNG.choice([-1.0, 1.0], size=g.m)
    sg = signing[edge_slot].astype(np.float32)
    # dense signed adjacency oracle
    A = np.zeros((g.n, g.n))
    for (u, v), s in zip(g.edges, signing):
        A[u, v] += s
        A[v, u] += s
    x = RNG.standard_normal(g.n).astype(np.float32)
    want = A @ x
    ref = np.asarray(KS.spmv_ref(jnp.asarray(x), jnp.asarray(table, jnp.int32),
                                 signs=jnp.asarray(sg)))
    ker = np.asarray(KS.spmv_padded(
        jnp.asarray(x), jnp.asarray(table, jnp.int32), None,
        jnp.asarray(sg), block_rows=8, interpret=True))
    np.testing.assert_allclose(ref, want, atol=1e-4)
    np.testing.assert_allclose(ker, want, atol=1e-4)


def test_spmv_dispatcher_and_matvec_agree():
    g = _random_regular(48, 5, seed=2)
    tab, w = g.gather_operands()
    x = jnp.asarray(RNG.standard_normal(48), jnp.float32)
    a = KS.spmv(x, jnp.asarray(tab, jnp.int32), jnp.asarray(w, jnp.float32),
                backend="ref")
    b = KS.spmv(x, jnp.asarray(tab, jnp.int32), jnp.asarray(w, jnp.float32),
                backend="pallas_interpret")
    mv = KS.spmv_matvec(tab, w, backend="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(mv(x)), np.asarray(a), atol=1e-6)


# --------------------------------------------------------------------------
# backend resolution
# --------------------------------------------------------------------------

def test_backend_resolution_order(monkeypatch):
    monkeypatch.delenv("REPRO_SPMV_BACKEND", raising=False)
    # auto: ref on CPU, pallas where it compiles
    auto = "pallas" if KS.pallas_supported() else "ref"
    assert KS.default_backend() == auto
    assert KS.resolve_backend() == auto
    # env overrides auto
    monkeypatch.setenv("REPRO_SPMV_BACKEND", "pallas_interpret")
    assert KS.resolve_backend() == "pallas_interpret"
    # context override beats env
    with KS.use_backend("ref"):
        assert KS.resolve_backend() == "ref"
        # explicit argument beats everything
        assert KS.resolve_backend("pallas_interpret") == "pallas_interpret"
    assert KS.resolve_backend() == "pallas_interpret"   # env restored


def test_backend_validation():
    with pytest.raises(ValueError):
        KS.resolve_backend("nope")
    with pytest.raises(ValueError):
        with KS.use_backend("nope"):
            pass


def test_kernel_backend_is_interpret_on_cpu():
    if jax.default_backend() == "cpu":
        assert not KS.pallas_supported()
        assert KS.kernel_backend() == "pallas_interpret"
        assert KS.default_backend() in ("ref", "pallas_interpret")
    else:                                          # pragma: no cover
        assert KS.kernel_backend() == "pallas"


# --------------------------------------------------------------------------
# engines route through the kernel (trace-count proofs) and fall back to ref
# --------------------------------------------------------------------------

def _pallas_traces(fn, backend):
    """Kernel traces caused by fn() under ``backend``, from cold caches (a
    cache hit replays a compiled trace without re-tracing), read from the
    ``spmv/pallas_trace`` counter of :mod:`repro.obs`."""
    with KS.use_backend(backend):               # clears jit caches on entry
        before = obs.counters()
        fn()
        return obs.counter_delta(before).get("spmv/pallas_trace", 0)


def _count_traces(fn):
    return _pallas_traces(fn, KS.kernel_backend())


def _count_ref(fn):
    return _pallas_traces(fn, "ref")


def test_spectral_routes_through_kernel():
    g = T.hypercube(5)
    assert _count_traces(lambda: S.rho2_lanczos(g, iters=20, seed=0)) > 0
    assert _count_ref(lambda: S.rho2_lanczos(g, iters=20, seed=0)) == 0


def test_batched_spectral_routes_through_kernel():
    g = T.hypercube(4)
    tab = g.neighbor_table()
    tabs = np.stack([tab] * 3)
    ws = np.zeros((3, g.n), np.float32)
    degs = np.full((3, g.n), 4.0, np.float32)

    def run():
        S.rho2_laplacian_batched(tabs, ws, degs, iters=12, seed=0)

    assert _count_traces(run) > 0
    assert _count_ref(run) == 0


def test_faults_route_through_kernel():
    from repro.core.faults import fault_sweep

    g = T.hypercube(4)

    def run():
        fault_sweep(g, rates=[0.05], model="link", samples=2, seed=0,
                    iters=12)

    assert _count_traces(run) > 0
    assert _count_ref(run) == 0


def test_synthesis_routes_through_kernel():
    from repro.core.synthesis import best_signing_batched

    g = T.petersen()

    def run():
        best_signing_batched(g, batch=3, steps=2, est_iters=4, iters=10,
                             seed=0)

    assert _count_traces(run) > 0
    assert _count_ref(run) == 0


def test_simulate_routes_through_kernel():
    from repro.core.simulate import simulate_collective

    g = T.torus(3, 2)

    def run():
        simulate_collective(g, "all_reduce", "ring", payloads=(1 << 16,))

    assert _count_traces(run) > 0
    assert _count_ref(run) == 0


def test_routing_sigma_routes_through_kernel():
    from repro.core.routing import analyze_routing

    g = T.torus(3, 2)
    assert _count_traces(lambda: analyze_routing(g)) > 0
    assert _count_ref(lambda: analyze_routing(g)) == 0


def test_traffic_routes_through_kernel():
    from repro.core.routing import analyze_routing
    from repro.core.traffic import evaluate_traffic

    g = T.torus(3, 2)

    def run():
        evaluate_traffic(g, "uniform", routing=analyze_routing(g))

    assert _count_traces(run) > 0
    assert _count_ref(run) == 0


def test_kernel_and_ref_agree_on_rho2():
    g = T.petersen_torus(3, 3)
    with KS.use_backend(KS.kernel_backend()):
        a = S.rho2_lanczos(g, iters=60, seed=0)
    with KS.use_backend("ref"):
        b = S.rho2_lanczos(g, iters=60, seed=0)
    assert a == pytest.approx(b, abs=1e-4)
