"""Config registry: exact assigned specs + shape-skip rules."""
import pytest

from repro.configs import SHAPES, cells_for, get_config, list_configs


def test_all_assigned_archs_registered():
    expect = {"qwen2-vl-7b", "jamba-v0.1-52b", "falcon-mamba-7b", "grok-1-314b",
              "kimi-k2-1t-a32b", "gemma3-12b", "h2o-danube-3-4b", "gemma-2b",
              "qwen2-7b", "hubert-xlarge"}
    assert expect <= set(list_configs())


def test_exact_assigned_specs():
    c = get_config("kimi-k2-1t-a32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff or c.moe_d_ff,
            c.vocab_size, c.n_experts, c.experts_per_token) == \
        (61, 7168, 64, 8, 2048, 163840, 384, 8)
    c = get_config("grok-1-314b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.moe_d_ff,
            c.vocab_size, c.n_experts, c.experts_per_token) == \
        (64, 6144, 48, 8, 32768, 131072, 8, 2)
    c = get_config("falcon-mamba-7b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size, c.ssm_state) == \
        (64, 4096, 0, 65024, 16)
    c = get_config("gemma-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.head_dim,
            c.d_ff, c.vocab_size) == (18, 2048, 8, 1, 256, 16384, 256000)
    c = get_config("hubert-xlarge")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size,
            c.causal) == (48, 1280, 16, 5120, 504, False)


def test_shape_cells_and_skips():
    # pure full-attention archs skip long_500k
    for a in ("qwen2-7b", "qwen2-vl-7b", "grok-1-314b", "kimi-k2-1t-a32b",
              "gemma-2b"):
        names = {s.name for s in cells_for(get_config(a))}
        assert "long_500k" not in names and "train_4k" in names
    # ssm/hybrid/swa run long_500k
    for a in ("falcon-mamba-7b", "jamba-v0.1-52b", "h2o-danube-3-4b",
              "gemma3-12b"):
        assert "long_500k" in {s.name for s in cells_for(get_config(a))}
    # encoder-only: no decode shapes
    names = {s.name for s in cells_for(get_config("hubert-xlarge"))}
    assert names == {"train_4k", "prefill_32k"}
    # total cells = 33
    total = sum(len(cells_for(get_config(a))) for a in
                ["qwen2-vl-7b", "jamba-v0.1-52b", "falcon-mamba-7b",
                 "grok-1-314b", "kimi-k2-1t-a32b", "gemma3-12b",
                 "h2o-danube-3-4b", "gemma-2b", "qwen2-7b", "hubert-xlarge"])
    assert total == 33


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
