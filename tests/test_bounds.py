"""Numerical validation of Table 1 + Theorems 1-3 on the constructed graphs.

This is the paper's core claim set: for every topology, the *measured* rho2 is
below the analytic upper bound, the witnessed bisection is inside
[Fiedler lower, analytic upper], and the measured diameter respects
Alon-Milman.
"""
import numpy as np
import pytest

from repro.core import bounds as B
from repro.core import spectral as S
from repro.core import topologies as T
from repro.core.properties import bisection_fiedler, diameter

CASES = [
    ("butterfly", dict(k=3, s=4), lambda: T.butterfly(3, 4), B.TABLE1["butterfly"](3, 4)),
    ("ccc", dict(d=4), lambda: T.cube_connected_cycles(4), B.TABLE1["ccc"](4)),
    ("clex", dict(k=3, ell=3), lambda: T.clex(3, 3), B.TABLE1["clex"](3, 3)),
    ("data_vortex", dict(A=5, C=4), lambda: T.data_vortex(5, 4), B.TABLE1["data_vortex"](5, 4)),
    ("hypercube", dict(d=6), lambda: T.hypercube(6), B.TABLE1["hypercube"](6)),
    ("petersen_torus", dict(a=5, b=4), lambda: T.petersen_torus(5, 4), B.TABLE1["petersen_torus"](5, 4)),
    ("slimfly", dict(q=5), lambda: T.slimfly(5), B.TABLE1["slimfly"](5)),
    ("torus", dict(k=6, d=2), lambda: T.torus(6, 2), B.TABLE1["torus"](6, 2)),
]


@pytest.mark.parametrize("name,params,builder,expect", CASES, ids=[c[0] for c in CASES])
def test_table1_nodes_radix(name, params, builder, expect):
    g = builder()
    assert g.n == expect["nodes"]
    assert abs(g.radix - expect["radix"]) < 1e-9


@pytest.mark.parametrize("name,params,builder,expect", CASES, ids=[c[0] for c in CASES])
def test_table1_rho2_upper_bound(name, params, builder, expect):
    g = builder()
    rho2 = S.algebraic_connectivity(g)
    assert rho2 <= expect["rho2_ub"] + 1e-6, f"{name}: {rho2} > {expect['rho2_ub']}"


@pytest.mark.parametrize("name,params,builder,expect", CASES, ids=[c[0] for c in CASES])
def test_table1_bisection_sandwich(name, params, builder, expect):
    """Fiedler LB <= witnessed bisection, and witnessed respects Theorem 3 + m/2."""
    g = builder()
    rho2 = S.algebraic_connectivity(g)
    bw_witness, _ = bisection_fiedler(g)
    assert bw_witness >= B.fiedler_bw_lb(g.n, rho2) - 1e-6
    assert bw_witness <= B.first_moment_bw_ub(g.m) + 1e-6
    k = g.degrees().max()
    assert bw_witness <= B.cheeger_bw_ub(g.n, k, rho2) + 1e-6


@pytest.mark.parametrize("name,params,builder,expect",
                         [c for c in CASES if c[0] in
                          ("hypercube", "torus", "slimfly", "data_vortex", "butterfly")],
                         ids=[c[0] for c in CASES if c[0] in
                              ("hypercube", "torus", "slimfly", "data_vortex", "butterfly")])
def test_table1_bw_upper_bound_has_witness(name, params, builder, expect):
    """The analytic BW upper bounds are real cuts: some balanced cut achieves <= bound."""
    g = builder()
    bw_witness, _ = bisection_fiedler(g)
    # Fiedler sweep may not find the optimal cut; it still must not beat a
    # *valid* upper bound by more than... it simply must satisfy >= BW >= LB.
    # The meaningful check: the analytic upper bound >= the true BW, so any
    # witnessed cut can only confirm BW <= witness; check bound >= min(witness, bound)
    assert expect["bw_ub"] <= B.first_moment_bw_ub(g.m) * 2  # sanity of the formula
    # explicit paper cuts: the dimension cut of Q_d achieves exactly 2^{d-1}
    # (the Fiedler sweep can miss it — rho2 = 2 has multiplicity d).
    if name == "hypercube":
        from repro.core.properties import bisection_witness
        dim_cut = (np.arange(g.n) & 1).astype(bool)   # split on bit 0
        assert bisection_witness(g, dim_cut) == expect["bw_ub"]
    if name == "torus":
        assert bw_witness <= 2 * expect["bw_ub"]


@pytest.mark.parametrize("name,params,builder,expect", CASES, ids=[c[0] for c in CASES])
def test_alon_milman_diameter(name, params, builder, expect):
    g = builder()
    rho2 = S.algebraic_connectivity(g)
    diam = diameter(g, vertex_transitive=False)
    assert diam <= B.alon_milman_diameter_ub(g.n, g.degrees().max(), rho2)
    assert diam >= B.mohar_diameter_lb(g.n, rho2) - 1e-9


GAP_CASES = [
    # the Ramanujan separation is asymptotic — test at production-relevant sizes
    ("torus", lambda: T.torus(16, 2)),
    ("ccc", lambda: T.cube_connected_cycles(6)),
    ("data_vortex", lambda: T.data_vortex(16, 5)),
    ("petersen_torus", lambda: T.petersen_torus(9, 8)),
    ("butterfly", lambda: T.butterfly(3, 8)),
]


@pytest.mark.parametrize("name,builder", GAP_CASES, ids=[c[0] for c in GAP_CASES])
def test_gap_to_ramanujan(name, builder):
    """The paper's conclusion: at scale, every surveyed topology has rho2 well
    below the Ramanujan value at equal radix."""
    g = builder()
    rho2 = S.algebraic_connectivity(g)
    assert rho2 < B.ramanujan_rho2(g.radix)


def test_fiedler_connectivity_bound():
    """kappa(G) >= rho2 (Fiedler) — check on a few graphs via networkx."""
    import networkx as nx
    for g in [T.hypercube(4), T.torus(4, 2), T.cube_connected_cycles(3)]:
        rho2 = S.algebraic_connectivity(g)
        kappa = nx.node_connectivity(nx.Graph(g.to_networkx()))
        assert kappa >= rho2 - 1e-8


def test_tanner_and_alon_milman_isoperimetric_chain():
    """Tanner LB on h(G) and Alon-Milman UB relation sanity on the hypercube."""
    g = T.hypercube(4)
    k = g.radix
    lam2 = np.sort(S.adjacency_spectrum(g))[-2]
    h_lb = B.tanner_isoperimetric_lb(k, lam2)
    assert -1e-9 <= h_lb <= k
    # Alon-Milman: k - lam2 >= h^2/(4+2h^2) with h >= h_lb
    assert k - lam2 >= B.alon_milman_gap_lb(h_lb) - 1e-9


# --------------------------------------------------------------------------
# golden values: Table-1 closed forms pinned to hard-coded paper numbers
# --------------------------------------------------------------------------

# The 9 bench families (benchmarks/fault_sweep.py SPECS).  These literals are
# the evaluated analytic expressions of bounds.py at the bench parameters; a
# regression in any closed form now fails a *named* test here instead of only
# tripping the bench-regression gate.
GOLDEN = [
    ("lps(13,5)", dict(nodes=2184, radix=6, rho2_lb=1.527864045000421)),
    ("slimfly(13)", dict(nodes=338, radix=19.0, rho2_ub=13.0, bw_ub=1105.0,
                         diameter=2, rho2_exact=True)),
    ("torus(16,2)", dict(nodes=256, radix=4, rho2_ub=0.1522409349774265,
                         bw_ub=32.0, diameter=16, rho2_exact=True)),
    ("hypercube(8)", dict(nodes=256, radix=8, rho2_ub=2.0, bw_ub=128.0,
                          diameter=8, rho2_exact=True)),
    ("ccc(6)", dict(nodes=384, radix=3, rho2_ub=0.17507707522447284,
                    bw_ub=32.0)),
    ("butterfly(3,4)", dict(nodes=324, radix=6, rho2_ub=6.0, bw_ub=162.0)),
    ("petersen_torus(5,4)", dict(nodes=200, radix=4,
                                 rho2_ub=1.2236067977499789, bw_ub=49.0)),
    ("dragonfly", dict(nodes=42, radix=6.0, rho2_ub=1.2, bw_ub=21.25)),
    ("random_regular(256,6,0)", dict(nodes=256, radix=6)),
]


@pytest.mark.parametrize("spec,golden", GOLDEN, ids=[g[0] for g in GOLDEN])
def test_table1_closed_forms_golden(spec, golden):
    from repro.api import parse_spec

    fam, bound = parse_spec(spec)
    forms = fam.forms(*bound[fam.params[0][0]]) if fam.variadic \
        else fam.forms(**bound)
    assert forms is not None, f"{spec}: no registered closed forms"
    assert set(forms) == set(golden), (
        f"{spec}: closed-form record keys changed: "
        f"{sorted(forms)} != {sorted(golden)}")
    for key, want in golden.items():
        got = forms[key]
        if isinstance(want, bool):
            assert got is want, f"{spec}.{key}: {got!r} != {want!r}"
        elif isinstance(want, float):
            assert got == pytest.approx(want, abs=1e-9), \
                f"{spec}.{key}: {got} != {want}"
        else:
            assert got == want, f"{spec}.{key}: {got} != {want}"
