"""Registry round-trip: every registered family builds from its spec string,
reports the expected n/radix, and its registered closed-form rho2 matches the
Analysis measurement on a small instance — the old TABLE1 consistency check,
now enforced uniformly for all families."""
import numpy as np
import pytest

from repro.api import (Analysis, REGISTRY, SpecError, build, closed_forms,
                       families, get, parse_spec)
from repro.core import bounds as B


ALL_FAMILIES = families()


def test_every_paper_family_is_registered():
    expected = {"path", "path_looped", "cycle", "complete", "petersen", "grid",
                "hypercube", "torus", "butterfly", "data_vortex", "ccc",
                "clex", "dragonfly", "slimfly", "petersen_torus", "fat_tree",
                "random_regular", "lps"}
    assert expected <= set(ALL_FAMILIES)


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_family_roundtrip(family):
    """build(default_instance) agrees with the registered closed forms."""
    fam = get(family)
    assert fam.default_instance, f"{family} needs a default_instance spec"
    g = build(fam.default_instance)
    assert g.meta["family"] == family
    a = Analysis(g)
    cf = a.closed_forms
    if cf is None:
        pytest.skip(f"{family} has no closed forms")
    assert g.n == cf["nodes"]
    if "radix" in cf:
        assert abs(g.radix - cf["radix"]) < 1e-9
    if "rho2_ub" in cf:
        if cf.get("rho2_exact"):
            assert abs(a.rho2 - cf["rho2_ub"]) < 1e-6 * max(1.0, cf["rho2_ub"])
        else:
            assert a.rho2 <= cf["rho2_ub"] + 1e-6
    if "rho2_lb" in cf:
        assert a.rho2 >= cf["rho2_lb"] - 1e-6


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_spec_string_roundtrip(family):
    """The spec stamped into meta re-parses to the same family + parameters."""
    fam = get(family)
    g = build(fam.default_instance)
    fam2, bound2 = parse_spec(g.meta["spec"])
    assert fam2.name == family
    g2 = fam2.build(**bound2) if not fam2.variadic else \
        fam2.build(*bound2[fam2.params[0][0]])
    assert g2.n == g.n and g2.m == g.m


def test_spec_parser_kwargs_and_positional():
    assert build("torus(6,2)").n == 36
    assert build("torus(k=6,d=2)").n == 36
    assert build("torus(6,d=2)").n == 36
    assert build("petersen").n == 10


def test_spec_parser_errors():
    with pytest.raises(SpecError, match="did you mean"):
        build("slimfily(5)")
    with pytest.raises(SpecError, match="no parameter"):
        build("torus(k=6,z=2)")
    with pytest.raises(SpecError, match="missing required"):
        build("torus(6)")
    with pytest.raises(SpecError, match="given twice"):
        build("torus(6,k=6)")
    with pytest.raises(SpecError, match="expected int"):
        build("torus(6.5,2)")
    with pytest.raises(SpecError):
        build("torus(6,2,3)")
    with pytest.raises(SpecError):
        build("")


def test_registry_defaults():
    g = build("fat_tree(3)")          # base_mult defaults to 1
    assert g.n == 15
    g2 = build("fat_tree(3,base_mult=2)")
    assert g2.m == 2 * g.m


def test_removed_alias_peterson_torus():
    """The misspelled alias finished its deprecation cycle: the registry
    rejects it (with a did-you-mean hint) and the module attribute is gone."""
    with pytest.raises(SpecError, match="petersen_torus"):
        build("peterson_torus(5,4)")

    import repro.core.topologies as T
    assert not hasattr(T, "peterson_torus")
    assert "peterson_torus" not in T.__all__
    # the correctly-spelled family still builds
    assert build("petersen_torus(5,4)").n == 200


def test_aliases_resolve():
    assert get("jellyfish").name == "random_regular"
    assert get("cube_connected_cycles").name == "ccc"
    assert get("generalized_grid").name == "grid"
    assert get("ramanujan").name == "lps"


def test_registry_absorbs_table1():
    """Registered closed forms agree with the legacy bounds.TABLE1 view."""
    cases = [
        ("butterfly", dict(k=3, s=4)),
        ("ccc", dict(d=4)),
        ("clex", dict(k=3, ell=3)),
        ("data_vortex", dict(A=5, C=4)),
        ("hypercube", dict(d=6)),
        ("petersen_torus", dict(a=5, b=4)),
        ("slimfly", dict(q=5)),
        ("torus", dict(k=6, d=2)),
    ]
    for name, params in cases:
        reg = closed_forms(name, **params)
        legacy = B.TABLE1[name](**params)
        for key, val in legacy.items():
            assert reg[key] == pytest.approx(val), (name, key)


def test_table1_removed_key_raises_helpful_error():
    with pytest.raises(KeyError, match="removed.*petersen_torus"):
        B.TABLE1["peterson_torus"]
    with pytest.raises(KeyError, match="known:"):
        B.TABLE1["no_such_family"]
    assert "peterson_torus" not in B.TABLE1


def test_variadic_grid():
    g = build("grid(3,4,2)")
    assert g.n == 24
    cf = closed_forms("grid", 3, 4, 2)
    assert cf["nodes"] == 24
    assert cf["rho2_ub"] == pytest.approx(2 * (1 - np.cos(np.pi / 4)))


def test_dragonfly_nested_spec():
    g = build("dragonfly(h='complete(6)')")
    assert g.n == 42 and g.radix == 6
    cf = closed_forms("dragonfly", h="complete(6)")
    assert cf["nodes"] == 42
    # generic H (non-complete): still get Corollary 2's rho2_ub
    cf2 = closed_forms("dragonfly", h="cycle(6)")
    assert cf2["nodes"] == 42
    assert cf2["rho2_ub"] == pytest.approx(1.0 + 6 / 12.0)


def test_build_stamps_meta():
    g = build("torus(6,2)")
    assert g.meta["family"] == "torus"
    assert g.meta["spec"] == "torus(6,2)"
    assert g.meta["vertex_transitive"] is True
