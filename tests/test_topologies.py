"""Construction invariants for every topology of paper §4."""
import numpy as np
import pytest

from repro.core import topologies as T
from repro.core import spectral as S


def test_path_spectrum():
    n = 7
    s = S.adjacency_spectrum(T.path(n))
    expect = np.sort([2 * np.cos(np.pi * j / (n + 1)) for j in range(1, n + 1)])
    np.testing.assert_allclose(np.sort(s), expect, atol=1e-9)


def test_path_looped_spectrum():
    n = 6
    s = S.adjacency_spectrum(T.path_looped(n))
    expect = np.sort([2 * np.cos(np.pi * j / n) for j in range(n)])
    np.testing.assert_allclose(np.sort(s), expect, atol=1e-9)


def test_cycle_spectrum():
    n = 8
    s = S.adjacency_spectrum(T.cycle(n))
    expect = np.sort([2 * np.cos(2 * np.pi * j / n) for j in range(n)])
    np.testing.assert_allclose(np.sort(s), expect, atol=1e-9)


def test_hypercube():
    q = T.hypercube(5)
    assert q.n == 32 and q.radix == 5
    # rho2 = 2 (well-known)
    assert abs(S.algebraic_connectivity(q) - 2.0) < 1e-8
    # adjacency spectrum: d - 2j with multiplicity C(d, j)
    s = np.sort(S.adjacency_spectrum(q))
    from math import comb
    expect = np.sort(sum([[5 - 2 * j] * comb(5, j) for j in range(6)], []))
    np.testing.assert_allclose(s, expect, atol=1e-8)


@pytest.mark.parametrize("k,d", [(3, 2), (4, 2), (5, 3)])
def test_torus(k, d):
    t = T.torus(k, d)
    assert t.n == k ** d and t.radix == 2 * d
    rho2 = S.algebraic_connectivity(t)
    assert abs(rho2 - 2 * (1 - np.cos(2 * np.pi / k))) < 1e-8


def test_generalized_grid():
    g = T.generalized_grid([3, 4, 2])
    assert g.n == 24
    rho2 = S.algebraic_connectivity(g)
    assert abs(rho2 - (2 - 2 * np.cos(np.pi / 4))) < 1e-8  # max k = 4


@pytest.mark.parametrize("k,s", [(2, 3), (3, 3), (3, 4), (4, 3)])
def test_butterfly(k, s):
    b = T.butterfly(k, s)
    assert b.n == s * k ** s
    assert b.radix == 2 * k
    # diameter s for the cyclic arrangement (paper: "diameter of s")
    from repro.core.properties import diameter
    assert diameter(b, vertex_transitive=False) <= 2 * s  # sanity envelope


@pytest.mark.parametrize("A,C", [(3, 3), (4, 3), (5, 4)])
def test_data_vortex(A, C):
    dv = T.data_vortex(A, C)
    assert dv.n == A * C * 2 ** (C - 1)
    assert dv.radix == 4  # after self-loop regularization
    # loop count: inner+outer rings = 2 * A * 2^(C-1)
    assert dv.loops.sum() == 2 * A * 2 ** (C - 1)


@pytest.mark.parametrize("d", [3, 4, 5])
def test_ccc(d):
    c = T.cube_connected_cycles(d)
    assert c.n == d * 2 ** d and c.radix == 3


def test_ccc_lemma2_exact():
    """Lemma 2: lambda_2(CC(G,d)) equals lambda_1 of G[s*] with exactly one -1
    loop.  Validated EXACTLY (the paper's Prop 3 closed form is only an order
    bound; see bounds._ccc)."""
    import itertools
    for d in (3, 4, 5):
        C = T.cycle(d).adjacency()
        ccc = T.cube_connected_cycles(d)
        lam2 = np.sort(S.adjacency_spectrum(ccc))[-2]
        s_star = np.ones(d)
        s_star[0] = -1.0
        lam1_sstar = np.linalg.eigvalsh(C + np.diag(s_star))[-1]
        assert abs(lam2 - lam1_sstar) < 1e-9


def test_ccc_theorem4():
    """Riess-Strehl-Wanka: chi(CC(G,d)) = prod_s chi(G[s])."""
    import itertools
    d = 4
    ccc = T.cube_connected_cycles(d)
    spec = np.sort(S.adjacency_spectrum(ccc))
    C = T.cycle(d).adjacency()
    ref = []
    for sv in itertools.product([-1, 1], repeat=d):
        ref.extend(np.linalg.eigvalsh(C + np.diag(sv)))
    np.testing.assert_allclose(spec, np.sort(ref), atol=1e-8)


@pytest.mark.parametrize("k,ell", [(3, 2), (3, 3), (4, 2), (5, 2)])
def test_clex_lemma3(k, ell):
    """CLEX adjacency == Lemma 3's Kronecker expression; degree = 2lk-k-1."""
    cl = T.clex(k, ell)
    assert cl.n == k ** ell
    assert cl.radix == 2 * ell * k - k - 1
    A = cl.adjacency()
    K = T.complete(k).adjacency()
    M = np.zeros((k * k, k * k))
    for i in range(k):
        for j in range(k):
            for a in range(k):
                for b in range(k):
                    M[i * k + j, a * k + b] = (i == b) + (j == a)
    ref = np.kron(K, np.eye(k ** (ell - 1)))
    for jj in range(ell - 1):
        ref += np.kron(np.kron(np.eye(k ** jj), M), np.eye(k ** (ell - 2 - jj)))
    np.testing.assert_allclose(A, ref, atol=1e-12)


def test_clex_lemma4_spectrum_of_M():
    k = 4
    M = np.zeros((k * k, k * k))
    for i in range(k):
        for j in range(k):
            for a in range(k):
                for b in range(k):
                    M[i * k + j, a * k + b] = (i == b) + (j == a)
    s = np.sort(np.linalg.eigvalsh(M))
    expect = np.sort([2 * k] + [k] * (k - 1) + [-k] * (k - 1) + [0] * ((k - 1) ** 2))
    np.testing.assert_allclose(s, expect, atol=1e-9)


@pytest.mark.parametrize("q", [5, 13])
def test_slimfly(q):
    sf = T.slimfly(q)
    assert sf.n == 2 * q * q
    assert sf.radix == (3 * q - 1) // 2
    # Proposition 9: rho2 EXACTLY q
    assert abs(S.algebraic_connectivity(sf) - q) < 1e-6
    # MMS graphs have diameter 2
    from repro.core.properties import diameter
    assert diameter(sf, vertex_transitive=False) == 2


@pytest.mark.parametrize("a,b", [(3, 3), (4, 3), (5, 2)])
def test_petersen_torus(a, b):
    pt = T.petersen_torus(a, b)
    assert pt.n == 10 * a * b and pt.radix == 4


def test_dragonfly():
    H = T.complete(6)
    df = T.dragonfly(H)
    assert df.n == 6 * 7
    assert df.radix == 6  # r + 1 = (|H|-1) + 1
    # one global link between every pair of groups
    groups = np.arange(df.n) // 6
    u, v = df.edges[:, 0], df.edges[:, 1]
    cross = groups[u] != groups[v]
    assert cross.sum() == 7 * 6 // 2


def test_g_connected_h_edge_condition():
    """Definition 10: e({v} x V_H, {v'} x V_H) = kt iff {v,v'} in E_G."""
    G = T.cycle(5)            # 2-regular
    H = T.cycle(6)            # 6 = t*d with t=3, d=2
    for k in (1, 2):
        g = T.g_connected_h(G, H, k=k)
        t = 3
        groups = np.arange(g.n) // H.n
        u, v = g.edges[:, 0], g.edges[:, 1]
        for (a, b) in G.edges:
            cnt = np.sum((groups[u] == a) & (groups[v] == b)) + \
                  np.sum((groups[u] == b) & (groups[v] == a))
            assert cnt == k * t
        # matching edges form a k-regular graph
        match = g.edges[groups[u] != groups[v]]
        deg = np.bincount(match.reshape(-1), minlength=g.n)
        assert np.all(deg == k)


def test_fat_tree_reduction_friendly():
    ft = T.fat_tree(3)
    assert ft.n == 15
    # leaves have degree base*2^0... root has 2 children with mult 4
    deg = ft.degrees()
    assert deg[0] == 8  # root: two child edges x mult 4


def test_random_regular():
    g = T.random_regular(64, 4, seed=1)
    assert g.radix == 4 and g.n == 64


def test_neighbor_table_matches_adjacency():
    for g in [T.hypercube(4), T.torus(4, 2), T.slimfly(5), T.butterfly(2, 3)]:
        tab = g.neighbor_table()
        A = g.adjacency()
        x = np.random.default_rng(0).normal(size=g.n)
        y_tab = x[tab].sum(axis=1)
        if g.loops is not None:
            y_tab = y_tab + g.loops * x
        np.testing.assert_allclose(y_tab, A @ x, atol=1e-9)
