"""2-lifts (Bilu-Linial / Xpander) + shard_map EP MoE exchange."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import spectral as S
from repro.core import topologies as T
from repro.core.lifts import (best_random_signing, k_lift,
                              signed_spectral_radius, two_lift, xpander_like)
from repro.core.ramanujan import ramanujan_bound


def test_two_lift_structure():
    g = T.complete(6)
    s = np.ones(g.m)
    lifted = two_lift(g, s)
    assert lifted.n == 12 and lifted.m == 2 * g.m
    assert lifted.radix == g.radix
    # all-parallel signing = two disjoint copies: spectrum doubled
    spec = np.sort(S.adjacency_spectrum(lifted))
    base = np.sort(S.adjacency_spectrum(g))
    np.testing.assert_allclose(spec, np.sort(np.concatenate([base, base])),
                               atol=1e-9)


def test_bilu_linial_spectral_identity():
    """spec(2-lift) = spec(A) ∪ spec(A_signed) — the core lift theorem."""
    rng = np.random.default_rng(0)
    g = T.random_regular(16, 4, seed=2)
    s = rng.choice([-1.0, 1.0], size=g.m)
    lifted = two_lift(g, s)
    spec_l = np.sort(S.adjacency_spectrum(lifted))
    A = g.adjacency()
    As = np.zeros_like(A)
    for (u, v), sg in zip(g.edges, s):
        As[u, v] += sg
        As[v, u] += sg
    expect = np.sort(np.concatenate([np.linalg.eigvalsh(A),
                                     np.linalg.eigvalsh(As)]))
    np.testing.assert_allclose(spec_l, expect, atol=1e-8)


def test_xpander_like_growth_keeps_expansion():
    """Grow K_6 by 3 doublings: 48 nodes, radix 5, near-Ramanujan signings."""
    seed = T.complete(6)
    g = xpander_like(seed, doublings=3, trials=48, seed=1)
    assert g.n == 48 and g.radix == 5
    lam = S.lambda_nontrivial(g)
    # each lift's new eigenvalues were kept near 2 sqrt(k-1)
    assert lam <= 1.35 * ramanujan_bound(5)
    assert all(l <= 1.35 * ramanujan_bound(5) for l in g.meta["lift_lams"])
    # still a strong expander: rho2 far above the torus at similar size/radix
    rho2 = S.algebraic_connectivity(g)
    assert rho2 > 2 * S.algebraic_connectivity(T.torus(7, 2))


def test_k_lift():
    g = T.complete(4)
    lifted = k_lift(g, 5, seed=3)
    assert lifted.n == 20 and lifted.radix == 3


def test_two_lift_preserves_regularity_doubles_counts():
    """Any signing: 2-lift doubles n and m, keeps every vertex degree."""
    rng = np.random.default_rng(7)
    for g in (T.petersen(), T.random_regular(14, 3, seed=1)):
        s = rng.choice([-1.0, 1.0], size=g.m)
        lifted = two_lift(g, s)
        assert lifted.n == 2 * g.n and lifted.m == 2 * g.m
        assert lifted.is_regular() and lifted.radix == g.radix


def test_k_lift_degree_preservation_irregular_base():
    """k-lift repeats the base degree sequence k times (even when irregular)."""
    g = T.path(5)                                   # degrees 1,2,2,2,1
    k = 4
    lifted = k_lift(g, k, seed=2)
    assert lifted.n == g.n * k and lifted.m == g.m * k
    base_deg = g.degrees()
    lift_deg = lifted.degrees()
    for v in range(g.n):
        np.testing.assert_array_equal(lift_deg[v * k:(v + 1) * k],
                                      np.full(k, base_deg[v]))


def test_best_random_signing_deterministic_under_fixed_seed():
    g = T.random_regular(12, 3, seed=4)
    for refine in (False, True):
        s1, lam1 = best_random_signing(g, trials=16, seed=5, refine=refine)
        s2, lam2 = best_random_signing(g, trials=16, seed=5, refine=refine)
        np.testing.assert_array_equal(s1, s2)
        assert lam1 == lam2
    # distinct seeds explore distinct signings (not a constant function)
    s3, _ = best_random_signing(g, trials=16, seed=6)
    s5, _ = best_random_signing(g, trials=16, seed=5)
    assert not np.array_equal(s3, s5)


def test_signed_radius_consistency_with_spectrum():
    """signed_spectral_radius == max |eig| of the signed adjacency."""
    g = T.complete(5)
    rng = np.random.default_rng(0)
    s = rng.choice([-1.0, 1.0], size=g.m)
    As = np.zeros((g.n, g.n))
    for (u, v), sg in zip(g.edges, s):
        As[u, v] += sg
        As[v, u] += sg
    assert signed_spectral_radius(g, s) == pytest.approx(
        float(np.max(np.abs(np.linalg.eigvalsh(As)))))


EP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.parallel.ep_moe import ep_moe_forward
from repro.models.moe import moe_forward

class Cfg:
    d_model=32; n_experts=8; experts_per_token=2; moe_d_ff=16
    capacity_factor=8.0; mlp_act="silu"; moe_dispatch_dtype="bfloat16"
cfg = Cfg()
key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 5)
params = dict(router=jax.random.normal(ks[0], (32, 8)) * 0.1,
              wg=jax.random.normal(ks[1], (8, 32, 16)) * 0.1,
              wu=jax.random.normal(ks[2], (8, 32, 16)) * 0.1,
              wd=jax.random.normal(ks[3], (8, 16, 32)) * 0.1)
x = jax.random.normal(ks[4], (4, 24, 32))
# reference: the GSPMD-path forward on one device
y_ref, _ = moe_forward(params, x, cfg)
# shard_map EP path on a 2x4 mesh
mesh = jax.make_mesh((2, 4), ("data", "model"))
xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
ps = dict(router=jax.device_put(params["router"], NamedSharding(mesh, P())),
          wg=jax.device_put(params["wg"], NamedSharding(mesh, P("model", None, None))),
          wu=jax.device_put(params["wu"], NamedSharding(mesh, P("model", None, None))),
          wd=jax.device_put(params["wd"], NamedSharding(mesh, P("model", None, None))))
y_ep = ep_moe_forward(mesh, ps, xs, cfg)
err = float(jnp.max(jnp.abs(y_ep.astype(jnp.float32) - y_ref.astype(jnp.float32))))
# count a2a in the lowered HLO
with mesh:
    lowered = jax.jit(lambda p, xx: ep_moe_forward(mesh, p, xx, cfg)).lower(ps, xs)
    hlo = lowered.compile().as_text()
print(json.dumps(dict(err=err, n_a2a=hlo.count("all-to-all"))))
"""


def test_ep_moe_matches_gspmd_path():
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    out = subprocess.run([sys.executable, "-c", EP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-4, res
    assert res["n_a2a"] >= 2, res   # explicit dispatch + return exchanges
