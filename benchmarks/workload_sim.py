"""Executed training workloads: does rho2 predict real step time?

``collective_sim`` executes synthetic schedules; this bench executes the
*full per-training-step communication plan* of real model configs
(:mod:`repro.core.workloads`) on all 9 bench families — DP gradient
all-reduces, TP all-gather/reduce-scatter streams, MoE all-to-all — and
ranks the families by simulated step time:

* for every workload, the plan's byte accounting is cross-checked against
  the independent ``launch/hlo_analysis`` parser
  (``hlo_crosscheck_ok`` required-true);
* ranks are placed **uniformly at random** (``placement="random"``, the
  placement-agnostic setting of the paper's discrepancy argument), and the
  simulated step time must rank-order the spectral five
  slimfly > hypercube > lps > torus > ccc consistently with rho2 for every
  workload (``step_time_rank_matches_spectral`` required-true) — the
  SpectralFly claim, observed on an executed training step;
* ``rank_correlation`` reports the Spearman correlation between the rho2
  ranking and the step-time ranking over all 9 families per workload.

Emits ``benchmarks/out/BENCH_workloads.json`` (gated in CI) and
``benchmarks/out/workload_sim.csv``.

    PYTHONPATH=src python -m benchmarks.workload_sim
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import List

from .collective_sim import DENSE_THRESHOLD, SPECS, SPECTRAL_ORDER

#: >= 3 model configs, all at world = 64 ranks so every family (including
#: the n=42 dragonfly, oversubscribed) hosts the same job: a 1T-scale MoE,
#: a dense 7B, and a 314B MoE with fewer, larger experts
WORKLOADS = [
    "kimi_k2_1t@dp=16,tp=4,ep=8",
    "qwen2_7b@dp=16,tp=4",
    "grok_1_314b@dp=16,tp=4,ep=8",
]

#: uniform-random placement — the paper's placement-agnostic setting, and
#: the one where topology (not rank locality) decides the step time
PLACEMENT = "random"


def run(out_json: str = "benchmarks/out/BENCH_workloads.json",
        out_csv: str = "benchmarks/out/workload_sim.csv") -> List[dict]:
    from repro.api import Analysis
    from repro.api.survey import csv_field
    from repro.core.workloads import (hlo_crosscheck, plan_workload,
                                      spectral_rank_correlation)

    from .calibrate import measure_calibration

    calibration = measure_calibration()
    t_all = time.time()
    plans = {w: plan_workload(w) for w in WORKLOADS}
    crosscheck_ok = True
    plan_details = {}
    for w, plan in plans.items():
        cc = hlo_crosscheck(plan)
        crosscheck_ok &= cc["ok"]
        plan_details[w] = dict(
            spec=plan.spec.spec, world=plan.world,
            tokens_per_step=plan.tokens_per_step,
            param_bytes=plan.param_bytes,
            compute_seconds=round(plan.compute_seconds, 6),
            phases=[dict(name=p.name, collective=p.collective,
                         group_axis=p.group_axis, group_size=p.group_size,
                         bytes_per_rank=p.bytes_per_rank,
                         ops_per_step=p.ops_per_step, dtype=p.dtype)
                    for p in plan.phases],
            hlo_crosscheck=cc)
    table: List[dict] = []
    rank_ok = True
    correlations = {}
    for spec in SPECS:
        a = Analysis(spec, dense_threshold=DENSE_THRESHOLD)
        for w, plan in plans.items():
            t0 = time.time()
            res = a.simulate(workload=plan, placement=PLACEMENT)
            table.append(dict(
                family=a.family or a.name,
                spec=spec,
                nodes=a.n,
                rho2=round(a.rho2, 5),
                workload=w,
                step_ms=round(res.step_seconds * 1e3, 4),
                compute_ms=round(res.compute_seconds * 1e3, 4),
                dp_ms=round(res.dp_seconds * 1e3, 4),
                tp_ms=round(res.tp_seconds * 1e3, 4),
                moe_ms=round(res.moe_seconds * 1e3, 4),
                exposed_frac=round(res.exposed_comm_fraction, 4),
                dropped_frac=round(res.dropped_frac, 6),
                seconds=round(time.time() - t0, 2),
            ))
    for w in WORKLOADS:
        rows = [r for r in table if r["workload"] == w]
        step = {r["spec"]: r["step_ms"] for r in rows}
        # faster step time on the better-gap family, pairwise down the five
        rank_ok &= all(step[a_] < step[b_] for a_, b_ in
                       zip(SPECTRAL_ORDER, SPECTRAL_ORDER[1:]))
        correlations[w] = round(
            spectral_rank_correlation(rows, step_key="step_ms"), 4)
    table.sort(key=lambda r: (r["workload"], r["step_ms"]))
    payload = dict(
        bench="workload_sim",
        total_seconds=round(time.time() - t_all, 3),
        calibration_seconds=round(calibration, 4),
        families=SPECS,
        workloads=WORKLOADS,
        placement=PLACEMENT,
        correctness=dict(
            cases=len(SPECS) * len(WORKLOADS),
            step_time_rank_matches_spectral=bool(rank_ok),
            hlo_crosscheck_ok=bool(crosscheck_ok),
            rank_correlation=correlations,
        ),
        workload_table=table,
        plans=plan_details,
    )
    p = pathlib.Path(out_json)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2))
    cols = list(table[0])
    pathlib.Path(out_csv).write_text("\n".join(
        [",".join(cols)]
        + [",".join(csv_field(row[c]) for c in cols) for row in table]))
    return table


if __name__ == "__main__":
    for row in run():
        print(row)
