"""Executed collectives & workloads: measure what the spectral model predicts.

Every earlier benchmark reports *predictions* (closed forms, static ECMP
loads, the (alpha, beta) NetworkModel).  This one **executes** schedules on
the links of all 9 bench families — the Ramanujan references ``lps(13,5)``
and the synthesized ``xpander(512,6)`` against the §4 survey — via
:mod:`repro.core.simulate`:

* ring all-reduce (64 MiB/node), measured completion time next to the
  NetworkModel analytic lower bound — ``ring_time_geq_model_lb`` asserts the
  certificate held on every family;
* topology-aware BFS-tree broadcast vs the oblivious binomial tree (and
  recursive halving/doubling where the node count is a power of two);
* an executed uniform all-to-all workload, whose measured saturation
  throughput must (a) agree with the static ECMP figure of
  ``BENCH_routing.json`` and (b) rank-order the spectral five
  slimfly > hypercube > lps > torus > ccc — the SpectralFly claim, observed
  on an executed schedule.

Emits ``benchmarks/out/BENCH_simulate.json`` (gated in CI, with the two
acceptance booleans required-true) and ``benchmarks/out/collective_sim.csv``.

    PYTHONPATH=src python -m benchmarks.collective_sim
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import List

# the 9 bench families: Ramanujan references (LPS + synthesized xpander)
# vs the paper's §4 survey topologies
SPECS = [
    "lps(13,5)",                  # Ramanujan reference (n=2184, k=6)
    "slimfly(13)",                # n=338
    "torus(16,2)",                # n=256
    "hypercube(8)",               # n=256
    "ccc(6)",                     # n=384
    "butterfly(3,4)",             # n=324
    "petersen_torus(5,4)",        # n=200
    "dragonfly",                  # n=42 (complete(6) routers)
    "xpander(512,6)",             # lift-synthesized expander (n=512, k=6)
]

#: the spectral ordering BENCH_routing.json measures for these five —
#: the executed workload must reproduce it
SPECTRAL_ORDER = ["slimfly(13)", "hypercube(8)", "lps(13,5)", "torus(16,2)",
                  "ccc(6)"]

PAYLOAD = float(1 << 26)          # 64 MiB per node

#: executed vs static ECMP throughput must agree to float32 accumulation
THPT_TOL = 1e-3

#: extra (multi-round ECMP-lowered) algorithms only below this node count —
#: each unique round is a full ECMP pass, which lps(13,5) would pay ~12x for
EXTRA_ALGO_MAX_N = 512

#: dense-oracle cutoff: route lps(13,5) through Lanczos (see routing_eval)
DENSE_THRESHOLD = 1024


def _round_opt(x, nd: int = 4):
    return None if x is None else round(float(x), nd)


def run(out_json: str = "benchmarks/out/BENCH_simulate.json",
        out_csv: str = "benchmarks/out/collective_sim.csv") -> List[dict]:
    from repro.api import Analysis
    from repro.api.survey import csv_field

    from .calibrate import measure_calibration

    calibration = measure_calibration()
    t_all = time.time()
    table: List[dict] = []
    details = {}
    ring_geq_model = True
    workload_matches = True
    for spec in SPECS:
        a = Analysis(spec, dense_threshold=DENSE_THRESHOLD)
        t0 = time.time()
        ring = a.simulate("all_reduce", "ring", payload=PAYLOAD,
                          telemetry=True)
        val = a.network_model().validate(ring)
        ring_geq_model &= val["all_measured_geq_predicted"]
        tree = a.simulate("broadcast", "bfs_tree", payload=PAYLOAD)
        uni = a.simulate("traffic", pattern="uniform", payload=PAYLOAD)
        static_thpt = a.traffic("uniform").saturation_throughput
        workload_matches &= abs(uni.saturation_throughput - static_thpt) \
            <= THPT_TOL * static_thpt
        binom = hd = None
        if a.n <= EXTRA_ALGO_MAX_N:
            binom = a.simulate("broadcast", "binomial", payload=PAYLOAD)
            if a.n & (a.n - 1) == 0:
                hd = a.simulate("all_reduce", "halving_doubling",
                                payload=PAYLOAD)
        secs = time.time() - t0
        vrow = val["rows"][0]
        table.append(dict(
            family=a.family or a.name,
            spec=spec,
            nodes=a.n,
            radix=a.radix,
            rho2=round(a.rho2, 5),
            ring_allreduce_ms=round(vrow["measured_s"] * 1e3, 4),
            model_allreduce_ms=round(vrow["predicted_s"] * 1e3, 4),
            ring_model_ratio=round(vrow["ratio"], 4),
            ring_geq_model=val["all_measured_geq_predicted"],
            ring_util_max=round(ring.utilization_max, 4),
            hd_allreduce_ms=_round_opt(
                None if hd is None else hd.time_seconds[0] * 1e3),
            bfs_tree_bcast_ms=round(float(tree.time_seconds[0]) * 1e3, 4),
            binomial_bcast_ms=_round_opt(
                None if binom is None else binom.time_seconds[0] * 1e3),
            thpt_uniform_sim=round(uni.saturation_throughput, 4),
            thpt_uniform_static=round(static_thpt, 4),
            seconds=round(secs, 2),
        ))
        tel = ring.telemetry
        details[spec] = dict(
            ring=ring.to_dict(), validate=val, bfs_tree=tree.to_dict(),
            workload_uniform=uni.to_dict(),
            ring_util_histogram=ring.utilization_histogram(),
            # per-round telemetry rollup: peak / mean directed-link
            # utilization over the executed ring rounds + the argmax
            # contended link (node, slot) — from RoundTelemetry, not a probe
            link_utilization=dict(
                rounds=int(tel.unique_rounds),
                util_max=round(float(tel.round_util_max.max()), 4),
                util_mean=round(float(tel.round_util_mean.mean()), 4),
                hot_link=[int(v) for v in tel.argmax_link()],
                max_round_ms=round(float(tel.round_seconds.max() * 1e3), 4)),
            binomial=None if binom is None else binom.to_dict(),
            halving_doubling=None if hd is None else hd.to_dict())
    thpt = {r["spec"]: r["thpt_uniform_sim"] for r in table}
    rank_ok = all(thpt[a_] > thpt[b_] for a_, b_ in
                  zip(SPECTRAL_ORDER, SPECTRAL_ORDER[1:]))
    table.sort(key=lambda r: -r["thpt_uniform_sim"])
    payload = dict(
        bench="collective_sim",
        total_seconds=round(time.time() - t_all, 3),
        calibration_seconds=round(calibration, 4),
        payload_bytes=PAYLOAD,
        families=SPECS,
        correctness=dict(
            cases=len(SPECS),
            ring_time_geq_model_lb=bool(ring_geq_model),
            thpt_rank_matches_spectral=bool(rank_ok),
            workload_matches_static_ecmp=bool(workload_matches),
        ),
        sim_table=table,
        details=details,
    )
    p = pathlib.Path(out_json)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2))
    cols = list(table[0])
    pathlib.Path(out_csv).write_text("\n".join(
        [",".join(cols)]
        + [",".join(csv_field(row[c]) for c in cols) for row in table]))
    return table


if __name__ == "__main__":
    for row in run():
        print(row)
