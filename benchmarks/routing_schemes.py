"""Non-minimal & adaptive routing schemes vs the MCF optimal-routing ceiling.

Minimal-path ECMP (what ``routing_eval`` measures) collapses on adversarial
permutation traffic: every flow insists on shortest paths, so a Fiedler-
matched permutation can focus all of them across one sparse cut.  This bench
measures what the alternative schemes recover on every family of the routing
set — Valiant load balancing (two minimal-ECMP legs through a random
intermediate), UGAL-style adaptive selection (per-pair minimal vs Valiant by
estimated channel load) and k-shortest-path non-minimal ECMP (paths up to
``dist+slack``) — and reports each against the linear-programming
multi-commodity-flow throughput ceiling ``thpt_mcf_ub``: the best any routing
scheme could do on that topology, so ``gap_to_opt`` separates routing loss
from the topological limit the spectral gap predicts.

Acceptance invariants (``required_true`` in CI):

* on every expander family (lps / slimfly / xpander) the non-minimal schemes
  beat minimal ECMP on adversarial traffic — Valiant's 2x average-load tax is
  worth paying when the adversary saturates the minimal paths;
* no scheme ever exceeds the MCF ceiling, on any family or pattern;
* the butterfly adversarial throughput is bit-identical across spmv backends
  (ref vs pallas_interpret) for all four schemes — the tie-sensitive
  degenerate-eigenspace regression this PR fixes.

Emits ``benchmarks/out/BENCH_routing_schemes.json`` (gated in CI) and
``benchmarks/out/routing_schemes.csv``.

    PYTHONPATH=src python -m benchmarks.routing_schemes
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import List

# the routing_eval coverage with the xpander expander swapped in for the
# random-regular baseline: the three expander families carry the acceptance
# invariant, the rest exercise the schemes on structured topologies
SPECS = [
    "lps(13,5)",                  # Ramanujan reference (n=2184, k=6)
    "slimfly(13)",                # n=338
    "xpander(256,6,0,0)",         # expander by construction (n=1792)
    "torus(16,2)",                # n=256
    "hypercube(8)",               # n=256
    "ccc(6)",                     # n=384
    "butterfly(3,4)",             # n=324
    "petersen_torus(5,4)",        # n=200
    "dragonfly",                  # n=42
]

#: the expander families whose adversarial traffic must be recovered by the
#: non-minimal schemes (the paper's thesis: spectral gap = routable bandwidth,
#: but only if the routing scheme can actually spread the load)
EXPANDERS = ("lps(13,5)", "slimfly(13)", "xpander(256,6,0,0)")

#: measured throughput may exceed the LP ceiling only by solver roundoff
MCF_TOL_REL = 1e-6
MCF_TOL_ABS = 1e-9

#: the backend-invariance probe: the family whose adversarial demand was
#: tie-sensitive before Fiedler canonicalization (degenerate rho2 eigenspace)
BACKEND_PROBE = "butterfly(3,4)"

#: large instances route rho2/Fiedler through Lanczos (same as routing_eval);
#: canonical_fiedler still recomputes the dense eigenspace below this size
DENSE_THRESHOLD = 1024

SCHEMES = ("minimal", "valiant", "ugal", "ksp")


def _thpts(a, pattern: str) -> dict:
    return {s: a.traffic(pattern, scheme=s).saturation_throughput
            for s in SCHEMES}


def _backend_invariance() -> dict:
    """Adversarial throughput of every scheme on the probe family, per spmv
    backend — returned as repr'd floats so bit-identity is visible in the
    payload."""
    from repro.api import Analysis
    from repro.core.routing import analyze_routing
    from repro.core.traffic import evaluate_traffic

    a = Analysis(BACKEND_PROBE, dense_threshold=DENSE_THRESHOLD)
    fiedler = a.fiedler                 # canonical: backend-independent
    out = {}
    for backend in ("ref", "pallas_interpret"):
        routing = analyze_routing(a.topo, backend=backend)
        out[backend] = {
            s: evaluate_traffic(a.topo, "adversarial", scheme=s,
                                routing=routing, fiedler=fiedler,
                                backend=backend).saturation_throughput
            for s in SCHEMES}
    return out


def run(out_json: str = "benchmarks/out/BENCH_routing_schemes.json",
        out_csv: str = "benchmarks/out/routing_schemes.csv") -> List[dict]:
    from repro.api import Analysis
    from repro.api.survey import csv_field

    from .calibrate import measure_calibration

    calibration = measure_calibration()
    t_all = time.time()
    table: List[dict] = []
    adversarial_wins = True
    mcf_ceiling_ok = True
    mcf_available = True
    for spec in SPECS:
        a = Analysis(spec, dense_threshold=DENSE_THRESHOLD)
        t0 = time.time()
        row = dict(family=a.family or a.name, spec=spec, nodes=a.n,
                   radix=a.radix, rho2=round(a.rho2, 5))
        for pattern in ("uniform", "adversarial"):
            meas = _thpts(a, pattern)
            try:
                ub = a.mcf_throughput_ub(pattern)
            except RuntimeError:          # scipy-less environment
                ub, mcf_available = None, False
            tag = "" if pattern == "uniform" else "_adv"
            for s in SCHEMES:
                row[f"thpt_{s}{tag}"] = round(meas[s], 4)
            row[f"thpt_mcf_ub{tag}"] = None if ub is None else round(ub, 4)
            if ub is not None:
                best = max(meas.values())
                row[f"gap_to_opt{tag}"] = round(best / ub, 4)
                mcf_ceiling_ok &= all(
                    v <= ub * (1 + MCF_TOL_REL) + MCF_TOL_ABS
                    for v in meas.values())
            else:
                row[f"gap_to_opt{tag}"] = None
            if pattern == "adversarial" and spec in EXPANDERS:
                adversarial_wins &= (meas["valiant"] >= meas["minimal"]
                                     and meas["ugal"] >= meas["minimal"])
        row["seconds"] = round(time.time() - t0, 2)
        table.append(row)
    probe = _backend_invariance()
    backends_bitwise = all(
        probe["ref"][s] == probe["pallas_interpret"][s] for s in SCHEMES)
    payload = dict(
        bench="routing_schemes",
        total_seconds=round(time.time() - t_all, 3),
        calibration_seconds=round(calibration, 4),
        families=SPECS,
        schemes=list(SCHEMES),
        correctness=dict(
            cases=len(SPECS),
            mcf_available=bool(mcf_available),
            nonminimal_wins_adversarial_on_expanders=bool(adversarial_wins),
            all_schemes_leq_mcf_ub=bool(mcf_ceiling_ok and mcf_available),
            adversarial_backend_bitwise=bool(backends_bitwise),
            backend_probe={b: {s: repr(v) for s, v in d.items()}
                           for b, d in probe.items()},
        ),
        scheme_table=table,
    )
    p = pathlib.Path(out_json)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2))
    cols = list(table[0])
    pathlib.Path(out_csv).write_text("\n".join(
        [",".join(cols)]
        + [",".join(csv_field(row[c]) for c in cols) for row in table]))
    return table


if __name__ == "__main__":
    for row in run():
        print(row)
