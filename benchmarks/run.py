"""Benchmark aggregator. One function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract); detailed CSVs go
to benchmarks/out/.  Every gated bench also emits a ``BENCH_*.json`` payload
(compared against ``benchmarks/baselines/`` by ``check_regression.py``), so
successive PRs accumulate a perf trajectory per subsystem.

Selectors::

    python -m benchmarks.run                 # full suite
    python -m benchmarks.run --list          # names only
    python -m benchmarks.run --only routing_eval --only table1

``--only`` accepts the registry names printed by ``--list`` (repeatable),
so one bench can be iterated — or one CI matrix entry gated — without paying
for the rest of the suite.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import Callable, Dict, List, Tuple


def _timed(name, fn, derive):
    t0 = time.time()
    rows = fn()
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derive(rows)}")
    return rows


def _emit_survey_bench(rows, total_us,
                       out_json: str = "benchmarks/out/BENCH_survey.json") -> None:
    from .calibrate import measure_calibration

    payload = dict(
        bench="table1_survey",
        total_seconds=round(total_us / 1e6, 3),
        calibration_seconds=round(measure_calibration(), 4),
        cases=len(rows),
        all_rho2_bounds_hold=all(r["rho2_ok"] for r in rows),
        per_row=[dict(spec=r.get("instance"), nodes=r.get("nodes"),
                      seconds=r.get("seconds")) for r in rows],
    )
    p = pathlib.Path(out_json)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2))


def _run_table1():
    from . import table1

    t0 = time.time()
    rows = _timed("table1_rho2_bw_bounds", table1.run,
                  lambda rows: f"all_rho2_bounds_hold="
                               f"{all(r['rho2_ok'] for r in rows)}")
    _emit_survey_bench(rows, (time.time() - t0) * 1e6)


def _run_fault_sweep():
    from . import fault_sweep

    _timed("fault_sweep_resilience", fault_sweep.run,
           lambda rows: "min_retention_at_10pct=%.2f"
           % min(r["retention_at_010"] or 0.0 for r in rows))


def _run_routing_eval():
    from . import routing_eval

    _timed("routing_eval_path_traffic", routing_eval.run,
           lambda rows: "all_diameters_match=%s"
           % all(r["diameter_ok"] is not False for r in rows))


def _run_routing_schemes():
    from . import routing_schemes

    _timed("routing_schemes_vs_mcf_ceiling", routing_schemes.run,
           lambda rows: "min_gap_to_opt=%.3f"
           % min(r["gap_to_opt_adv"] for r in rows
                 if r["gap_to_opt_adv"] is not None))


def _run_synthesis_frontier():
    from . import synthesis_frontier

    _timed("synthesis_frontier_ramanujan_gap", synthesis_frontier.run,
           lambda rows: "max_gap_fraction=%.3f"
           % max(r["gap_fraction"] for r in rows))


def _run_collective_sim():
    from . import collective_sim

    _timed("collective_sim_measured_vs_model", collective_sim.run,
           lambda rows: "all_ring_geq_model=%s"
           % all(r["ring_geq_model"] for r in rows))


def _run_workload_sim():
    from . import workload_sim

    _timed("workload_sim_step_time", workload_sim.run,
           lambda rows: "max_dropped_frac=%.4f"
           % max(r["dropped_frac"] for r in rows))


def _run_fig5():
    from . import fig5

    _timed("fig5_proportional_bw", fig5.run,
           lambda rows: f"curve_points={len(rows)}")


def _run_lps_bench():
    from . import lps_bench

    _timed("lps_ramanujan_cert", lps_bench.run,
           lambda rows: f"all_ramanujan={all(r['ramanujan'] for r in rows)}")


def _run_collective_model():
    from . import collective_model

    _timed("collective_model_torus_vs_lps", collective_model.run,
           lambda rows: "max_speedup=%.1fx"
           % max(r["speedup_vs_torus"] for r in rows))


def _run_roofline():
    from . import roofline

    _timed("roofline_dryrun_table", roofline.run,
           lambda rows: f"cells={len(rows)}")


def _run_scale_bench():
    from . import scale_bench

    _timed("scale_survey_row_65536", scale_bench.run,
           lambda rows: "within_budget=%s"
           % (rows[0]["correctness"]["within_wall_budget"]
              and rows[0]["correctness"]["within_rss_budget"]))


def _run_obs_overhead():
    from . import obs_overhead

    _timed("obs_overhead_span_tax", obs_overhead.run,
           lambda rows: "overhead_frac=%.4f"
           % rows[0]["correctness"]["overhead_frac"])


#: name -> (runner, BENCH json this bench emits — None for ungated benches).
#: Declaration order is execution order for the full suite.
BENCHES: Dict[str, Tuple[Callable[[], None], str]] = {
    "table1": (_run_table1, "BENCH_survey.json"),
    "fault_sweep": (_run_fault_sweep, "BENCH_faults.json"),
    "routing_eval": (_run_routing_eval, "BENCH_routing.json"),
    "routing_schemes": (_run_routing_schemes, "BENCH_routing_schemes.json"),
    "synthesis_frontier": (_run_synthesis_frontier, "BENCH_synthesis.json"),
    "collective_sim": (_run_collective_sim, "BENCH_simulate.json"),
    "workloads": (_run_workload_sim, "BENCH_workloads.json"),
    "fig5": (_run_fig5, None),
    "lps_bench": (_run_lps_bench, None),
    "collective_model": (_run_collective_model, "BENCH_collective_model.json"),
    "roofline": (_run_roofline, "BENCH_roofline.json"),
    "scale": (_run_scale_bench, "BENCH_scale.json"),
    "obs": (_run_obs_overhead, "BENCH_obs.json"),
}


def _run_instrumented(name: str) -> list:
    """Run one bench under :mod:`repro.obs`: spans enabled, counters
    snapshotted — and inject the observability ``meta`` block (peak RSS,
    build/compile/execute phase breakdown, jit-trace count, span count) into
    the BENCH json the bench just emitted.  Returns the bench's trace events
    so the aggregator can write one merged ``benchmarks/out/trace.json``."""
    from repro import obs

    runner, bench_json = BENCHES[name]
    rss0 = obs.peak_rss_kb()
    before = obs.counters()
    t0 = time.time()
    with obs.tracing():
        # no phase= tag: the wrapper must not swallow the per-phase rollup
        with obs.span("bench/" + name, bench=name):
            runner()
        rep = obs.metrics_report()
        events = list(obs.trace_events())
    wall = time.time() - t0
    if bench_json:
        p = pathlib.Path("benchmarks/out") / bench_json
        if p.exists():
            payload = json.loads(p.read_text())
            payload["meta"] = dict(
                wall_seconds=round(wall, 3),
                peak_rss_gb=round(rep.peak_rss_kb / 1e6, 3),
                rss_growth_gb=round(max(0, rep.peak_rss_kb - rss0) / 1e6, 3),
                phases={k: round(v, 3)
                        for k, v in sorted(rep.phases.items())},
                jit_traces=sum(
                    obs.counter_delta(before, "jit_trace/").values()),
                spans=len(events),
            )
            p.write_text(json.dumps(payload, indent=2))
    return events


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", action="append", default=None, metavar="NAME",
                    help="run only the named bench (repeatable; see --list)")
    ap.add_argument("--list", action="store_true",
                    help="print bench names (+ emitted BENCH file) and exit")
    args = ap.parse_args(argv)
    if args.list:
        for name, (_, bench_json) in BENCHES.items():
            print(f"{name}\t{bench_json or '-'}")
        return 0
    names = list(BENCHES) if args.only is None else args.only
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown bench name(s) {unknown}; known: {list(BENCHES)}")
    events: List[dict] = []
    for name in names:
        events += _run_instrumented(name)
    out = pathlib.Path("benchmarks/out/trace.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(
        dict(traceEvents=events, displayTimeUnit="ms"), indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
