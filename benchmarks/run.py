"""Benchmark aggregator. One function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract); detailed CSVs go
to benchmarks/out/.  Also emits ``benchmarks/out/BENCH_survey.json`` timing
the full Table-1 survey (total + per-row), so successive PRs accumulate a
perf trajectory for the survey engine.
"""
from __future__ import annotations

import json
import pathlib
import time


def _timed(name, fn, derive):
    t0 = time.time()
    rows = fn()
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derive(rows)}")
    return rows


def _emit_survey_bench(rows, total_us,
                       out_json: str = "benchmarks/out/BENCH_survey.json") -> None:
    from .calibrate import measure_calibration

    payload = dict(
        bench="table1_survey",
        total_seconds=round(total_us / 1e6, 3),
        calibration_seconds=round(measure_calibration(), 4),
        cases=len(rows),
        all_rho2_bounds_hold=all(r["rho2_ok"] for r in rows),
        per_row=[dict(spec=r.get("instance"), nodes=r.get("nodes"),
                      seconds=r.get("seconds")) for r in rows],
    )
    p = pathlib.Path(out_json)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2))


def main() -> None:
    from . import collective_model, fault_sweep, fig5, lps_bench, roofline, \
        routing_eval, synthesis_frontier, table1

    t0 = time.time()
    rows = _timed("table1_rho2_bw_bounds", table1.run,
                  lambda rows: f"all_rho2_bounds_hold={all(r['rho2_ok'] for r in rows)}")
    _emit_survey_bench(rows, (time.time() - t0) * 1e6)
    _timed("fault_sweep_resilience", fault_sweep.run,
           lambda rows: "min_retention_at_10pct=%.2f"
           % min(r["retention_at_010"] or 0.0 for r in rows))
    _timed("routing_eval_path_traffic", routing_eval.run,
           lambda rows: "all_diameters_match=%s"
           % all(r["diameter_ok"] is not False for r in rows))
    _timed("synthesis_frontier_ramanujan_gap", synthesis_frontier.run,
           lambda rows: "max_gap_fraction=%.3f"
           % max(r["gap_fraction"] for r in rows))
    _timed("fig5_proportional_bw", fig5.run,
           lambda rows: f"curve_points={len(rows)}")
    _timed("lps_ramanujan_cert", lps_bench.run,
           lambda rows: f"all_ramanujan={all(r['ramanujan'] for r in rows)}")
    _timed("collective_model_torus_vs_lps", collective_model.run,
           lambda rows: "max_speedup=%.1fx" % max(r["speedup_vs_torus"] for r in rows))
    _timed("roofline_dryrun_table", roofline.run,
           lambda rows: f"cells={len(rows)}")


if __name__ == "__main__":
    main()
