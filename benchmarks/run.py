"""Benchmark aggregator. One function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract); detailed CSVs go
to benchmarks/out/.
"""
from __future__ import annotations

import time


def _timed(name, fn, derive):
    t0 = time.time()
    rows = fn()
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derive(rows)}")
    return rows


def main() -> None:
    from . import collective_model, fig5, lps_bench, roofline, table1

    _timed("table1_rho2_bw_bounds", table1.run,
           lambda rows: f"all_rho2_bounds_hold={all(r['rho2_ok'] for r in rows)}")
    _timed("fig5_proportional_bw", fig5.run,
           lambda rows: f"curve_points={len(rows)}")
    _timed("lps_ramanujan_cert", lps_bench.run,
           lambda rows: f"all_ramanujan={all(r['ramanujan'] for r in rows)}")
    _timed("collective_model_torus_vs_lps", collective_model.run,
           lambda rows: "max_speedup=%.1fx" % max(r["speedup_vs_torus"] for r in rows))
    _timed("roofline_dryrun_table", roofline.run,
           lambda rows: f"cells={len(rows)}")


if __name__ == "__main__":
    main()
