"""Registry-wide resilience benchmark: survival curves under fault injection.

Reproduces the SpectralFly/Donetti comparison axis across our registry: for
each family, Monte-Carlo link-fault survival curves (rho2, Fiedler bisection
floor, connectivity probability vs fault rate) plus the two adversarial
attacks, all solved through the batched Laplacian Lanczos path — B=32 fault
samples per rate cost ONE vmapped solve, never a per-sample Python loop.

Emits ``benchmarks/out/BENCH_faults.json`` (consumed by the CI bench-
regression gate next to ``BENCH_survey.json``) and
``benchmarks/out/fault_sweep.csv`` with the registry-wide resilience table.

    PYTHONPATH=src python -m benchmarks.fault_sweep
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import List

# Ramanujan (lps) vs the paper's §4 survey families, equal footing:
# link-fault survival curves for every spec below.
SPECS = [
    "lps(13,5)",                  # Ramanujan reference (n=2184, k=6)
    "slimfly(13)",                # n=338
    "torus(16,2)",                # n=256
    "hypercube(8)",               # n=256
    "ccc(6)",                     # n=384
    "butterfly(3,4)",             # n=324
    "petersen_torus(5,4)",        # n=200
    "dragonfly",                  # n=42 (complete(6) routers)
    "random_regular(256,6,0)",    # near-Ramanujan random baseline
]

RATES = (0.02, 0.05, 0.1, 0.2)
SAMPLES = 32
ATTACK_RATE = 0.1
SEED = 0
ITERS = 160


def _retention_at(sweep_rows: List[dict], rate: float):
    for r in sweep_rows:
        if abs(r["rate"] - rate) < 1e-12:
            return r["rho2_retention"]
    return None


def _round_opt(x, nd: int = 4):
    return None if x is None else round(x, nd)


def run(out_json: str = "benchmarks/out/BENCH_faults.json",
        out_csv: str = "benchmarks/out/fault_sweep.csv") -> List[dict]:
    from repro.api import Analysis
    from repro.api.survey import csv_field

    from .calibrate import measure_calibration

    calibration = measure_calibration()
    t_all = time.time()
    curves, adversarial, table = {}, {}, []
    interlacing_ok = True
    batched_ok = True
    for spec in SPECS:
        a = Analysis(spec)
        t0 = time.time()
        sweep = a.fault_sweep(rates=RATES, model="link", samples=SAMPLES,
                              seed=SEED, iters=ITERS)
        interlacing_ok &= all(
            r["rho2_max"] <= r["interlacing_rho2_ub"] + 1e-3
            for r in sweep.rows)
        batched_ok &= sweep.batched_solves == len(RATES)
        atk = {m: a.fault_sweep(rates=(ATTACK_RATE,), model=m, iters=ITERS)
               for m in ("attack_degree", "attack_spectral")}
        secs = time.time() - t0
        curves[spec] = sweep.to_dict()
        adversarial[spec] = {m: s.to_dict() for m, s in atk.items()}
        row20 = sweep.rows[-1]
        table.append(dict(
            family=a.family or a.name,
            spec=spec,
            nodes=a.n,
            radix=a.radix,
            rho2_healthy=round(sweep.rho2_healthy, 5),
            retention_at_010=_round_opt(_retention_at(sweep.rows, 0.1)),
            retention_at_020=_round_opt(_retention_at(sweep.rows, 0.2)),
            connectivity_at_020=row20["connectivity_prob"],
            attack_degree_retention=_round_opt(
                atk["attack_degree"].rows[0]["rho2_retention"]),
            attack_spectral_retention=_round_opt(
                atk["attack_spectral"].rows[0]["rho2_retention"]),
            seconds=round(secs, 2),
        ))
    table.sort(key=lambda r: -(r["retention_at_010"] or 0.0))
    payload = dict(
        bench="fault_sweep",
        total_seconds=round(time.time() - t_all, 3),
        calibration_seconds=round(calibration, 4),
        samples=SAMPLES,
        rates=list(RATES),
        attack_rate=ATTACK_RATE,
        iters=ITERS,
        seed=SEED,
        families=SPECS,
        correctness=dict(
            cases=len(SPECS),
            all_interlacing_hold=bool(interlacing_ok),
            one_batched_solve_per_rate=bool(batched_ok),
        ),
        resilience_table=table,
        curves=curves,
        adversarial=adversarial,
    )
    p = pathlib.Path(out_json)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2))
    cols = list(table[0])
    pathlib.Path(out_csv).write_text("\n".join(
        [",".join(cols)]
        + [",".join(csv_field(r[c]) for c in cols) for r in table]))
    return table


if __name__ == "__main__":
    for row in run():
        print(row)
