"""Paper Table 1: per-topology rho2 / bisection-bandwidth bounds vs measured.

Driven entirely by the ``repro.api`` survey engine: every case is a registry
spec string, the measurement backend (dense oracle vs JAX Lanczos) is chosen
per instance by ``n``, and the closed forms come from each family's registered
Table-1 record — no per-topology constructor dispatch here.
"""
from __future__ import annotations

from typing import List

from repro.api import TABLE1_COLUMNS, survey

SPECS = [
    "butterfly(3,4)",
    "butterfly(4,4)",
    "ccc(5)",
    "ccc(7)",
    "clex(3,3)",
    "clex(4,3)",
    "data_vortex(8,4)",
    "data_vortex(16,5)",
    "hypercube(8)",
    "hypercube(10)",
    "petersen_torus(7,6)",
    "slimfly(5)",
    "slimfly(13)",
    "slimfly(17)",
    "torus(8,2)",
    "torus(16,2)",
    "torus(8,3)",
]


def run(out_csv: str = "benchmarks/out/table1.csv") -> List[dict]:
    res = survey(SPECS, columns=TABLE1_COLUMNS)
    res.to_csv(out_csv)
    return res.rows


if __name__ == "__main__":
    for r in run():
        print(r)
