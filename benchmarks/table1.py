"""Paper Table 1: per-topology rho2 / bisection-bandwidth bounds vs measured.

For each topology at several parameter points: build the graph, measure rho2
(dense or Lanczos) and a witnessed bisection, and compare against the paper's
closed forms + the Ramanujan reference at equal radix.
"""
from __future__ import annotations

import math
import time
from typing import List

from repro.core import bounds as B
from repro.core import spectral as S
from repro.core import topologies as T
from repro.core.properties import bisection_fiedler
from repro.core.ramanujan import lps

CASES = [
    ("butterfly", lambda: T.butterfly(3, 4), B.TABLE1["butterfly"](3, 4)),
    ("butterfly", lambda: T.butterfly(4, 4), B.TABLE1["butterfly"](4, 4)),
    ("ccc", lambda: T.cube_connected_cycles(5), B.TABLE1["ccc"](5)),
    ("ccc", lambda: T.cube_connected_cycles(7), B.TABLE1["ccc"](7)),
    ("clex", lambda: T.clex(3, 3), B.TABLE1["clex"](3, 3)),
    ("clex", lambda: T.clex(4, 3), B.TABLE1["clex"](4, 3)),
    ("data_vortex", lambda: T.data_vortex(8, 4), B.TABLE1["data_vortex"](8, 4)),
    ("data_vortex", lambda: T.data_vortex(16, 5), B.TABLE1["data_vortex"](16, 5)),
    ("hypercube", lambda: T.hypercube(8), B.TABLE1["hypercube"](8)),
    ("hypercube", lambda: T.hypercube(10), B.TABLE1["hypercube"](10)),
    ("peterson_torus", lambda: T.peterson_torus(7, 6), B.TABLE1["peterson_torus"](7, 6)),
    ("slimfly", lambda: T.slimfly(5), B.TABLE1["slimfly"](5)),
    ("slimfly", lambda: T.slimfly(13), B.TABLE1["slimfly"](13)),
    ("slimfly", lambda: T.slimfly(17), B.TABLE1["slimfly"](17)),
    ("torus", lambda: T.torus(8, 2), B.TABLE1["torus"](8, 2)),
    ("torus", lambda: T.torus(16, 2), B.TABLE1["torus"](16, 2)),
    ("torus", lambda: T.torus(8, 3), B.TABLE1["torus"](8, 3)),
]


def run(out_csv: str = "benchmarks/out/table1.csv") -> List[dict]:
    import pathlib
    rows = []
    for name, builder, expect in CASES:
        t0 = time.time()
        g = builder()
        rho2 = S.algebraic_connectivity(g)
        bw_witness, _ = bisection_fiedler(g)
        k = g.radix
        row = dict(
            topology=name, instance=g.name, nodes=g.n, radix=k,
            rho2=round(rho2, 6), rho2_ub_paper=round(expect["rho2_ub"], 6),
            rho2_ok=rho2 <= expect["rho2_ub"] + 1e-6,
            bw_fiedler_lb=round(B.fiedler_bw_lb(g.n, rho2), 2),
            bw_witness=bw_witness,
            bw_ub_paper=round(expect["bw_ub"], 2),
            ramanujan_rho2=round(B.ramanujan_rho2(k), 6),
            rho2_gap_ratio=round(rho2 / B.ramanujan_rho2(k), 4),
            seconds=round(time.time() - t0, 2),
        )
        rows.append(row)
    p = pathlib.Path(out_csv)
    p.parent.mkdir(parents=True, exist_ok=True)
    cols = list(rows[0])
    p.write_text("\n".join([",".join(cols)] +
                           [",".join(str(r[c]) for c in cols) for r in rows]))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
