"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json, emits the per-(arch x shape x mesh) table:
three terms, dominant bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, bytes/device.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import List


def run(dryrun_dir: str = "experiments/dryrun",
        out_csv: str = "benchmarks/out/roofline.csv",
        out_json: str = "benchmarks/out/BENCH_roofline.json") -> List[dict]:
    t_all = time.time()
    rows = []
    for p in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        r = json.loads(p.read_text())
        rf = r["roofline"]
        dom_s = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        rows.append(dict(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            compute_s=round(rf["compute_s"], 5),
            memory_s=round(rf["memory_s"], 5),
            collective_s=round(rf["collective_s"], 5),
            dominant=rf["dominant"],
            roofline_fraction=round(rf["compute_s"] / max(dom_s, 1e-12), 4),
            useful_flops_ratio=round(r["useful_flops_ratio"], 4),
            hbm_gb_per_device=round(r["memory"]["peak_bytes"] / 1e9, 2),
            fits_16gb=r["memory"]["peak_bytes"] <= 16e9,
            ag_gb=round(r["collectives"]["bytes_by_kind"]["all-gather"] / 1e9, 3),
            ar_gb=round(r["collectives"]["bytes_by_kind"]["all-reduce"] / 1e9, 3),
            a2a_gb=round(r["collectives"]["bytes_by_kind"]["all-to-all"] / 1e9, 3),
            rs_gb=round(r["collectives"]["bytes_by_kind"]["reduce-scatter"] / 1e9, 3),
            compile_s=r["compile_seconds"],
        ))
    out = pathlib.Path(out_csv)
    out.parent.mkdir(parents=True, exist_ok=True)
    if rows:
        cols = list(rows[0])
        out.write_text("\n".join([",".join(cols)] +
                                 [",".join(str(r[c]) for c in cols) for r in rows]))
    # gated even when no dry-run artifacts exist: a cell-count drift (e.g. a
    # dryrun artifact silently failing to parse) is a correctness signal
    payload = dict(
        bench="roofline",
        total_seconds=round(time.time() - t_all, 3),
        correctness=dict(
            cases=len(rows),
            all_fit_16gb=all(r["fits_16gb"] for r in rows),
        ),
        table=rows,
    )
    pathlib.Path(out_json).write_text(json.dumps(payload, indent=2))
    return rows


def markdown_table(rows: List[dict]) -> str:
    if not rows:
        return "(no dry-run artifacts yet)"
    cols = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
            "dominant", "roofline_fraction", "useful_flops_ratio",
            "hbm_gb_per_device"]
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        lines.append("| " + " | ".join(str(r[c]) for c in cols) + " |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = run()
    print(markdown_table(rows))
