"""Beyond-paper: the paper's thesis quantified for LM collectives.

Predicted collective times on the faithful v5e torus ICI vs an equal-radix
LPS-like Ramanujan rewiring (physically plausible on OCS fabrics), for the
actual payloads of our dry-run workloads (DP grad all-reduce, FSDP
all-gathers, MoE all-to-all).
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import List

from repro.core import bounds as B
from repro.core.collectives import NetworkModel, tpu_v5e_ici

# payloads per device (bytes) representative of the dry-run cells
WORKLOADS = [
    # (name, collective, bytes/node)
    ("dp_grad_allreduce_7b", "all-reduce", 2 * 7.6e9 / 256),     # bf16 grads, 256-way
    ("fsdp_allgather_layer", "all-gather", 2 * 7.6e9 / 28 / 16), # one layer's params
    ("moe_alltoall_kimi", "all-to-all", 8 * 7168 * 2 * 4096 / 16),  # top-8 routed acts
    ("tp_allreduce_act", "all-reduce", 16 * 4096 * 7168 * 2),    # residual psum
]


def make_networks(n: int = 256):
    torus = tpu_v5e_ici(16, 16)
    k = 4  # equal radix
    ram_rho2 = B.ramanujan_rho2(k)
    ram = NetworkModel(name=f"ramanujan(k={k})", n=n, radix=k,
                       bisection_links=B.fiedler_bw_lb(n, ram_rho2),
                       diameter=6)   # ~log_{k-1} n
    # next-gen radix comparison
    torus3d = NetworkModel(name="torus(8x8x4)3d", n=n, radix=6,
                           bisection_links=2 * 8 * 4, diameter=8 // 2 + 8 // 2 + 4 // 2)
    ram6 = NetworkModel(name="ramanujan(k=6)", n=n, radix=6,
                        bisection_links=B.fiedler_bw_lb(n, B.ramanujan_rho2(6)),
                        diameter=4)
    return [torus, ram, torus3d, ram6]


def run(out_csv: str = "benchmarks/out/collective_model.csv",
        out_json: str = "benchmarks/out/BENCH_collective_model.json"
        ) -> List[dict]:
    from .calibrate import measure_calibration

    calibration = measure_calibration()
    t_all = time.time()
    rows = []
    nets = make_networks()
    # the equal-radix claim the table exists to demonstrate: at MATCHED radix
    # the Ramanujan rewiring is never slower than the torus on any workload
    # (checked on unrounded seconds: radix-4 ram vs the 2D torus, radix-6 ram
    # vs the 3D torus)
    ram_never_slower = True
    for wname, kind, payload in WORKLOADS:
        base = None
        times = {}
        for net in nets:
            t = net.collective_time(kind, payload)
            times[net.name] = t
            if base is None:
                base = t
            rows.append(dict(workload=wname, collective=kind,
                             bytes_per_node=int(payload), network=net.name,
                             bisection_links=round(net.bisection_links, 1),
                             predicted_ms=round(t * 1e3, 4),
                             speedup_vs_torus=round(base / t, 2)))
        ram_never_slower &= times["ramanujan(k=4)"] <= times["torus(16x16)"] \
            and times["ramanujan(k=6)"] <= times["torus(8x8x4)3d"]
    p = pathlib.Path(out_csv)
    p.parent.mkdir(parents=True, exist_ok=True)
    cols = list(rows[0])
    p.write_text("\n".join([",".join(cols)] +
                           [",".join(str(r[c]) for c in cols) for r in rows]))
    payload = dict(
        bench="collective_model",
        total_seconds=round(time.time() - t_all, 3),
        calibration_seconds=round(calibration, 4),
        correctness=dict(
            cases=len(rows),
            ramanujan_never_slower_than_torus=bool(ram_never_slower),
            max_speedup_vs_torus=round(
                max(r["speedup_vs_torus"] for r in rows), 2),
        ),
        table=rows,
    )
    pathlib.Path(out_json).write_text(json.dumps(payload, indent=2))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
