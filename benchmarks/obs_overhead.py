"""Observability tax gate: instrumentation must cost < 3% wall time.

One stable-jit workload unit (exact routing + uniform ECMP traffic + an
executed ring all-reduce over three tier-1 families) is warmed up, then the
span tax is measured as **per-span cost x spans per unit / unit wall time**:

* the per-span cost comes from a tight micro-benchmark of the enabled span
  enter/exit path minus the disabled no-op path (min over batches of 20k
  spans — deterministic to well under a microsecond);
* the span count per unit and the unit wall time (min-of-N, interleaved
  enabled/disabled so drift cancels) come from the real workload.

Spans are purely additive host-side context managers — enabling them changes
no engine code path (the gated ``no_unexpected_recompiles`` proves the jit
caches are untouched) — so the product is the exact instrumentation cost,
without the +/-5% jitter a small JAX CPU workload puts on an end-to-end
subtraction.  The raw end-to-end delta is still reported
(``measured_end_to_end_frac``) for eyeballing, but the gate rides on the
composed figure: anything above :data:`OVERHEAD_BUDGET_FRAC` means either a
span leaked into a per-iteration hot loop (span count explodes) or the span
path itself got expensive.

Two more acceptance invariants ride along, both read from counters rather
than monkey-patched probes:

* **no_unexpected_recompiles** — re-running the warmed unit adds zero
  ``jit_trace/*`` counts: enabling spans must not perturb jit caches.
* **telemetry_matches_static_ecmp** — ``simulate(..., telemetry=True)``
  per-round link loads reduce to the static ECMP ``max_link_load`` on
  uniform traffic for three families (the ISSUE-10 acceptance identity).
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

#: families for the timed unit — small enough for a tight min-of-N, large
#: enough that the unit is dominated by engine work, not dispatch
SPECS = ["slimfly(5)", "torus(6,2)", "petersen_torus(3,3)"]

#: families for the telemetry-vs-static-ECMP identity check
TELEMETRY_SPECS = ["petersen", "hypercube(5)", "torus(6,2)"]

OVERHEAD_BUDGET_FRAC = 0.03
REPS = 7
MICRO_SPANS = 20000
MICRO_BATCHES = 5
PAYLOADS = (1 << 16, 1 << 20)


def _unit(topos, routings):
    """One workload rep: traffic lowering + executed ring all-reduce per
    family.  Everything jit-cached after the warmup rep."""
    from repro.core import traffic as TF
    from repro.core.simulate import simulate_collective

    for g, rt in zip(topos, routings):
        TF.evaluate_traffic(g, "uniform", routing=rt)
        simulate_collective(g, "all_reduce", "ring", payloads=PAYLOADS)


def _span_tax_seconds(obs) -> float:
    """Enabled-span enter/exit cost minus the disabled no-op cost, per span
    (min over micro-benchmark batches)."""
    def batch(enabled: bool) -> float:
        if enabled:
            obs.enable()
        else:
            obs.disable()
        obs.reset_spans()
        t0 = time.perf_counter()
        for _ in range(MICRO_SPANS):
            with obs.span("obs_overhead/probe", phase="execute"):
                pass
        dt = time.perf_counter() - t0
        obs.reset_spans()
        return dt / MICRO_SPANS

    enabled = min(batch(True) for _ in range(MICRO_BATCHES))
    disabled = min(batch(False) for _ in range(MICRO_BATCHES))
    return max(0.0, enabled - disabled)


def _interleaved_min(disabled_fn, enabled_fn, reps):
    """min-of-N for both variants, alternating rep pairs so clock-frequency
    or allocator drift across the measurement window cancels instead of
    landing entirely on whichever variant runs second."""
    best_d = best_e = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        disabled_fn()
        best_d = min(best_d, time.perf_counter() - t0)
        t0 = time.perf_counter()
        enabled_fn()
        best_e = min(best_e, time.perf_counter() - t0)
    return best_d, best_e


def _telemetry_cases():
    from repro.api import Analysis, build

    cases = []
    for spec in TELEMETRY_SPECS:
        a = Analysis(build(spec))
        sim = a.simulate("traffic", pattern="uniform", telemetry=True)
        static = a.traffic("uniform").max_link_load
        peak = float(sim.telemetry.round_max_link_load.max())
        cases.append(dict(
            family=spec, static_max_link_load=round(static, 6),
            telemetry_max_round_load=round(peak, 6),
            rounds=int(sim.telemetry.unique_rounds),
            matches=bool(np.isclose(peak, static, rtol=1e-6))))
    return cases


def run(out_json: str = "benchmarks/out/BENCH_obs.json"):
    from repro import obs
    from repro.api import build
    from repro.core import routing as R

    from .calibrate import measure_calibration

    t0 = time.time()
    topos = [build(s) for s in SPECS]
    routings = [R.analyze_routing(g) for g in topos]

    _unit(topos, routings)                       # warmup: populate jit caches
    before = obs.counters("jit_trace/")
    _unit(topos, routings)
    retraces = obs.counter_delta(before, "jit_trace/")

    was_enabled = obs.enabled()
    span_tax_s = _span_tax_seconds(obs)
    obs.disable()

    def _disabled_rep():
        obs.disable()
        _unit(topos, routings)

    def _enabled_rep():
        with obs.tracing():
            _unit(topos, routings)
            _enabled_rep.spans = len(obs.trace_events())

    disabled_s, enabled_s = _interleaved_min(_disabled_rep, _enabled_rep,
                                             REPS)
    if was_enabled:                      # restore an outer tracing session
        obs.enable()
    frac = span_tax_s * _enabled_rep.spans / disabled_s
    end_to_end = max(0.0, enabled_s / disabled_s - 1.0)

    telemetry = _telemetry_cases()

    payload = dict(
        bench="obs_overhead",
        total_seconds=round(time.time() - t0, 3),
        calibration_seconds=round(measure_calibration(), 4),
        reps=REPS,
        budget_frac=OVERHEAD_BUDGET_FRAC,
        span_tax_us=round(span_tax_s * 1e6, 3),
        disabled_seconds=round(disabled_s, 5),
        enabled_seconds=round(enabled_s, 5),
        measured_end_to_end_frac=round(end_to_end, 4),
        telemetry=telemetry,
        correctness=dict(
            cases=len(SPECS),
            spans_recorded=_enabled_rep.spans,
            overhead_frac=round(frac, 4),
            overhead_within_budget=bool(frac < OVERHEAD_BUDGET_FRAC),
            no_unexpected_recompiles=not retraces,
            telemetry_matches_static_ecmp=all(
                c["matches"] for c in telemetry),
        ),
    )
    out = pathlib.Path(out_json)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(payload, indent=2))
    return [payload]


if __name__ == "__main__":
    rows = run()
    print(json.dumps(rows[0]["correctness"], indent=2))
