"""CI bench-regression gate.

Compares the freshly-emitted ``benchmarks/out/BENCH_*.json`` payloads against
the committed baselines in ``benchmarks/baselines/`` and exits non-zero on

* **wall-time regression** — any gated timing field more than ``--tolerance``
  (default 20%, env ``BENCH_GATE_TOLERANCE``) above the baseline (timings
  whose baseline sits under :data:`MIN_GATED_SECONDS` are skipped — at that
  scale the ratio measures scheduler noise);
* **correctness drift** — any gated correctness field differing from the
  baseline at all (these are exact: bound checks, case counts, batching
  invariants);
* **acceptance failure** — any ``required_true`` invariant not literally true
  in the current payload (e.g. the simulator's measured-vs-model bound),
  regardless of what a regenerated baseline says.

Usage (what the CI bench-gate job runs)::

    PYTHONPATH=src python -m benchmarks.run          # emits the BENCH files
    python benchmarks/check_regression.py

``--only BENCH_routing.json`` (repeatable) gates a subset — the partner of
``benchmarks.run --only`` for iterating one bench or sharding the CI matrix.
``--simulate-slowdown 1.25`` multiplies the current timings before comparing —
the knob used to demonstrate that the gate actually fails on an injected
regression.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

#: per-bench gated fields: correctness fields (dotted access into the JSON
#: payload; must equal the baseline exactly), timing fields (bounded by
#: baseline * (1 + tolerance)), and ``required_true`` fields — acceptance
#: invariants that must be literally true in the CURRENT payload, not merely
#: unchanged (a baseline regenerated with a broken invariant still fails).
GATES = {
    "BENCH_survey.json": dict(
        correctness=["all_rho2_bounds_hold", "cases"],
        timings=["total_seconds"],
    ),
    "BENCH_faults.json": dict(
        correctness=["correctness.cases", "correctness.all_interlacing_hold",
                     "correctness.one_batched_solve_per_rate", "families",
                     "samples", "rates"],
        timings=["total_seconds"],
    ),
    "BENCH_routing.json": dict(
        correctness=["correctness.cases",
                     "correctness.all_diameters_match_closed_forms",
                     "correctness.load_conservation_ok",
                     # canonical-Fiedler adversarial throughput per family:
                     # exact-match gated so tie-sensitive eigensolver drift
                     # (the PR-8 butterfly regression) can never recur
                     "correctness.thpt_adversarial", "families"],
        timings=["total_seconds"],
    ),
    "BENCH_routing_schemes.json": dict(
        correctness=["correctness.cases", "families", "schemes",
                     "correctness.mcf_available",
                     "correctness.backend_probe"],
        # the PR-9 acceptance set: non-minimal routing recovers adversarial
        # throughput on every expander family, no scheme beats the LP
        # optimal-routing ceiling, and the adversarial demand is bit-stable
        # across spmv backends — all must hold in the CURRENT payload
        required_true=[
            "correctness.nonminimal_wins_adversarial_on_expanders",
            "correctness.all_schemes_leq_mcf_ub",
            "correctness.adversarial_backend_bitwise"],
        timings=["total_seconds"],
    ),
    "BENCH_synthesis.json": dict(
        correctness=["correctness.cases",
                     "correctness.lift_meets_lps_target",
                     "correctness.rewire_no_worse_than_start",
                     "correctness.synthesized_above_matched_table1",
                     "families"],
        timings=["total_seconds"],
    ),
    "BENCH_simulate.json": dict(
        correctness=["correctness.cases",
                     "correctness.workload_matches_static_ecmp", "families",
                     "payload_bytes"],
        # the paper-thesis acceptance pair: every executed ring all-reduce
        # sits at/above the analytic spectral lower bound, and the executed
        # uniform-workload throughput rank-orders families exactly as the
        # spectral gap predicts
        required_true=["correctness.ring_time_geq_model_lb",
                       "correctness.thpt_rank_matches_spectral"],
        timings=["total_seconds"],
    ),
    "BENCH_workloads.json": dict(
        correctness=["correctness.cases", "families", "workloads",
                     "placement"],
        # the PR-7 acceptance pair: simulated training-step time rank-orders
        # the spectral five exactly as rho2 predicts (under uniform-random
        # placement), and every plan's byte accounting agrees with the
        # independent launch/hlo_analysis parser
        required_true=["correctness.step_time_rank_matches_spectral",
                       "correctness.hlo_crosscheck_ok"],
        timings=["total_seconds"],
    ),
    "BENCH_collective_model.json": dict(
        correctness=["correctness.cases",
                     "correctness.ramanujan_never_slower_than_torus",
                     "correctness.max_speedup_vs_torus"],
        timings=["total_seconds"],
    ),
    "BENCH_roofline.json": dict(
        correctness=["correctness.cases", "correctness.all_fit_16gb"],
        timings=["total_seconds"],
    ),
    "BENCH_scale.json": dict(
        correctness=["correctness.cases", "correctness.scale_nodes",
                     "scale_spec", "budget"],
        # the datacenter-scale acceptance set: the sampled estimator's
        # degenerate limit is bit-exact on all tier-1 families, and the
        # n=65536 survey row lands inside the committed wall/RSS budgets
        # with a certified diameter lower bound — all must hold in the
        # CURRENT payload, not merely match a (possibly broken) baseline
        required_true=["correctness.sample_fraction_one_bitwise",
                       "correctness.within_wall_budget",
                       "correctness.within_rss_budget",
                       "correctness.diameter_lb_certified",
                       "correctness.avg_hops_inside_ci",
                       "correctness.saturation_throughput_positive"],
        timings=["total_seconds"],
    ),
    "BENCH_obs.json": dict(
        correctness=["correctness.cases", "correctness.spans_recorded",
                     "budget_frac", "reps"],
        # the ISSUE-10 acceptance set: span instrumentation costs < 3% wall
        # on a warmed workload, enabling tracing perturbs no jit cache, and
        # per-round telemetry reduces to the static ECMP link load — all
        # must hold in the CURRENT payload
        required_true=["correctness.overhead_within_budget",
                       "correctness.no_unexpected_recompiles",
                       "correctness.telemetry_matches_static_ecmp"],
        timings=["total_seconds"],
    ),
}

#: timings are not ratio-gated while BOTH baseline and current sit below this
#: many seconds — at that scale the ratio measures scheduler noise, not the
#: benchmark; crossing the floor re-enables the comparison
MIN_GATED_SECONDS = 0.5


def _get(payload: dict, dotted: str):
    cur = payload
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def check(name: str, baseline: dict, current: dict, tolerance: float,
          slowdown: float) -> list:
    errors = []
    gate = GATES[name]
    for field in gate["correctness"]:
        base, cur = _get(baseline, field), _get(current, field)
        if base != cur:
            errors.append(f"{name}: correctness drift in {field!r}: "
                          f"baseline={base!r} current={cur!r}")
    for field in gate.get("required_true", ()):
        if _get(current, field) is not True:
            errors.append(f"{name}: acceptance invariant {field!r} is "
                          f"{_get(current, field)!r}, must be true")
    # Machine-speed normalization: when both payloads carry the calibration
    # probe (benchmarks/calibrate.py), gate on seconds-per-calibration-unit so
    # a slower/faster runner class doesn't produce phantom verdicts.
    base_cal = baseline.get("calibration_seconds")
    cur_cal = current.get("calibration_seconds")
    normalized = bool(base_cal and cur_cal)
    unit = "x-cal" if normalized else "s"
    for field in gate["timings"]:
        base, cur = _get(baseline, field), _get(current, field)
        if base is None or cur is None:
            errors.append(f"{name}: timing field {field!r} missing "
                          f"(baseline={base!r} current={cur!r})")
            continue
        cur = cur * slowdown
        if base < MIN_GATED_SECONDS and cur < MIN_GATED_SECONDS:
            # both sides in noise territory; a cheap bench that climbs PAST
            # the floor still gets compared (and fails) below
            print(f"  {name}:{field}: baseline {base:.3f}s and current "
                  f"{cur:.3f}s below the {MIN_GATED_SECONDS}s gating floor "
                  f"-> SKIPPED")
            continue
        if normalized:
            base, cur = base / base_cal, cur / cur_cal
        limit = base * (1.0 + tolerance)
        verdict = "OK" if cur <= limit else "REGRESSION"
        print(f"  {name}:{field}: baseline {base:.3f}{unit}, "
              f"current {cur:.3f}{unit}, limit {limit:.3f}{unit} -> {verdict}")
        if cur > limit:
            errors.append(
                f"{name}: wall-time regression in {field!r}: {cur:.3f}{unit} "
                f"> {limit:.3f}{unit} (baseline {base:.3f}{unit} + "
                f"{tolerance:.0%})")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", default="benchmarks/baselines")
    ap.add_argument("--out-dir", default="benchmarks/out")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_GATE_TOLERANCE", 0.20)),
                    help="allowed fractional wall-time growth (default 0.20)")
    ap.add_argument("--simulate-slowdown", type=float, default=1.0,
                    help="multiply current timings (inject a fake regression "
                         "to prove the gate fires)")
    ap.add_argument("--only", action="append", default=None,
                    metavar="BENCH_FILE",
                    help="gate only the named BENCH_*.json (repeatable; "
                         "default: all gated benches)")
    args = ap.parse_args(argv)
    names = list(GATES) if args.only is None else args.only
    unknown = [n for n in names if n not in GATES]
    if unknown:
        ap.error(f"unknown bench file(s) {unknown}; known: {list(GATES)}")
    errors = []
    for name in names:
        base_p = pathlib.Path(args.baseline_dir) / name
        cur_p = pathlib.Path(args.out_dir) / name
        if not base_p.exists():
            errors.append(f"missing committed baseline {base_p} "
                          f"(regenerate and commit it)")
            continue
        if not cur_p.exists():
            errors.append(f"missing current bench output {cur_p} "
                          f"(run benchmarks/run.py first)")
            continue
        errors += check(name, json.loads(base_p.read_text()),
                        json.loads(cur_p.read_text()),
                        args.tolerance, args.simulate_slowdown)
    if errors:
        print("\nBENCH GATE FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print("bench gate passed: no wall-time regression, no correctness drift")
    return 0


if __name__ == "__main__":
    sys.exit(main())
