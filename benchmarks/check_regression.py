"""CI bench-regression gate.

Compares the freshly-emitted ``benchmarks/out/BENCH_survey.json`` and
``BENCH_faults.json`` against the committed baselines in
``benchmarks/baselines/`` and exits non-zero on

* **wall-time regression** — any gated timing field more than ``--tolerance``
  (default 20%, env ``BENCH_GATE_TOLERANCE``) above the baseline;
* **correctness drift** — any gated correctness field differing from the
  baseline at all (these are exact: bound checks, case counts, batching
  invariants).

Usage (what the CI bench-gate job runs)::

    PYTHONPATH=src python -m benchmarks.run          # emits both BENCH files
    python benchmarks/check_regression.py

``--simulate-slowdown 1.25`` multiplies the current timings before comparing —
the knob used to demonstrate that the gate actually fails on an injected
regression.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

#: per-bench gated fields: (correctness fields, timing fields).  Correctness
#: paths use dotted access into the JSON payload.
GATES = {
    "BENCH_survey.json": dict(
        correctness=["all_rho2_bounds_hold", "cases"],
        timings=["total_seconds"],
    ),
    "BENCH_faults.json": dict(
        correctness=["correctness.cases", "correctness.all_interlacing_hold",
                     "correctness.one_batched_solve_per_rate", "families",
                     "samples", "rates"],
        timings=["total_seconds"],
    ),
    "BENCH_routing.json": dict(
        correctness=["correctness.cases",
                     "correctness.all_diameters_match_closed_forms",
                     "correctness.load_conservation_ok", "families"],
        timings=["total_seconds"],
    ),
    "BENCH_synthesis.json": dict(
        correctness=["correctness.cases",
                     "correctness.lift_meets_lps_target",
                     "correctness.rewire_no_worse_than_start",
                     "correctness.synthesized_above_matched_table1",
                     "families"],
        timings=["total_seconds"],
    ),
}


def _get(payload: dict, dotted: str):
    cur = payload
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def check(name: str, baseline: dict, current: dict, tolerance: float,
          slowdown: float) -> list:
    errors = []
    gate = GATES[name]
    for field in gate["correctness"]:
        base, cur = _get(baseline, field), _get(current, field)
        if base != cur:
            errors.append(f"{name}: correctness drift in {field!r}: "
                          f"baseline={base!r} current={cur!r}")
    # Machine-speed normalization: when both payloads carry the calibration
    # probe (benchmarks/calibrate.py), gate on seconds-per-calibration-unit so
    # a slower/faster runner class doesn't produce phantom verdicts.
    base_cal = baseline.get("calibration_seconds")
    cur_cal = current.get("calibration_seconds")
    normalized = bool(base_cal and cur_cal)
    unit = "x-cal" if normalized else "s"
    for field in gate["timings"]:
        base, cur = _get(baseline, field), _get(current, field)
        if base is None or cur is None:
            errors.append(f"{name}: timing field {field!r} missing "
                          f"(baseline={base!r} current={cur!r})")
            continue
        cur = cur * slowdown
        if normalized:
            base, cur = base / base_cal, cur / cur_cal
        limit = base * (1.0 + tolerance)
        verdict = "OK" if cur <= limit else "REGRESSION"
        print(f"  {name}:{field}: baseline {base:.3f}{unit}, "
              f"current {cur:.3f}{unit}, limit {limit:.3f}{unit} -> {verdict}")
        if cur > limit:
            errors.append(
                f"{name}: wall-time regression in {field!r}: {cur:.3f}{unit} "
                f"> {limit:.3f}{unit} (baseline {base:.3f}{unit} + "
                f"{tolerance:.0%})")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", default="benchmarks/baselines")
    ap.add_argument("--out-dir", default="benchmarks/out")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_GATE_TOLERANCE", 0.20)),
                    help="allowed fractional wall-time growth (default 0.20)")
    ap.add_argument("--simulate-slowdown", type=float, default=1.0,
                    help="multiply current timings (inject a fake regression "
                         "to prove the gate fires)")
    args = ap.parse_args(argv)
    errors = []
    for name in GATES:
        base_p = pathlib.Path(args.baseline_dir) / name
        cur_p = pathlib.Path(args.out_dir) / name
        if not base_p.exists():
            errors.append(f"missing committed baseline {base_p} "
                          f"(regenerate and commit it)")
            continue
        if not cur_p.exists():
            errors.append(f"missing current bench output {cur_p} "
                          f"(run benchmarks/run.py first)")
            continue
        errors += check(name, json.loads(base_p.read_text()),
                        json.loads(cur_p.read_text()),
                        args.tolerance, args.simulate_slowdown)
    if errors:
        print("\nBENCH GATE FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print("bench gate passed: no wall-time regression, no correctness drift")
    return 0


if __name__ == "__main__":
    sys.exit(main())
