"""Ramanujan-frontier benchmark: synthesized vs surveyed topologies.

The paper's closing claim is that existing topologies sit well below the
Ramanujan spectral-gap optimum.  This bench measures how much of that gap the
synthesis subsystem (:mod:`repro.core.synthesis`) actually recovers: at
matched (n, k) it runs the batched lift and rewire searches next to the
table-1 family of the same degree and the LPS Ramanujan reference, reporting
each graph's achieved rho2 as a fraction of the Ramanujan-bound optimum
``k - 2 sqrt(k-1)`` — the frontier-plot data.

Emits ``benchmarks/out/BENCH_synthesis.json`` (gated by
``benchmarks/check_regression.py`` against the committed baseline) and
``benchmarks/out/synthesis_frontier.csv``.

    PYTHONPATH=src python -m benchmarks.synthesis_frontier
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import List

SEED = 0
#: search effort: total SA flip steps (lift) / candidate evaluations (rewire)
LIFT_BUDGET = 2400
REWIRE_BUDGET = 288

#: matched-(n, k) comparison points: the synthesized methods vs the table-1
#: family of identical size and degree, plus the equal-degree LPS reference
POINTS = [
    dict(n=512, k=6, table1="torus(8,3)", reference="lps(13,5)"),
    dict(n=256, k=4, table1="torus(16,2)", reference=None),
]


def _measured_rho2(spec: str) -> tuple:
    from repro.api import Analysis

    a = Analysis(spec)
    return float(a.rho2), a.n, float(a.radix)


def run(out_json: str = "benchmarks/out/BENCH_synthesis.json",
        out_csv: str = "benchmarks/out/synthesis_frontier.csv") -> List[dict]:
    from repro.core import bounds as B
    from repro.core.synthesis import synthesize
    from repro.api.survey import csv_field

    from .calibrate import measure_calibration

    calibration = measure_calibration()
    t_all = time.time()
    rows, trajectories = [], {}
    lift_ok = rewire_ok = above_table1_ok = True
    for pt in POINTS:
        n, k = pt["n"], pt["k"]
        opt = B.ramanujan_rho2(k)

        def add(spec, kind, rho2, nodes, seconds):
            rows.append(dict(spec=spec, kind=kind, n=nodes, k=k,
                             rho2=round(rho2, 5),
                             ramanujan_rho2=round(opt, 5),
                             gap_fraction=round(rho2 / opt, 4),
                             seconds=round(seconds, 2)))
            return rho2 / opt

        t0 = time.time()
        lift = synthesize(n, k, method="lift", budget=LIFT_BUDGET, seed=SEED)
        frac_lift = add(f"xpander({n},{k})", "synthesized-lift", lift.rho2,
                        lift.n, time.time() - t0)
        trajectories[f"xpander({n},{k})"] = lift.to_dict()["trajectory"]

        t0 = time.time()
        rew = synthesize(n, k, method="rewire", budget=REWIRE_BUDGET,
                         seed=SEED)
        frac_rew = add(f"rewired({n},{k})", "synthesized-rewire", rew.rho2,
                       rew.n, time.time() - t0)
        # rewiring starts FROM the random graph and moves monotonically;
        # trajectory[0] is a Lanczos estimate of the start rho2, so allow
        # estimate-level slack rather than float-roundoff slack
        rewire_ok &= rew.rho2 >= rew.trajectory[0] - 1e-3

        t0 = time.time()
        rho2_t1, n_t1, _ = _measured_rho2(pt["table1"])
        frac_t1 = add(pt["table1"], "table1", rho2_t1, n_t1, time.time() - t0)
        above_table1_ok &= (frac_lift > frac_t1) and (frac_rew > frac_t1)

        t0 = time.time()
        rho2_rr, n_rr, _ = _measured_rho2(f"random_regular({n},{k},{SEED})")
        add(f"random_regular({n},{k},{SEED})", "random", rho2_rr, n_rr,
            time.time() - t0)

        if pt["reference"]:
            t0 = time.time()
            rho2_ref, n_ref, _ = _measured_rho2(pt["reference"])
            frac_ref = add(pt["reference"], "ramanujan-reference", rho2_ref,
                           n_ref, time.time() - t0)
            # the acceptance bar: the designed lift recovers >= 90% of the
            # LPS-class gap fraction at matched degree
            lift_ok &= frac_lift >= 0.9 * frac_ref

    payload = dict(
        bench="synthesis_frontier",
        total_seconds=round(time.time() - t_all, 3),
        calibration_seconds=round(calibration, 4),
        seed=SEED,
        lift_budget=LIFT_BUDGET,
        rewire_budget=REWIRE_BUDGET,
        families=[r["spec"] for r in rows],
        correctness=dict(
            cases=len(rows),
            lift_meets_lps_target=bool(lift_ok),
            rewire_no_worse_than_start=bool(rewire_ok),
            synthesized_above_matched_table1=bool(above_table1_ok),
        ),
        frontier_table=rows,
        rho2_trajectories=trajectories,
    )
    p = pathlib.Path(out_json)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2))
    cols = list(rows[0])
    pathlib.Path(out_csv).write_text("\n".join(
        [",".join(cols)]
        + [",".join(csv_field(r[c]) for c in cols) for r in rows]))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
