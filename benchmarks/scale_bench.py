"""Datacenter-scale gate: one full survey row at n = 65536.

Two halves, one payload:

* **Exactness sweep** — on every tier-1 bench family (the nine
  ``routing_eval`` SPECS), ``analyze_routing(sample_fraction=1.0)`` must
  reproduce the exact all-sources analysis bit-for-bit (same dist / sigma
  matrices, same scalars).  This pins the estimator's degenerate limit, so
  the sampled path is provably the same algorithm, just on fewer rows.
* **Scale row** — build ``xpander(65536,32)`` (budget=0: best-of-24 random
  signings per lift level — construction, not search) and complete a full
  survey row: chunked-Lanczos rho2 + 64-source sampled routing with
  bootstrap CI + bias-corrected uniform traffic.  The row must finish
  inside fixed wall-time and peak-RSS budgets (committed below), proving
  the engines hold at datacenter scale, not just tier-1 scale.

The committed budgets are deliberately loose (~4x measured wall, ~3x
measured RSS) so they gate the complexity class — a quadratic-memory or
all-sources regression blows through them — while the calibration-normalized
``total_seconds`` gate in ``check_regression.py`` catches ordinary slowdowns.
"""
from __future__ import annotations

import json
import pathlib
import resource
import time

import numpy as np

#: the tier-1 routing bench families (keep in sync with routing_eval.SPECS)
SPECS = [
    "lps(13,5)",
    "slimfly(13)",
    "torus(16,2)",
    "hypercube(8)",
    "ccc(6)",
    "butterfly(3,4)",
    "petersen_torus(5,4)",
    "dragonfly",
    "random_regular(256,6,0)",
]

SCALE_SPEC = "xpander(65536,32,0,0)"
SCALE_NODES = 65536
SCALE_SOURCES = 64            # sample_fraction = 64 / 65536 ~ 0.1%

#: fixed scale-row budgets (measured: ~105 s wall, ~1.2 GiB peak RSS)
WALL_BUDGET_SECONDS = 420.0
RSS_BUDGET_GB = 4.0

#: Moore bound: a 32-regular graph on 65536 nodes has diameter >= 4, and any
#: single BFS source certifies >= half the true eccentricity spread — the
#: sampled lower bound must land in [3, true diameter]
DIAMETER_LB_FLOOR = 3

COLUMNS = [
    "instance", "nodes", "radix", "backend", "rho2",
    "diameter_bfs", "diameter_lb", "diameter_ok", "avg_hops", "avg_hops_ci",
    "path_diversity", "traffic_pattern", "max_link_load",
    "saturation_throughput", "throughput_spectral", "seconds",
]


def _bitwise_case(spec: str) -> dict:
    """sample_fraction=1.0 vs exact analyze_routing, field by field."""
    from repro.api import build
    from repro.core import routing as R

    t0 = time.time()
    topo = build(spec)
    exact = R.analyze_routing(topo)
    full = R.analyze_routing(topo, sample_fraction=1.0, seed=1)
    bitwise = bool(
        full.exact
        and np.array_equal(full.sources, exact.sources)
        and np.array_equal(full.dist, exact.dist)
        and np.array_equal(full.sigma, exact.sigma)
        and full.diameter == exact.diameter == full.diameter_lb
        and full.avg_path_length == exact.avg_path_length
        and np.array_equal(full.hop_histogram, exact.hop_histogram)
        and full.path_diversity_mean == exact.path_diversity_mean
        and full.avg_hops_ci == (exact.avg_path_length,
                                 exact.avg_path_length))
    return dict(family=topo.name, spec=spec, nodes=topo.n,
                bitwise=bitwise, seconds=round(time.time() - t0, 3))


def run(out_json: str = "benchmarks/out/BENCH_scale.json",
        out_csv: str = "benchmarks/out/scale_bench.csv"):
    from repro.api import survey
    from repro.api.survey import csv_field

    from .calibrate import measure_calibration

    t0 = time.time()
    cases = [_bitwise_case(spec) for spec in SPECS]

    t_row = time.time()
    res = survey([SCALE_SPEC], COLUMNS,
                 routing=dict(pattern="uniform",
                              sample_fraction=SCALE_SOURCES / SCALE_NODES,
                              seed=0))
    row = res.rows[0]
    row_seconds = time.time() - t_row
    rss_gb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 2 ** 20

    lo, hi = row["avg_hops_ci"]
    payload = dict(
        bench="scale_survey_row",
        total_seconds=round(time.time() - t0, 3),
        calibration_seconds=round(measure_calibration(), 4),
        scale_spec=SCALE_SPEC,
        budget=dict(wall_seconds=WALL_BUDGET_SECONDS, rss_gb=RSS_BUDGET_GB,
                    sources=SCALE_SOURCES),
        scale_row=dict(row, seconds=round(row_seconds, 3),
                       peak_rss_gb=round(rss_gb, 3)),
        exactness=cases,
        correctness=dict(
            cases=len(cases),
            sample_fraction_one_bitwise=all(c["bitwise"] for c in cases),
            scale_nodes=row["nodes"],
            within_wall_budget=bool(row_seconds <= WALL_BUDGET_SECONDS),
            within_rss_budget=bool(rss_gb <= RSS_BUDGET_GB),
            diameter_lb_certified=bool(
                DIAMETER_LB_FLOOR <= row["diameter_lb"] <= row["diameter_bfs"]),
            avg_hops_inside_ci=bool(lo <= row["avg_hops"] <= hi),
            saturation_throughput_positive=bool(
                row["saturation_throughput"] > 0),
        ),
    )
    out = pathlib.Path(out_json)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(payload, indent=2))

    lines = [",".join(["family", "spec", "nodes", "bitwise", "seconds"])]
    for c in cases:
        lines.append(",".join(csv_field(c[k]) for k in
                              ("family", "spec", "nodes", "bitwise",
                               "seconds")))
    pathlib.Path(out_csv).write_text("\n".join(lines) + "\n")
    return [payload]


if __name__ == "__main__":
    rows = run()
    print(json.dumps(rows[0]["correctness"], indent=2))
