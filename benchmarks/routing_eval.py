"""Registry-wide path-level routing & traffic evaluation.

The paper predicts network quality from the spectral gap; this benchmark
*measures* it, SpectralFly-style: for every family in the resilience-survey
set (incl. the lps(13,5) Ramanujan reference, n=2184), batched all-sources BFS
gives the exact diameter, average shortest-path length, and per-pair
minimal-path diversity, then minimal-path ECMP link-load accounting under
synthetic traffic (uniform all-to-all, bit-complement, adversarial
Fiedler-matched permutation, transpose where n is square) gives max-link-load
and saturation throughput — reported side by side with the spectral
prediction (Theorem 2 bisection floor → ``thpt_spectral``).

Emits ``benchmarks/out/BENCH_routing.json`` (gated in CI next to
``BENCH_survey.json`` / ``BENCH_faults.json``) and
``benchmarks/out/routing_eval.csv``.

    PYTHONPATH=src python -m benchmarks.routing_eval
"""
from __future__ import annotations

import json
import math
import pathlib
import time
from typing import List

# same registry coverage as the fault sweep: Ramanujan reference vs §4 survey
SPECS = [
    "lps(13,5)",                  # Ramanujan reference (n=2184, k=6)
    "slimfly(13)",                # n=338
    "torus(16,2)",                # n=256
    "hypercube(8)",               # n=256
    "ccc(6)",                     # n=384
    "butterfly(3,4)",             # n=324
    "petersen_torus(5,4)",        # n=200
    "dragonfly",                  # n=42 (complete(6) routers)
    "random_regular(256,6,0)",    # near-Ramanujan random baseline
]

#: conservation must hold to float32 accumulation accuracy
CONSERVATION_TOL = 1e-4

#: route n > 1024 through the Lanczos rho2/Fiedler path: the routing/traffic
#: measurements themselves are size-independent of this, but the lps(13,5)
#: dense 2184^2 eigendecompositions would dominate (and destabilize) the
#: gated wall time for a column that Lanczos reproduces to ~1e-4
DENSE_THRESHOLD = 1024


def _round_opt(x, nd: int = 4):
    return None if x is None else round(float(x), nd)


def run(out_json: str = "benchmarks/out/BENCH_routing.json",
        out_csv: str = "benchmarks/out/routing_eval.csv") -> List[dict]:
    from repro.api import Analysis
    from repro.api.survey import csv_field
    from repro.core.traffic import spectral_throughput_estimate

    from .calibrate import measure_calibration

    calibration = measure_calibration()
    t_all = time.time()
    table: List[dict] = []
    diameters_ok = True
    conservation_ok = True
    details = {}
    for spec in SPECS:
        a = Analysis(spec, dense_threshold=DENSE_THRESHOLD)
        t0 = time.time()
        r = a.routing()
        patterns = ["uniform", "bit_complement", "adversarial"]
        if math.isqrt(a.n) ** 2 == a.n:
            patterns.append("transpose")
        traffic = {p: a.traffic(p) for p in patterns}
        secs = time.time() - t0
        cf = a.closed_forms or {}
        diam_cf = cf.get("diameter")
        diam_ok = None if diam_cf is None else bool(r.diameter == int(diam_cf))
        if diam_ok is False:
            diameters_ok = False
        conservation_ok &= all(t.conservation_error < CONSERVATION_TOL
                               for t in traffic.values())
        uni = traffic["uniform"]
        table.append(dict(
            family=a.family or a.name,
            spec=spec,
            nodes=a.n,
            radix=a.radix,
            rho2=round(a.rho2, 5),
            diameter_bfs=r.diameter,
            diameter_closed_form=None if diam_cf is None else int(diam_cf),
            diameter_ok=diam_ok,
            avg_hops=round(r.avg_path_length, 4),
            path_diversity=round(r.path_diversity_mean, 4),
            max_load_uniform=round(uni.max_link_load, 4),
            thpt_uniform=round(uni.saturation_throughput, 4),
            thpt_spectral=round(spectral_throughput_estimate(a.n, a.rho2), 4),
            thpt_bit_complement=_round_opt(
                traffic["bit_complement"].saturation_throughput),
            thpt_adversarial=_round_opt(
                traffic["adversarial"].saturation_throughput),
            thpt_transpose=_round_opt(
                traffic["transpose"].saturation_throughput
                if "transpose" in traffic else None),
            seconds=round(secs, 2),
        ))
        details[spec] = dict(
            routing=r.to_dict(),
            traffic={p: t.to_dict() for p, t in traffic.items()})
    table.sort(key=lambda row: -row["thpt_uniform"])
    payload = dict(
        bench="routing_eval",
        total_seconds=round(time.time() - t_all, 3),
        calibration_seconds=round(calibration, 4),
        families=SPECS,
        correctness=dict(
            cases=len(SPECS),
            all_diameters_match_closed_forms=bool(diameters_ok),
            load_conservation_ok=bool(conservation_ok),
            # the adversarial pattern is built on the *canonical* Fiedler
            # vector (deterministic on degenerate eigenspaces), so its
            # throughput is reproducible and gated exactly per family
            thpt_adversarial={row["spec"]: row["thpt_adversarial"]
                              for row in table},
        ),
        routing_table=table,
        details=details,
    )
    p = pathlib.Path(out_json)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2))
    cols = list(table[0])
    pathlib.Path(out_csv).write_text("\n".join(
        [",".join(cols)]
        + [",".join(csv_field(row[c]) for c in cols) for row in table]))
    return table


if __name__ == "__main__":
    for row in run():
        print(row)
