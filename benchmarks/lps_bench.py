"""LPS Ramanujan certification (§3.1) + matrix-free Lanczos timing.

For each (p, q): construct X^{p,q} through the registry, certify
lambda(G) <= 2 sqrt(q) (dense oracle for small n, deflated Lanczos above —
the Analysis session picks the backend by ``n``), and check the diameter
against Alon-Milman.  Everything is one ``survey()`` call over spec strings.
"""
from __future__ import annotations

from typing import List

from repro.api import RAMANUJAN_COLUMNS, survey

SPECS = [
    "lps(13,5)",
    "lps(13,17)",
    "lps(17,5)",
    "lps(17,13)",
    "lps(29,5)",
]

#: LPS instances above this order skip the dense eigendecomposition and
#: certify through the deflated Lanczos path instead.
DENSE_THRESHOLD = 5000


def run(out_csv: str = "benchmarks/out/lps.csv") -> List[dict]:
    res = survey(SPECS, columns=RAMANUJAN_COLUMNS,
                 dense_threshold=DENSE_THRESHOLD, lanczos_iters=150)
    res.to_csv(out_csv)
    # the aggregator's contract: a boolean per row under 'ramanujan'
    for r in res.rows:
        r["ramanujan"] = r["is_ramanujan"]
    return res.rows


if __name__ == "__main__":
    for r in run():
        print(r)
