"""LPS Ramanujan certification (§3.1) + Pallas-kernel Lanczos timing.

For each (p, q): construct X^{p,q}, certify lambda(G) <= 2 sqrt(q) (dense for
small n, deflated Lanczos above), check girth/diameter against Alon-Milman,
and time the cayley_spmv-backed matvec (the production eigensolver path).
"""
from __future__ import annotations

import math
import pathlib
import time
from typing import List

import numpy as np

from repro.core import bounds as B
from repro.core import spectral as S
from repro.core.properties import eccentricity
from repro.core.ramanujan import is_ramanujan, lps, ramanujan_bound

CASES = [(13, 5), (13, 17), (17, 5), (17, 13), (29, 5)]


def run(out_csv: str = "benchmarks/out/lps.csv") -> List[dict]:
    rows = []
    for p, q in CASES:
        t0 = time.time()
        g = lps(p, q)
        build_s = time.time() - t0
        k = g.radix
        if g.n <= 5000:
            spec = S.adjacency_spectrum(g)
            lam = float(np.max(np.abs(spec[np.abs(np.abs(spec) - k) > 1e-6])))
        else:
            defl = [np.ones(g.n)]
            if g.meta["bipartite"]:
                import networkx as nx
                color = nx.bipartite.color(g.to_networkx())
                defl.append(np.array([1.0 if color[i] == 0 else -1.0
                                      for i in range(g.n)]))
            mv = S.table_matvec(g.neighbor_table())
            lmax, lmin = S.lanczos_extremes(mv, g.n, m=150, deflate_vectors=defl)
            lam = max(abs(lmax), abs(lmin))
        t1 = time.time()
        diam = eccentricity(g, 0)   # vertex-transitive (Cayley)
        rows.append(dict(
            p=p, q=q, n=g.n, radix=k, bipartite=g.meta["bipartite"],
            lam=round(lam, 5), bound=round(ramanujan_bound(k), 5),
            ramanujan=lam <= ramanujan_bound(k) + 1e-6,
            diameter=diam,
            alon_milman_diam_ub=B.alon_milman_diameter_ub(
                g.n, k, k - lam),
            build_seconds=round(build_s, 2),
            spectrum_seconds=round(t1 - t0 - build_s, 2),
        ))
    path = pathlib.Path(out_csv)
    path.parent.mkdir(parents=True, exist_ok=True)
    cols = list(rows[0])
    path.write_text("\n".join([",".join(cols)] +
                              [",".join(str(r[c]) for c in cols) for r in rows]))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
