"""Paper Figure 5: proportional bisection bandwidth (BW / 2m) vs node count.

Curves per topology family under the paper's §5 assumptions (radix regimes
<=64 current / <=128 next-gen; butterfly s>=3, CLEX ell>=2 & k>=3, DV C>=3,
torus k>=3) + the Ramanujan Fiedler floor (k - 2 sqrt(k-1)) n/4 / (kn/2).

All analytic Table-1 records come from the topology registry
(``repro.api.closed_forms``) — the same expressions the survey engine checks
against measurements — instead of a hand-maintained parallel dict.
"""
from __future__ import annotations

import pathlib
from typing import List

from repro.api import closed_forms
from repro.core import bounds as B

#: (family, parameter sweep, node cap or None).  prop_bw is scale-free, so
#: families plotted to arbitrary size (butterfly/ccc/hypercube/slimfly) carry
#: no cap; the capped ones match the paper's plotted domain.
SWEEPS = [
    ("butterfly", [dict(k=k, s=s) for k in (2, 3, 4, 8, 16, 32)
                   for s in range(3, 12)], None),
    ("ccc", [dict(d=d) for d in range(3, 22)], None),
    ("clex", [dict(k=k, ell=ell) for k in range(3, 20) for ell in range(2, 8)],
     3e6),
    ("data_vortex", [dict(A=A, C=C) for A in (4, 8, 16, 32, 64)
                     for C in range(3, 12)], 3e6),
    ("hypercube", [dict(d=d) for d in range(3, 22)], None),
    ("slimfly", [dict(q=q) for q in (5, 13, 17, 29, 37, 41, 53, 61, 73, 89, 97)],
     None),
    ("torus", [dict(k=k, d=d) for d in (2, 3, 4, 5)
               for k in (3, 4, 8, 16, 32, 64)], 3e6),
]


def _ram_floor(k: float) -> float:
    # proportional: Fiedler LB at Ramanujan rho2, over 2m = k*n
    return B.ramanujan_rho2(k) / (4.0 * k)


def curves(radix_cap: int = 64) -> List[dict]:
    rows = []
    for family, sweep, node_cap in SWEEPS:
        for params in sweep:
            e = closed_forms(family, **params)
            if e["radix"] > radix_cap or "bw_ub" not in e:
                continue
            if node_cap is not None and e["nodes"] > node_cap:
                continue
            rows.append(dict(topology=family, nodes=e["nodes"],
                             prop_bw=e["bw_ub"] / (e["radix"] * e["nodes"]),
                             radix=e["radix"]))
    # Ramanujan floor at matched radixes
    for k in (3, 4, 6, 8, 16, 32, 64, 128):
        if k > radix_cap + 64:
            continue
        for n in (1e2, 1e3, 1e4, 1e5, 1e6):
            rows.append(dict(topology=f"ramanujan_floor_k{k}", nodes=int(n),
                             prop_bw=_ram_floor(k), radix=k))
    return rows


def run(out_csv: str = "benchmarks/out/fig5.csv") -> List[dict]:
    rows = curves(64) + [dict(r, regime="128") for r in curves(128)]
    p = pathlib.Path(out_csv)
    p.parent.mkdir(parents=True, exist_ok=True)
    cols = ["topology", "nodes", "prop_bw", "radix"]
    p.write_text("\n".join([",".join(cols)] +
                           [",".join(str(r[c]) for c in cols) for r in rows]))
    return rows


if __name__ == "__main__":
    rows = run()
    print(f"{len(rows)} curve points written")
