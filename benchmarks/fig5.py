"""Paper Figure 5: proportional bisection bandwidth (BW / 2m) vs node count.

Curves per topology family under the paper's §5 assumptions (radix regimes
<=64 current / <=128 next-gen; butterfly s>=3, CLEX ell>=2 & k>=3, DV C>=3,
torus k>=3) + the Ramanujan Fiedler floor (k - 2 sqrt(k-1)) n/4 / (kn/2).
"""
from __future__ import annotations

import math
import pathlib
from typing import List

from repro.core import bounds as B


def _ram_floor(k: float) -> float:
    # proportional: Fiedler LB at Ramanujan rho2, over 2m = k*n
    return B.ramanujan_rho2(k) / (4.0 * k)


def curves(radix_cap: int = 64) -> List[dict]:
    rows = []
    # Butterfly(k, s): radix 2k, n = s k^s, BW_ub = (k+1)k^s/2, 2m = 2k n
    for k in (2, 3, 4, 8, 16, 32):
        if 2 * k > radix_cap:
            continue
        for s in range(3, 12):
            e = B.TABLE1["butterfly"](k, s)
            rows.append(dict(topology="butterfly", nodes=e["nodes"],
                             prop_bw=e["bw_ub"] / (e["radix"] * e["nodes"]),
                             radix=e["radix"]))
    # CCC(d): radix 3
    for d in range(3, 22):
        e = B.TABLE1["ccc"](d)
        rows.append(dict(topology="ccc", nodes=e["nodes"],
                         prop_bw=e["bw_ub"] / (e["radix"] * e["nodes"]),
                         radix=3))
    # CLEX(k, ell)
    for k in range(3, 20):
        for ell in range(2, 8):
            e = B.TABLE1["clex"](k, ell)
            if e["radix"] > radix_cap or e["nodes"] > 3e6:
                continue
            rows.append(dict(topology="clex", nodes=e["nodes"],
                             prop_bw=e["bw_ub"] / (e["radix"] * e["nodes"]),
                             radix=e["radix"]))
    # DataVortex(A, C): radix 4
    for A in (4, 8, 16, 32, 64):
        for C in range(3, 12):
            e = B.TABLE1["data_vortex"](A, C)
            if e["nodes"] > 3e6:
                continue
            rows.append(dict(topology="data_vortex", nodes=e["nodes"],
                             prop_bw=e["bw_ub"] / (e["radix"] * e["nodes"]),
                             radix=4))
    # Hypercube
    for d in range(3, 22):
        if d > radix_cap:
            continue
        e = B.TABLE1["hypercube"](d)
        rows.append(dict(topology="hypercube", nodes=e["nodes"],
                         prop_bw=e["bw_ub"] / (e["radix"] * e["nodes"]),
                         radix=d))
    # SlimFly(q): prime q = 1 mod 4
    for q in (5, 13, 17, 29, 37, 41, 53, 61, 73, 89, 97):
        e = B.TABLE1["slimfly"](q)
        if e["radix"] > radix_cap:
            continue
        rows.append(dict(topology="slimfly", nodes=e["nodes"],
                         prop_bw=e["bw_ub"] / (e["radix"] * e["nodes"]),
                         radix=e["radix"]))
    # Torus(k, d)
    for d in (2, 3, 4, 5):
        for k in (3, 4, 8, 16, 32, 64):
            e = B.TABLE1["torus"](k, d)
            if e["nodes"] > 3e6 or e["radix"] > radix_cap:
                continue
            rows.append(dict(topology="torus", nodes=e["nodes"],
                             prop_bw=e["bw_ub"] / (e["radix"] * e["nodes"]),
                             radix=e["radix"]))
    # Ramanujan floor at matched radixes
    for k in (3, 4, 6, 8, 16, 32, 64, 128):
        if k > radix_cap + 64:
            continue
        for n in (1e2, 1e3, 1e4, 1e5, 1e6):
            rows.append(dict(topology=f"ramanujan_floor_k{k}", nodes=int(n),
                             prop_bw=_ram_floor(k), radix=k))
    return rows


def run(out_csv: str = "benchmarks/out/fig5.csv") -> List[dict]:
    rows = curves(64) + [dict(r, regime="128") for r in curves(128)]
    p = pathlib.Path(out_csv)
    p.parent.mkdir(parents=True, exist_ok=True)
    cols = ["topology", "nodes", "prop_bw", "radix"]
    p.write_text("\n".join([",".join(cols)] +
                           [",".join(str(r[c]) for c in cols) for r in rows]))
    return rows


if __name__ == "__main__":
    rows = run()
    print(f"{len(rows)} curve points written")
