"""Machine-speed calibration for the bench-regression gate.

Absolute wall-clock baselines committed from one machine flake on another
(different runner class, cold JIT cache, concurrent load).  Each BENCH
payload therefore records ``calibration_seconds`` — the median time of a
fixed dense eigendecomposition measured in the same process right before the
benchmark — and ``check_regression.py`` gates on the *calibration-normalized*
ratio whenever both sides carry the field.
"""
from __future__ import annotations

import time

import numpy as np

_N = 768
_REPS = 5


def measure_calibration() -> float:
    """Median seconds of ``eigvalsh`` on a fixed symmetric 768x768 matrix.

    One discarded warmup rep first (BLAS thread-pool spin-up dominates the
    cold call), then the median of ``_REPS`` timed reps — the probe sits in
    the gate's denominator, so its noise multiplies straight into the
    normalized verdict.
    """
    rng = np.random.default_rng(0)
    a = rng.normal(size=(_N, _N))
    a = (a + a.T) / 2.0
    np.linalg.eigvalsh(a)           # warmup, not timed
    times = []
    for _ in range(_REPS):
        t0 = time.time()
        np.linalg.eigvalsh(a)
        times.append(time.time() - t0)
    return float(np.median(times))
