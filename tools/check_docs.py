"""CI docs gate: dead intra-repo links + API coverage of the docs site.

Checks, with **stdlib only** (no numpy/jax — the CI docs job installs
nothing):

1. every relative markdown link in ``docs/*.md`` and ``README.md`` resolves
   to an existing file (http(s)/mailto/pure-anchor links are skipped);
2. every public symbol of ``repro.api`` (the ``__all__`` literal, read by AST
   so nothing is imported) appears in ``docs/api.md``;
3. every registered topology family name (the ``@register("name", ...)``
   decorators in ``repro/core/topologies.py`` / ``ramanujan.py``, also read
   by AST) appears in ``docs/api.md``;
4. every ``*_COLUMNS`` constant exported by ``repro.api.survey`` — the name
   AND every column it lists — appears backticked in ``docs/api.md``, so
   a column addition can't silently skip the docs;
5. every public symbol of ``repro.core.workloads`` appears in
   ``docs/workloads.md`` (the subsystem page documents its own API);
6. every public symbol of ``repro.obs`` appears in
   ``docs/observability.md`` (same per-subsystem-page rule).

Exit code 0 when clean, 1 with a per-failure listing otherwise::

    python tools/check_docs.py [--root REPO_ROOT]
"""
from __future__ import annotations

import argparse
import ast
import pathlib
import re
import sys
from typing import List

#: [text](target) — target captured up to the closing paren (no nesting in
#: our docs); images ![alt](target) match the same tail.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

DOC_FILES = ["README.md", "docs/architecture.md", "docs/theory.md",
             "docs/api.md", "docs/synthesis.md", "docs/simulation.md",
             "docs/workloads.md", "docs/scale.md",
             "docs/routing-schemes.md", "docs/observability.md"]
API_INIT = "src/repro/api/__init__.py"
SURVEY_MODULE = "src/repro/api/survey.py"
WORKLOADS_MODULE = "src/repro/core/workloads.py"
OBS_MODULE = "src/repro/obs.py"
REGISTER_FILES = ["src/repro/core/topologies.py", "src/repro/core/ramanujan.py",
                  "src/repro/core/synthesis.py"]


def check_links(root: pathlib.Path, md_files: List[pathlib.Path]) -> List[str]:
    """Dead relative links in the given markdown files."""
    errors = []
    for md in md_files:
        text = md.read_text()
        # fenced code blocks are not navigation; skip their pseudo-links
        text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
        for m in _LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(root)}: dead link -> {target}")
    return errors


def _module_all(path: pathlib.Path) -> List[str]:
    """The ``__all__`` list literal of a module, without importing it."""
    tree = ast.parse(path.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if "__all__" in targets:
                return list(ast.literal_eval(node.value))
    raise ValueError(f"{path}: no __all__ literal found")


def _registered_families(path: pathlib.Path) -> List[str]:
    """Family names from ``@register("name", ...)`` decorators, via AST."""
    names = []
    tree = ast.parse(path.read_text())
    for node in ast.walk(tree):
        for deco in getattr(node, "decorator_list", []):
            if (isinstance(deco, ast.Call) and isinstance(deco.func, ast.Name)
                    and deco.func.id == "register" and deco.args
                    and isinstance(deco.args[0], ast.Constant)):
                names.append(deco.args[0].value)
    return names


def _documented(name: str, text: str) -> bool:
    """A name counts as documented only in code-literal (backticked) position
    — ``` `build` ``` or ``` `build(spec)` ``` — never as a prose substring
    ('builds', 'target'), which would satisfy short names vacuously."""
    return re.search(r"`%s\b" % re.escape(name), text) is not None


def _column_constants(path: pathlib.Path) -> dict:
    """Every module-level ``*_COLUMNS`` list literal: name -> member list."""
    out = {}
    tree = ast.parse(path.read_text())
    for node in tree.body:                 # top level only, not ast.walk
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.List):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id.endswith("_COLUMNS"):
                    out[t.id] = list(ast.literal_eval(node.value))
    return out


def check_columns_coverage(root: pathlib.Path) -> List[str]:
    """Every exported *_COLUMNS constant — name and members — in docs/api.md."""
    api_md = root / "docs" / "api.md"
    if not api_md.exists():
        return []                          # already reported by api coverage
    if not (root / SURVEY_MODULE).exists():
        return [f"missing module {SURVEY_MODULE} (listed in SURVEY_MODULE)"]
    text = api_md.read_text()
    errors = []
    exported = set(_module_all(root / API_INIT))
    for const, members in _column_constants(root / SURVEY_MODULE).items():
        if const not in exported:
            errors.append(f"{SURVEY_MODULE}: {const} is not exported via "
                          "repro.api __all__ (export it or drop the suffix)")
        if not _documented(const, text):
            errors.append(f"docs/api.md: column set {const!r} undocumented")
        for col in members:
            if not _documented(col, text):
                errors.append(f"docs/api.md: column {col!r} ({const}) "
                              "undocumented")
    return errors


def check_workloads_coverage(root: pathlib.Path) -> List[str]:
    """Every repro.core.workloads public symbol named in docs/workloads.md."""
    wl_md = root / "docs" / "workloads.md"
    if not wl_md.exists():
        return ["docs/workloads.md is missing"]
    if not (root / WORKLOADS_MODULE).exists():
        return [f"missing module {WORKLOADS_MODULE} "
                "(listed in WORKLOADS_MODULE)"]
    text = wl_md.read_text()
    errors = []
    for sym in _module_all(root / WORKLOADS_MODULE):
        if not _documented(sym, text):
            errors.append(f"docs/workloads.md: repro.core.workloads symbol "
                          f"{sym!r} undocumented")
    return errors


def check_obs_coverage(root: pathlib.Path) -> List[str]:
    """Every repro.obs public symbol named in docs/observability.md."""
    obs_md = root / "docs" / "observability.md"
    if not obs_md.exists():
        return ["docs/observability.md is missing"]
    if not (root / OBS_MODULE).exists():
        return [f"missing module {OBS_MODULE} (listed in OBS_MODULE)"]
    text = obs_md.read_text()
    errors = []
    for sym in _module_all(root / OBS_MODULE):
        if not _documented(sym, text):
            errors.append(f"docs/observability.md: repro.obs symbol "
                          f"{sym!r} undocumented")
    return errors


def check_api_coverage(root: pathlib.Path) -> List[str]:
    """Every repro.api public symbol + registered family named in docs/api.md."""
    api_md = root / "docs" / "api.md"
    if not api_md.exists():
        return ["docs/api.md is missing"]
    text = api_md.read_text()
    errors = []
    for sym in _module_all(root / API_INIT):
        if not _documented(sym, text):
            errors.append(f"docs/api.md: repro.api symbol {sym!r} undocumented")
    for reg_file in REGISTER_FILES:
        if not (root / reg_file).exists():
            errors.append(f"missing constructor module {reg_file} "
                          "(listed in REGISTER_FILES)")
            continue
        for fam in _registered_families(root / reg_file):
            if not _documented(fam, text):
                errors.append(f"docs/api.md: registered family {fam!r} "
                              f"({reg_file}) undocumented")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=str(pathlib.Path(__file__).parents[1]),
                    help="repository root (default: this file's grandparent)")
    args = ap.parse_args(argv)
    root = pathlib.Path(args.root).resolve()
    md_files = []
    for rel in DOC_FILES:
        p = root / rel
        if p.exists():
            md_files.append(p)
        else:
            print(f"  missing doc file: {rel}", file=sys.stderr)
    errors = check_links(root, md_files)
    errors += check_api_coverage(root)
    errors += check_columns_coverage(root)
    errors += check_workloads_coverage(root)
    errors += check_obs_coverage(root)
    missing = [rel for rel in DOC_FILES if not (root / rel).exists()]
    errors += [f"missing doc file {rel}" for rel in missing]
    if errors:
        print("DOCS GATE FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print(f"docs gate passed: {len(md_files)} files, links resolve, "
          "repro.api, every registered family, every *_COLUMNS constant, "
          "repro.core.workloads, and repro.obs documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
