"""End-to-end driver: train the ~100M-param LM with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --batch 8 --seq 256
    # kill it mid-run, then re-run the same command: it resumes from the
    # latest checkpoint and reproduces the straight-through loss curve.

Use --arch to train a reduced config of any assigned architecture instead.
"""
import argparse
import time

from repro.configs import get_config
from repro.configs.base import reduced
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm100m")
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the arch to smoke size")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="experiments/train_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or args.arch != "lm100m":
        cfg = reduced(cfg)
    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M")
    opt = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    data = DataConfig(global_batch=args.batch, seq_len=args.seq,
                      vocab_size=cfg.vocab_size)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, log_every=10,
                         grad_compression=args.compress_grads)
    tr = Trainer(cfg, opt, data, tcfg)
    start = tr.init_or_restore()
    if start:
        print(f"resumed from checkpoint at step {start}")
    t0 = time.time()
    last_log = start
    while tr.step < args.steps:
        tr.run(steps=min(10, args.steps - tr.step))
        h = tr.history[-1]
        tok_s = (tr.step - last_log) * args.batch * args.seq / max(time.time() - t0, 1e-9)
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  lr {h['lr']:.2e}  "
              f"gnorm {h['grad_norm']:.2f}  {tok_s:,.0f} tok/s"
              + ("  [straggler]" if h["straggler"] else ""))
        t0, last_log = time.time(), tr.step
    tr.save()
    print(f"done at step {tr.step}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
