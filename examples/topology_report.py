"""Generate a paper-style topology report (Table 1 + Ramanujan comparison)
for a topology of your choice.

    PYTHONPATH=src python examples/topology_report.py --topology slimfly --q 13
    PYTHONPATH=src python examples/topology_report.py --topology lps --p 13 --q 17
    PYTHONPATH=src python examples/topology_report.py --topology torus --k 16 --d 2
"""
import argparse

import numpy as np

from repro.core import bounds as B
from repro.core import spectral as S
from repro.core import topologies as T
from repro.core.properties import bisection_fiedler, diameter
from repro.core.ramanujan import is_ramanujan, lps, ramanujan_bound


def build(args):
    t = args.topology
    if t == "torus":
        return T.torus(args.k, args.d)
    if t == "hypercube":
        return T.hypercube(args.d)
    if t == "slimfly":
        return T.slimfly(args.q)
    if t == "butterfly":
        return T.butterfly(args.k, args.s)
    if t == "ccc":
        return T.cube_connected_cycles(args.d)
    if t == "clex":
        return T.clex(args.k, args.ell)
    if t == "data_vortex":
        return T.data_vortex(args.a, args.c)
    if t == "peterson_torus":
        return T.peterson_torus(args.a, args.b)
    if t == "dragonfly":
        return T.dragonfly(T.complete(args.k))
    if t == "lps":
        return lps(args.p, args.q)
    if t == "jellyfish":
        return T.random_regular(args.n, args.k, seed=0)
    raise SystemExit(f"unknown topology {t}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", required=True)
    for flag, default in (("k", 4), ("d", 2), ("q", 5), ("s", 3), ("ell", 2),
                          ("a", 5), ("b", 4), ("c", 4), ("p", 13), ("n", 128)):
        ap.add_argument(f"--{flag}", type=int, default=default)
    args = ap.parse_args()
    g = build(args)
    k = g.radix
    rho2 = S.algebraic_connectivity(g)
    bw, _ = bisection_fiedler(g)
    diam = diameter(g, vertex_transitive=False)
    print(f"topology        : {g.name}")
    print(f"nodes / radix   : {g.n} / {k}")
    print(f"rho2 (measured) : {rho2:.5f}")
    print(f"spectral gap    : {S.spectral_gap(g):.5f}" if g.n <= 4096 else
          "spectral gap    : (n too large for dense path)")
    print(f"diameter        : {diam}  (Alon-Milman UB: "
          f"{B.alon_milman_diameter_ub(g.n, g.degrees().max(), rho2)})")
    print(f"bisection       : witnessed {bw:.0f}; Fiedler floor "
          f"{B.fiedler_bw_lb(g.n, rho2):.0f}; m/2 cap {B.first_moment_bw_ub(g.m):.0f}")
    print(f"fault tolerance : kappa >= rho2 = {rho2:.3f}")
    print("--- Ramanujan comparison (equal radix) ---")
    print(f"rho2 optimum    : {B.ramanujan_rho2(k):.5f} "
          f"(this graph: {rho2 / B.ramanujan_rho2(k) * 100:.1f}% of optimal)")
    print(f"BW floor at opt : {B.ramanujan_bw_lb(g.n, k):.0f} edges")
    if g.n <= 4096:
        ok, lam = is_ramanujan(g)
        print(f"Ramanujan?      : {ok} (lambda={lam:.4f}, bound={ramanujan_bound(k):.4f})")


if __name__ == "__main__":
    main()
