"""Generate a paper-style topology report (Table 1 + Ramanujan comparison)
for any registered topology, addressed by spec string.

    PYTHONPATH=src python examples/topology_report.py "slimfly(q=13)"
    PYTHONPATH=src python examples/topology_report.py "lps(13,17)"
    PYTHONPATH=src python examples/topology_report.py "torus(16,2)" --fault-rate 0.05
    PYTHONPATH=src python examples/topology_report.py "torus(16,2)" --routing
    PYTHONPATH=src python examples/topology_report.py --list

``--fault-rate`` appends the resilience block: survival statistics (rho2,
guaranteed bisection floor, connectivity) under the chosen fault model,
solved through the batched degraded-Lanczos sweep (see README "Fault
tolerance & degraded operation").

``--routing`` appends the measured path structure (batched all-sources BFS:
exact diameter, hop distribution, path diversity) and the ECMP link-load
accounting of ``--traffic-pattern`` (max link load, saturation throughput) —
see docs/api.md "Routing & traffic".

``--workload`` appends the executed-training-step block for a workload spec
(``"kimi_k2_1t@dp=64,tp=8,ep=16"``): the closed-form communication plan and
its per-phase link times on this topology, beside the Theorem-1/2
predictions of the main report — see docs/workloads.md.

    PYTHONPATH=src python examples/topology_report.py "slimfly(q=13)" \\
        --workload "qwen2_7b@dp=16,tp=4" --placement random

``--trace out.json`` records the whole run as :mod:`repro.obs` spans, prints
the span tree (name, wall time, peak-RSS delta per engine phase), and writes
perfetto-loadable Chrome-trace JSON — see docs/observability.md.

There is no per-topology dispatch here: the registry parses the spec, builds
the instance, and the lazy Analysis session computes (and backend-selects)
every reported quantity.
"""
import argparse
import contextlib

from repro import obs
from repro.api import Analysis, REGISTRY


def list_families() -> str:
    lines = ["registered topology families:"]
    for fam in REGISTRY:
        schema = ", ".join(f"{p}:{t.__name__}" for p, t in fam.params)
        example = fam.default_instance or fam.name
        lines.append(f"  {fam.name:16s} ({schema:24s})  e.g. {example}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("spec", nargs="?", help='topology spec, e.g. "slimfly(q=13)"')
    ap.add_argument("--list", action="store_true",
                    help="list registered families and their spec schemas")
    ap.add_argument("--dense-threshold", type=int, default=4096,
                    help="largest n using the dense float64 oracle")
    ap.add_argument("--lanczos-iters", type=int, default=200)
    ap.add_argument("--fault-rate", type=float, default=None,
                    help="append a resilience block at this fault rate")
    ap.add_argument("--fault-model", default="link",
                    choices=["link", "node", "attack_degree", "attack_spectral"])
    ap.add_argument("--fault-samples", type=int, default=32)
    ap.add_argument("--routing", action="store_true",
                    help="append measured path structure + traffic loads")
    ap.add_argument("--traffic-pattern", default="uniform",
                    help="traffic pattern for --routing (uniform, "
                         "bit_complement, transpose, neighbor, adversarial)")
    ap.add_argument("--workload", default=None, metavar="SPEC",
                    help='append an executed-training-step block for a '
                         'workload spec, e.g. "kimi_k2_1t@dp=64,tp=8,ep=16"')
    ap.add_argument("--placement", default="linear",
                    choices=["linear", "round_robin", "random"],
                    help="logical-rank -> physical-node strategy for "
                         "--workload")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="record the run as repro.obs spans, print the span "
                         "tree, and write Chrome-trace JSON here")
    args = ap.parse_args()
    if args.list or not args.spec:
        print(list_families())
        if not args.spec:
            ap.error("a topology spec is required (see the list above)")
        return
    with contextlib.ExitStack() as stack:
        if args.trace:
            stack.enter_context(obs.tracing(args.trace))
        a = Analysis(args.spec, dense_threshold=args.dense_threshold,
                     lanczos_iters=args.lanczos_iters)
        print(a.report())
        if args.routing:
            print("--- measured path structure (routing & traffic) ---")
            print(a.routing().report())
            print(a.traffic(args.traffic_pattern).report())
        if args.workload:
            print("--- executed training step (workload lowering) ---")
            res = a.simulate(workload=args.workload, placement=args.placement)
            print(res.plan.report())
            print(res.report())
        if args.fault_rate is not None:
            print("--- resilience (degraded operation) ---")
            print(a.fault_sweep(rates=(args.fault_rate,),
                                model=args.fault_model,
                                samples=args.fault_samples).report())
        if args.trace:
            print(f"--- span tree (trace written to {args.trace}) ---")
            print(obs.render_tree())


if __name__ == "__main__":
    main()
