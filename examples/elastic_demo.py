"""Fault-tolerance walkthrough: crash -> restore -> elastic re-mesh, plus the
paper's discrepancy certificate for degraded operation.

    PYTHONPATH=src python examples/elastic_demo.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault_tolerance import (degraded_operation_certificate,
                                           plan_elastic_remesh)
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    tmp = tempfile.mkdtemp(prefix="elastic_demo_")
    cfg = reduced(get_config("qwen2-7b"), repeats=1)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=24)
    data = DataConfig(global_batch=4, seq_len=32, vocab_size=cfg.vocab_size)
    tcfg = TrainerConfig(total_steps=24, ckpt_every=6, ckpt_dir=tmp)

    print("1. train 12 steps with checkpoints every 6 ...")
    t = Trainer(cfg, opt, data, tcfg)
    t.init_or_restore()
    t.run(steps=12)
    print(f"   loss at step 12: {t.history[-1]['loss']:.4f}")

    print("2. 'crash' — new process restores from the atomic checkpoint ...")
    t2 = Trainer(cfg, opt, data, tcfg)
    resumed = t2.init_or_restore()
    print(f"   resumed at step {resumed}")
    t2.run(steps=12)
    print(f"   loss at step 24: {t2.history[-1]['loss']:.4f}")

    print("3. elastic re-mesh after losing 16 of 512 chips (TP axis kept):")
    plan = plan_elastic_remesh(n_devices=512, lost=16, model_axis=16)
    print(f"   {plan.old_devices} -> {plan.new_devices} chips, "
          f"new mesh {plan.new_mesh_shape}  ({plan.note})")
    print("   (restore path re-places the same checkpoint under the new mesh\n"
          "    via runtime.fault_tolerance.reshard — same arrays, new shardings)")

    print("4. the paper's degraded-operation certificate (LPS interconnect):")
    for alpha in (0.97, 0.9, 0.8):
        cert = degraded_operation_certificate(n=4896, radix=18, alpha=alpha)
        print(f"   alpha={alpha:.2f}: guaranteed bisection >= "
              f"{cert.guaranteed_bisection_edges:8.0f} edges on ANY surviving set")
    print("   a torus gives 0 guaranteed edges for non-contiguous survivors.")
    shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
