"""Quickstart: the paper in 60 seconds.

Builds a v5e-pod torus and an equal-radix LPS Ramanujan graph, compares their
spectral gap / bisection / diameter / fault tolerance, and shows the predicted
impact on a training step's collectives.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import bounds as B
from repro.core import spectral as S
from repro.core import topologies as T
from repro.core.collectives import NetworkModel, network_from_topology, tpu_v5e_ici
from repro.core.placement import (empirical_subset_bw,
                                  ramanujan_placement_guarantee)
from repro.core.properties import bisection_fiedler, diameter
from repro.core.ramanujan import is_ramanujan, lps


def main():
    print("=" * 72)
    print("1. A v5e pod's ICI is Torus(16,2) — the paper says tori expand badly")
    print("=" * 72)
    torus = T.torus(16, 2)
    rho2_t = S.algebraic_connectivity(torus)
    print(f"   torus(16,2):  n={torus.n:5d} radix={torus.radix} "
          f"rho2={rho2_t:.4f}  diameter={diameter(torus, vertex_transitive=True)}")
    print(f"   Ramanujan optimum at radix 4: rho2 >= {B.ramanujan_rho2(4):.4f} "
          f"({B.ramanujan_rho2(4) / rho2_t:.1f}x better)")

    print()
    print("=" * 72)
    print("2. An actual Ramanujan graph: LPS X^{13,17} (PSL(2,F_13) Cayley)")
    print("=" * 72)
    g = lps(13, 17)
    ok, lam = is_ramanujan(g)
    print(f"   lps(13,17): n={g.n} radix={g.radix} lambda={lam:.4f} "
          f"<= 2 sqrt(k-1) = {B.ramanujan_rho2(18) and 2 * np.sqrt(17):.4f} "
          f"-> Ramanujan: {ok}")
    rho2_r = S.algebraic_connectivity(g)
    bw, _ = bisection_fiedler(g)
    print(f"   rho2={rho2_r:.3f}; witnessed bisection={bw:.0f} edges "
          f"(Fiedler floor {B.fiedler_bw_lb(g.n, rho2_r):.0f})")

    print()
    print("=" * 72)
    print("3. What that buys a training job (collective cost model)")
    print("=" * 72)
    net_t = tpu_v5e_ici(16, 16)
    net_r = NetworkModel("ramanujan(k=4)", n=256, radix=4,
                         bisection_links=B.fiedler_bw_lb(256, B.ramanujan_rho2(4)),
                         diameter=6)
    grad_bytes = 2 * 7.6e9 / 256   # qwen2-7b bf16 grads, 256-way DP
    for net in (net_t, net_r):
        t = net.all_reduce(grad_bytes)
        print(f"   {net.name:16s} grad all-reduce: {t * 1e3:7.3f} ms "
              f"(bisection {net.bisection_links:.0f} links)")

    print()
    print("=" * 72)
    print("4. Fault tolerance: guaranteed bandwidth on ANY 90% of nodes")
    print("=" * 72)
    cert = ramanujan_placement_guarantee(g.n, g.radix, 0.9)
    emp = empirical_subset_bw(g, 0.9, trials=8)
    print(f"   discrepancy floor: {cert.guaranteed_bisection_edges:.0f} edges "
          f"(measured worst-of-8 random subsets: {emp:.0f})")
    t33 = T.torus(33, 2)
    emp_t = empirical_subset_bw(t33, 0.9, trials=8)
    print(f"   torus(33,2) same test: measured {emp_t:.0f} edges, NO floor "
          f"(guarantee requires contiguous re-packing)")


if __name__ == "__main__":
    main()
