"""Serving example: batched prefill + decode with the KV/SSM cache.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-7b --requests 4

Uses the reduced config (CPU container); the same prefill/decode step
functions are what the multi-pod dry-run lowers at full size.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, S = args.requests, args.prompt_len
    max_len = S + args.max_new
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = ({"tokens": prompts} if cfg.frontend == "none"
             else {"embeds": jax.random.normal(key, (B, S, cfg.d_model))})

    prefill = jax.jit(lambda p, b: M.prefill(p, b, cfg, max_len=max_len))
    decode = jax.jit(lambda p, t, c, pos: M.decode_step(p, t, c, pos, cfg))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {B} requests x {S} tokens in {t_prefill * 1e3:.1f} ms "
          f"({B * S / t_prefill:,.0f} tok/s)")

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    for i in range(args.max_new - 1):
        if cfg.frontend == "none":
            logits, caches = decode(params, tok, caches, jnp.int32(S + i))
        else:
            emb = jax.random.normal(jax.random.fold_in(key, i), (B, cfg.d_model))
            logits, caches = decode(params, emb, caches, jnp.int32(S + i))
        if args.temperature > 0:
            logits = logits / args.temperature
            tok = jax.random.categorical(jax.random.fold_in(key, 100 + i), logits)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    steps = args.max_new - 1
    print(f"decode: {steps} steps x {B} requests in {t_decode * 1e3:.1f} ms "
          f"({B * steps / max(t_decode, 1e-9):,.0f} tok/s, "
          f"{t_decode / steps * 1e3:.2f} ms/step)")
    gen = np.stack([np.asarray(t) for t in outs], axis=1)
    for r in range(B):
        print(f"request {r}: {gen[r].tolist()}")


if __name__ == "__main__":
    main()
